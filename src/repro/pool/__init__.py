"""repro.pool — batched environment execution engines (EnvPool-style).

The canonical way to run every env in the repo:

  - `EnvPool`        : XLA-resident batched pool, Gym-style reset/step plus
                       a pure `xla()` API for in-graph use (docs/pool.md).
  - `ShardedEnvPool` : same API, batch sharded over a device mesh.
  - `HostPool`       : same API over interpreted host envs (the paper's
                       foreign-runtime stand-ins), threaded + double-buffered.
  - `make_pool`      : registry-id factory over all three backends.
"""
from __future__ import annotations

from typing import Optional

from repro.core.spaces import sample_batch
from repro.pool.envpool import EnvPool, PoolState, PoolStep, XlaPool
from repro.pool.host import HostPool
from repro.pool.sharded import ShardedEnvPool, default_pool_mesh


def make_pool(name: str, num_envs: int, backend: str = "xla",
              mesh=None, **env_kwargs):
    """Build a pool for a registered env id.

    backend: "xla" (EnvPool) | "sharded" (ShardedEnvPool) | "host" (HostPool,
    interpreted baseline_python port — only ids with a baseline).
    """
    if backend == "xla":
        return EnvPool(name, num_envs, **env_kwargs)
    if backend == "sharded":
        return ShardedEnvPool(name, num_envs, mesh=mesh, **env_kwargs)
    if backend == "host":
        return HostPool(name, num_envs)
    raise ValueError(f"unknown pool backend {backend!r}; "
                     "expected 'xla', 'sharded' or 'host'")


__all__ = [
    "EnvPool", "ShardedEnvPool", "HostPool", "PoolState", "PoolStep",
    "XlaPool", "sample_batch", "default_pool_mesh", "make_pool",
]
