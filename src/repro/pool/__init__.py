"""repro.pool — batched environment execution engines (EnvPool-style).

The canonical way to run every env in the repo:

  - `make_vec`       : THE frontend. One constructor, one shared protocol;
                       returns the right pool for the request.
  - `EnvPool`        : XLA-resident batched pool, Gym-style reset/step plus
                       a pure `xla()` API for in-graph use (docs/pool.md).
  - `ShardedEnvPool` : same API, batch sharded over a device mesh.
  - `AsyncEnvPool`   : async mode — `send(actions, ids)` / `recv()` step
                       only ready lanes; sessions are spliced into free
                       slots (continuous refill, docs/pool.md).
  - `HostPool`       : same API over interpreted host envs (the paper's
                       foreign-runtime stand-ins), threaded + double-buffered.
  - `make_pool`      : legacy registry-id factory (kept for back-compat;
                       new code should call `make_vec`).
"""
from __future__ import annotations

from typing import Optional, Union

from repro.core.env import Env, supports_fused_step
from repro.core.registry import make as registry_make
from repro.core.spaces import sample_batch
from repro.pool.async_pool import AsyncEnvPool, AsyncUnsupportedError
from repro.pool.envpool import (EnvPool, FUSED_BACKENDS, PoolState, PoolStep,
                                XlaPool)
from repro.pool.host import HostPool
from repro.pool.sharded import ShardedEnvPool, default_pool_mesh

#: step-engine names `make_vec` accepts (besides "auto" and "async")
STEP_BACKENDS = ("vmap",) + FUSED_BACKENDS


def make_vec(env: Union[str, Env], num_envs: int, *, backend: str = "auto",
             mesh=None, host: bool = False, unroll: int = 1,
             num_workers: Optional[int] = None, **env_kwargs):
    """Unified vector frontend: `make_vec(id, num_envs)` -> the right pool.

    One constructor over every execution engine, all behind the shared
    pool protocol (`reset/step`, `xla()`, `rollout`):

      - default               -> `EnvPool` (XLA-resident, single process)
      - `backend="async"`     -> `AsyncEnvPool` (send/recv, continuous refill)
      - `mesh=...`            -> `ShardedEnvPool` over that device mesh
      - `host=True`           -> `HostPool` of interpreted baselines

    `backend` picks the step engine: "auto" resolves to the fused megastep
    kernel ("pallas": Pallas on TPU, row-major jnp elsewhere) whenever the
    declared pipeline supports it and to the scanned vmap step otherwise;
    pass "vmap", "pallas", "pallas_interpret" or "jnp" to pin one, or
    "async" for the session-per-slot async pool (lanes step only when their
    client has sent; `num_envs` becomes the slot count). `unroll` is the
    fused chunk depth (steps per kernel launch) for `rollout` / `step_many`
    consumers.

    `env_kwargs` go to the registry (`repro.core.registry.make`), so
    construction errors name the id and the offending kwargs.
    """
    if backend == "async":
        if mesh is not None or host:
            raise ValueError("backend='async' is single-process and "
                             "XLA-resident; mesh=/host= do not apply")
        return AsyncEnvPool(env, num_envs, **env_kwargs)
    if host:
        if not isinstance(env, str):
            raise ValueError("host=True builds interpreted baselines and "
                             "needs a registry id, not an Env instance")
        if mesh is not None:
            raise ValueError("host=True and mesh=... are mutually exclusive")
        if env_kwargs:
            raise ValueError(
                f"env_kwargs {sorted(env_kwargs)} cannot be applied with "
                "host=True: interpreted baselines (envs.baseline_python) are "
                "fixed default-config ports, and silently dropping the kwargs "
                "would compare differently-configured envs")
        return HostPool(env, num_envs, num_workers=num_workers)
    if isinstance(env, str):
        env = registry_make(env, **env_kwargs)
    elif env_kwargs:
        raise ValueError(f"env_kwargs {sorted(env_kwargs)} only apply when "
                         "building from a registry id, not an Env instance")
    if backend == "auto":
        backend = "pallas" if supports_fused_step(env) else "vmap"
    elif backend not in STEP_BACKENDS:
        raise ValueError(f"unknown step backend {backend!r}; expected 'auto' "
                         f"or one of {STEP_BACKENDS}")
    if mesh is not None:
        return ShardedEnvPool(env, num_envs, mesh=mesh, backend=backend,
                              unroll=unroll)
    return EnvPool(env, num_envs, backend=backend, unroll=unroll)


def make_pool(name: str, num_envs: int, backend: str = "xla",
              mesh=None, step_backend: str = "vmap", unroll: int = 1,
              **env_kwargs):
    """Legacy pool factory (pre-`make_vec` API), kept as a thin shim.

    backend: "xla"/"vmap" (EnvPool) | "pallas"/"pallas_interpret"/"jnp"
    (EnvPool on the fused megastep engine) | "sharded" (ShardedEnvPool,
    combine with `step_backend=`) | "host" (HostPool).
    """
    if backend in ("xla", "vmap"):
        return make_vec(name, num_envs, backend=step_backend, unroll=unroll,
                        **env_kwargs)
    if backend == "async":
        return make_vec(name, num_envs, backend="async", **env_kwargs)
    if backend in FUSED_BACKENDS:
        return make_vec(name, num_envs, backend=backend, unroll=unroll,
                        **env_kwargs)
    if backend == "sharded":
        return make_vec(name, num_envs, mesh=mesh or default_pool_mesh(),
                        backend=step_backend, unroll=unroll, **env_kwargs)
    if backend == "host":
        return make_vec(name, num_envs, host=True)
    raise ValueError(f"unknown pool backend {backend!r}; expected 'xla', "
                     f"'sharded', 'host' or one of {FUSED_BACKENDS}")


__all__ = [
    "AsyncEnvPool", "AsyncUnsupportedError", "EnvPool", "FUSED_BACKENDS",
    "STEP_BACKENDS", "ShardedEnvPool", "HostPool", "PoolState", "PoolStep",
    "XlaPool", "sample_batch", "default_pool_mesh", "make_pool", "make_vec",
]
