"""repro.pool — batched environment execution engines (EnvPool-style).

The canonical way to run every env in the repo:

  - `EnvPool`        : XLA-resident batched pool, Gym-style reset/step plus
                       a pure `xla()` API for in-graph use (docs/pool.md).
  - `ShardedEnvPool` : same API, batch sharded over a device mesh.
  - `HostPool`       : same API over interpreted host envs (the paper's
                       foreign-runtime stand-ins), threaded + double-buffered.
  - `make_pool`      : registry-id factory over all three backends.
"""
from __future__ import annotations

from typing import Optional

from repro.core.spaces import sample_batch
from repro.pool.envpool import (EnvPool, FUSED_BACKENDS, PoolState, PoolStep,
                                XlaPool)
from repro.pool.host import HostPool
from repro.pool.sharded import ShardedEnvPool, default_pool_mesh


def make_pool(name: str, num_envs: int, backend: str = "xla",
              mesh=None, step_backend: str = "vmap", unroll: int = 1,
              **env_kwargs):
    """Build a pool for a registered env id.

    backend: "xla"/"vmap" (EnvPool) | "pallas"/"pallas_interpret"/"jnp"
    (EnvPool on the fused megastep engine, `unroll` steps per kernel launch)
    | "sharded" (ShardedEnvPool; combine with `step_backend="pallas"` for
    the shard_mapped megastep engine) | "host" (HostPool, interpreted
    baseline_python port — only ids with a baseline).
    """
    if backend in ("xla", "vmap"):
        return EnvPool(name, num_envs, backend=step_backend, unroll=unroll,
                       **env_kwargs)
    if backend in FUSED_BACKENDS:
        return EnvPool(name, num_envs, backend=backend, unroll=unroll,
                       **env_kwargs)
    if backend == "sharded":
        return ShardedEnvPool(name, num_envs, mesh=mesh, backend=step_backend,
                              unroll=unroll, **env_kwargs)
    if backend == "host":
        return HostPool(name, num_envs)
    raise ValueError(f"unknown pool backend {backend!r}; expected 'xla', "
                     f"'sharded', 'host' or one of {FUSED_BACKENDS}")


__all__ = [
    "EnvPool", "FUSED_BACKENDS", "ShardedEnvPool", "HostPool", "PoolState",
    "PoolStep", "XlaPool", "sample_batch", "default_pool_mesh", "make_pool",
]
