"""EnvPool-style batched environment execution engine.

EnvPool (Weng et al., 2022) showed that the multiplier after eliminating
interpreter overhead (the CaiRL claim) is a *pooled*, batched execution
engine behind one vectorized API. Here the pool is XLA-resident: the
batched env state is a device pytree that never crosses the host boundary,
`step` is a single compiled program with the previous state's buffers
donated, and the whole pool can be lowered *into* a training program via
`xla()` (the analogue of EnvPool's XLA API) so rollout collection and
learning fuse into one device program.

Two surfaces:

  - Gym-style stateful:  `obs = pool.reset(seed)`,
                         `obs, rew, done, info = pool.step(actions)`.
    State lives on device between calls; the step is jit-compiled with
    `donate_argnums` so XLA reuses the previous state's buffers in place.

  - XLA-resident pure:   `h = pool.xla()`, `carry = h.init(key)`,
                         `carry, out = h.step(carry, actions[, key])`.
    Pure functions of an explicit carry — scannable, vmappable, and the
    canonical batching layer the RL algorithms (rl/dqn.py, rl/ppo.py)
    are built on. Passing an explicit per-step `key` gives callers full
    control of the RNG stream (the carry key is used when omitted).

`EnvPool` is backed by `Vec(AutoReset(env))`: autoreset re-enters `reset`
inside the program on `done` (pre-reset obs surfaced as
`info["terminal_obs"]`), and `Vec` vmaps the whole stack across the batch.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env, Timestep, supports_fused_step
from repro.core.registry import make as registry_make
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset, Vec

#: step-engine backends: "vmap" scans Vec(AutoReset(env)).step; the fused
#: family routes stepping through the megastep kernel (kernels/envstep) —
#: "pallas" auto-dispatches (Pallas on TPU, jnp rows elsewhere),
#: "pallas_interpret"/"jnp" force the interpreter / reference paths.
FUSED_BACKENDS = ("pallas", "pallas_interpret", "jnp")


class PoolState(NamedTuple):
    """XLA-resident pool carry. Everything stays on device across steps."""

    env_state: Any          # Vec(AutoReset(env)) state pytree, leading dim B
    obs: jax.Array          # (B, ...) current observation
    key: jax.Array          # fallback RNG stream for key-less stepping


class PoolStep(NamedTuple):
    """One batched transition (post-autoreset obs; terminal obs in info)."""

    obs: jax.Array          # (B, ...)
    reward: jax.Array       # (B,)
    done: jax.Array         # (B,)
    info: Dict[str, jax.Array]


class XlaPool(NamedTuple):
    """Pure-function handle for in-graph use (EnvPool's XLA API analogue)."""

    init: Callable[[jax.Array], PoolState]
    step: Callable[..., Tuple[PoolState, PoolStep]]
    step_many: Callable[..., Tuple[PoolState, PoolStep]]


class EnvPool:
    """Batched pool of one env type: `Vec(AutoReset(env), num_envs)` + jit.

    >>> pool = EnvPool("CartPole-v1", num_envs=256)
    >>> obs = pool.reset(seed=0)                  # (256, 4) on device
    >>> obs, rew, done, info = pool.step(actions) # one compiled dispatch

    backend="pallas" swaps the scan-of-vmap-step inner loop for the fused
    megastep kernel (kernels/envstep): `step` becomes one kernel launch, and
    `rollout`/`step_many` fuse `unroll` env steps per launch. Trajectories
    match the vmap backend (exact for int/bool fields, float rounding only
    where compilers reassociate). Requires fused-spec support
    (`core.env.supports_fused_step`); "pallas" resolves to the Pallas kernel
    on TPU and the row-major jnp reference elsewhere, "pallas_interpret" and
    "jnp" pin the interpreter / reference paths (tests, debugging).
    """

    def __init__(self, env: Union[Env, str], num_envs: int,
                 backend: str = "vmap", unroll: int = 1, **env_kwargs):
        if isinstance(env, str):
            env = registry_make(env, **env_kwargs)
        self.env = env
        self.num_envs = int(num_envs)
        self.backend = backend
        self.unroll = max(int(unroll), 1)
        if backend == "vmap":
            self._kernel_backend = None
        elif backend in FUSED_BACKENDS:
            self._kernel_backend = "auto" if backend == "pallas" else backend
            if not supports_fused_step(env):
                raise ValueError(
                    f"backend={backend!r} needs fused megastep support, but "
                    f"{env.name} has none (see repro.kernels.envstep); use "
                    "backend='vmap'")
        else:
            raise ValueError(f"unknown pool backend {backend!r}; expected "
                             f"'vmap' or one of {FUSED_BACKENDS}")
        self.venv = Vec(AutoReset(env), self.num_envs)
        self._carry: Optional[Tuple[Any, jax.Array]] = None  # (env_state, key)
        self._obs: Optional[jax.Array] = None
        # Stateful fast path: donate (env_state, key) so XLA writes the new
        # state into the old state's buffers. obs/reward/done outputs are NOT
        # part of the donated carry, so they stay valid across later steps.
        self._jit_reset = jax.jit(self._stateful_reset)
        self._jit_step = jax.jit(self._stateful_step, donate_argnums=(0,))
        self._jit_step_key = jax.jit(self._stateful_step_key,
                                     donate_argnums=(0,))
        self._rollout_cache: Dict[Tuple[int, bool], Callable] = {}

    # -- spaces / metadata ---------------------------------------------------
    @property
    def observation_space(self):
        return self.env.observation_space

    @property
    def action_space(self):
        return self.env.action_space

    def __len__(self) -> int:
        return self.num_envs

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}({self.env.name}, num_envs={self.num_envs})"

    @property
    def _fused(self) -> bool:
        return self._kernel_backend is not None

    # -- XLA-resident pure API ----------------------------------------------
    def _xla_init(self, key: jax.Array) -> PoolState:
        state, obs = self.venv.reset(key)
        return PoolState(state, obs, jax.random.fold_in(key, 0x57EB))

    def _step_many_core(self, env_state, actions: jax.Array, key: jax.Array,
                        venv: Optional[Vec] = None):
        """K batched env steps -> (env_state, (obs, reward, done, info)),
        outputs stacked with a leading (K, ...) axis. Fused backends run the
        whole block as one megastep kernel launch; vmap scans the step."""
        if self._fused:
            new_state, ts = self.env.fused_step(
                env_state, actions, num_steps=actions.shape[0],
                backend=self._kernel_backend)
            return new_state, (ts.obs, ts.reward, ts.done, ts.info)

        venv = venv if venv is not None else self.venv

        def body(state, xs):
            a, k = xs
            ts = venv.step(state, a, k)
            return ts.state, (ts.obs, ts.reward, ts.done, ts.info)

        k = actions.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(k))
        return jax.lax.scan(body, env_state, (actions, keys))

    def _xla_step(self, carry: PoolState, actions: jax.Array,
                  key: Optional[jax.Array] = None) -> Tuple[PoolState, PoolStep]:
        if self._fused:
            ps, out = self._xla_step_many(carry, actions[None], key)
            first = lambda x: x[0]
            return ps, PoolStep(out.obs[0], out.reward[0], out.done[0],
                                jax.tree.map(first, out.info))
        if key is None:
            next_key, key = jax.random.split(carry.key)
        else:
            next_key = carry.key
        ts = self.venv.step(carry.env_state, actions, key)
        return (PoolState(ts.state, ts.obs, next_key),
                PoolStep(ts.obs, ts.reward, ts.done, ts.info))

    def _xla_step_many(self, carry: PoolState, actions: jax.Array,
                       key: Optional[jax.Array] = None
                       ) -> Tuple[PoolState, PoolStep]:
        """Step the pool `actions.shape[0]` times in one fused block.

        `actions` is (K, B[, A]); outputs carry a leading (K, ...) axis.
        Equivalent to scanning `step` over the block (envs whose dynamics
        ignore the per-step key make the two paths bit-compatible)."""
        if key is None:
            next_key, key = jax.random.split(carry.key)
        else:
            next_key = carry.key
        state, (obs, reward, done, info) = self._step_many_core(
            carry.env_state, actions, key)
        return (PoolState(state, obs[-1], next_key),
                PoolStep(obs, reward, done, info))

    def xla(self) -> XlaPool:
        """Pure `(init, step, step_many)` for building into larger programs."""
        return XlaPool(self._xla_init, self._xla_step, self._xla_step_many)

    # -- Gym-style stateful API ----------------------------------------------
    def _stateful_reset(self, key):
        ps = self._xla_init(key)
        return (ps.env_state, ps.key), ps.obs

    def _stateful_step(self, carry, actions):
        env_state, key = carry
        ps, out = self._xla_step(PoolState(env_state, None, key), actions)
        return (ps.env_state, ps.key), out

    def _stateful_step_key(self, carry, actions, key):
        env_state, carry_key = carry
        ps, out = self._xla_step(PoolState(env_state, None, carry_key),
                                 actions, key)
        return (ps.env_state, ps.key), out

    def reset(self, seed: int = 0) -> jax.Array:
        """(Re)initialise all envs; returns the batched observation."""
        self._carry, self._obs = self._jit_reset(jax.random.PRNGKey(seed))
        return self._obs

    def step(self, actions,
             key: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array, jax.Array, Dict]:
        """Step every env once. Autoreset on done; state never leaves device.

        `key` pins the per-step RNG stream explicitly (the carry chain is
        left untouched) — `step(a, key=fold_in(k, t))` reproduces the raw
        `Vec.step(state, a, fold_in(k, t))` trace bit-for-bit, which is how
        the kill-and-resume tests replay the committed golden traces through
        a supervised pool (tests/test_supervisor.py).
        """
        if self._carry is None:
            raise RuntimeError("call reset() before step()")
        if key is None:
            self._carry, out = self._jit_step(self._carry, jnp.asarray(actions))
        else:
            self._carry, out = self._jit_step_key(
                self._carry, jnp.asarray(actions), key)
        self._obs = out.obs
        return out.obs, out.reward, out.done, out.info

    def sample_actions(self, seed: int = 0) -> jax.Array:
        return sample_batch(self.action_space, jax.random.PRNGKey(seed),
                            self.num_envs)

    def step_lowered(self):
        """Lower (don't run) the stateful step — for HLO inspection: the
        fault suite certifies the supervised steady-state step path still
        contains zero host-transfer instructions."""
        if self._carry is None:
            self.reset(seed=0)
        acts = jnp.zeros((self.num_envs,) + tuple(self.action_space.shape),
                         self.action_space.dtype)
        return jax.jit(self._stateful_step).lower(self._carry, acts)

    # -- snapshot / restore ----------------------------------------------------
    # The survivable-rollout contract (runtime/supervisor.py): `state_dict()`
    # is a HOST-materialized copy of the stateful carry — env state (with the
    # AutoReset key chain inside), the fallback carry key, and the current
    # obs — safe against XLA reusing the donated buffers on the next step.
    # `load_state_dict()` re-places it on device; ShardedEnvPool overrides
    # `_put_carry` so a gathered snapshot re-shards onto ANY mesh (the
    # elastic contract of checkpoint/manager.py).
    def state_dict(self) -> Dict[str, Any]:
        """Host snapshot of the stateful carry (numpy leaves, copied)."""
        if self._carry is None:
            raise RuntimeError("call reset() before snapshotting the pool")
        env_state, key = self._carry
        tree = {"env_state": env_state, "key": key, "obs": self._obs}
        return jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), tree)

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        """Restore a `state_dict()` snapshot (possibly from another pool
        instance — or, for sharded pools, another mesh)."""
        d = self._put_carry(d)
        self._carry = (d["env_state"], d["key"])
        self._obs = d["obs"]

    def _put_carry(self, d: Dict[str, Any]) -> Dict[str, Any]:
        return jax.tree.map(jnp.asarray, d)

    # -- compiled whole-rollout fast path -------------------------------------
    def rollout(self, num_steps: int, key: jax.Array, render: bool = False):
        """Random-policy rollout as ONE device program (Listing 1/2 loop).

        Returns (sum_reward (B,), episodes (B,), last_frame or zeros) —
        bit-identical to runner.rollout_random_fast for the unsharded pool.
        """
        fn = self._rollout_cache.get((num_steps, render))
        if fn is None:
            fn = jax.jit(lambda k: self._rollout(k, num_steps, render))
            self._rollout_cache[(num_steps, render)] = fn
        return fn(key)

    def rollout_lowered(self, num_steps: int, render: bool = False):
        """Lower (don't run) the rollout — for HLO inspection (fig4)."""
        return jax.jit(lambda k: self._rollout(k, num_steps, render)).lower(
            jax.random.PRNGKey(0))

    def _rollout(self, key: jax.Array, num_steps: int, render: bool):
        # Fused backends chunk the loop into `unroll`-step kernel launches
        # (render mode still needs per-step frames, so it keeps the vmap body).
        if self._fused and not render:
            return self._rollout_fused(key, num_steps)
        carry0 = self._xla_init(jax.random.fold_in(key, 0x5EED))
        frame0 = (self.venv.render(carry0.env_state) if render
                  else jnp.zeros((self.num_envs,), jnp.float32))

        def body(carry, i):
            ps, rew, eps, frame = carry
            k = jax.random.fold_in(key, i)
            actions = sample_batch(self.action_space, k, self.num_envs)
            # repro: allow[key-reuse] action-sample and step share the per-step key by design — the committed golden traces and the fused/vmap bit-parity proof pin this exact chain
            ps, out = self._xla_step(ps, actions, k)
            frame = self.venv.render(ps.env_state) if render else frame
            return (ps, rew + out.reward, eps + out.done.astype(jnp.int32), frame), None

        init = (carry0, jnp.zeros((self.num_envs,), jnp.float32),
                jnp.zeros((self.num_envs,), jnp.int32), frame0)
        (_, rew, eps, frame), _ = jax.lax.scan(body, init, jnp.arange(1, num_steps + 1))
        return rew, eps, frame

    def _rollout_fused(self, key: jax.Array, num_steps: int):
        """Same rollout, `unroll` steps per megastep launch. RNG mirrors the
        vmap body (actions from `fold_in(key, i)`, i in 1..num_steps), so
        trajectories match it step for step."""
        carry0 = self._xla_init(jax.random.fold_in(key, 0x5EED))
        kk = max(min(self.unroll, num_steps), 1)  # num_steps=0 -> no chunks
        n_chunks, rem = divmod(num_steps, kk)

        def chunk(n):
            def body(carry, c):
                ps, rew, eps = carry
                steps = c * kk + 1 + jnp.arange(n)
                ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(steps)
                acts = jax.vmap(
                    lambda s: sample_batch(self.action_space, s, self.num_envs)
                )(ks)
                ps, out = self._xla_step_many(ps, acts, key)
                return (ps, rew + out.reward.sum(0),
                        eps + out.done.astype(jnp.int32).sum(0)), None
            return body

        carry = (carry0, jnp.zeros((self.num_envs,), jnp.float32),
                 jnp.zeros((self.num_envs,), jnp.int32))
        if n_chunks:
            carry, _ = jax.lax.scan(chunk(kk), carry, jnp.arange(n_chunks))
        if rem:
            carry, _ = chunk(rem)(carry, jnp.asarray(n_chunks))
        _, rew, eps = carry
        return rew, eps, jnp.zeros((self.num_envs,), jnp.float32)
