"""HostPool — the interpreted-env pool behind the same batched API.

The paper's JVM/Flash runners (and our pure-Python "AI Gym" baselines,
envs/baseline_python) cannot be traced into XLA; HostPool runs a batch of
them on a thread pool behind the EnvPool-shaped `reset()/step(actions)`
API so compiled and interpreted execution are interchangeable in
benchmarks and training harnesses (fig1/fig2 comparisons).

Async double-buffering: `send(actions)` dispatches one worker task per
env and returns immediately; `recv()` joins. A learner can therefore
overlap its (GIL-releasing, jit-compiled) update with host env stepping —
EnvPool's async API shape. `step()` is send+recv.

Semantics mirror `Vec(AutoReset(env))`: envs auto-reset on done and the
pre-reset observation is surfaced as `info["terminal_obs"]`.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Union

import numpy as np

from repro.runtime.straggler import StragglerTracker


class HostPool:
    """Thread-pooled batch of Gym-semantics host envs (reset/step/render).

    `env_factory` is a zero-arg callable returning an object with
    `seed(s)`, `reset() -> obs`, `step(a) -> (obs, r, done, info)` and
    `action_space_sample()` — the PythonRunner contract (core/runner.py) —
    or a registry id resolved through envs.baseline_python.BASELINES.

    Straggler telemetry: interpreted envs are exactly where per-lane step
    time varies (GC pauses, GIL contention, env-specific hot paths), so
    every worker step is timed into a runtime/straggler.StragglerTracker
    keyed by env index — `stragglers()` surfaces the profile/demote advice
    for lanes persistently slower than the batch median. The clock is
    injectable for deterministic tests.
    """

    def __init__(self, env_factory: Union[Callable, str], num_envs: int,
                 num_workers: Optional[int] = None, seed: int = 0,
                 tracker: Optional[StragglerTracker] = None,
                 clock: Optional[Callable[[], float]] = None):
        if isinstance(env_factory, str):
            from repro.envs.baseline_python import BASELINES

            env_factory = BASELINES[env_factory]
        self.env_factory = env_factory
        self.num_envs = int(num_envs)
        self.tracker = tracker or StragglerTracker(num_hosts=self.num_envs)
        self._clock = clock or time.monotonic
        self._envs = [env_factory() for _ in range(self.num_envs)]
        workers = num_workers or min(self.num_envs, os.cpu_count() or 1)
        self._exec = ThreadPoolExecutor(max_workers=workers)
        self._pending = None
        self.seed(seed)

    def __len__(self) -> int:
        return self.num_envs

    def seed(self, seed: int) -> None:
        for i, env in enumerate(self._envs):
            env.seed(seed + i)

    # -- Gym-style batched API -------------------------------------------------
    def reset(self, seed: Optional[int] = None) -> np.ndarray:
        if self._pending is not None:  # join in-flight steps: envs are not
            for f in self._pending:    # safe to reset while workers mutate them
                f.result()
            self._pending = None
        if seed is not None:
            self.seed(seed)
        obs = list(self._exec.map(lambda e: np.asarray(e.reset(), np.float32),
                                  self._envs))
        return np.stack(obs)

    def send(self, actions) -> None:
        """Dispatch one step per env to the worker pool; non-blocking."""
        if self._pending is not None:
            raise RuntimeError("recv() the in-flight step before send()ing again")
        actions = np.asarray(actions)
        if actions.shape[0] != self.num_envs:
            raise ValueError(f"actions batch {actions.shape[0]} != {self.num_envs} envs")
        self._pending = [self._exec.submit(self._step_one, i, env, a)
                         for i, (env, a) in enumerate(zip(self._envs,
                                                          actions))]

    def recv(self):
        """Join the in-flight step: (obs, reward, done, info)."""
        if self._pending is None:
            raise RuntimeError("send() actions before recv()")
        results = [f.result() for f in self._pending]
        self._pending = None
        obs, reward, done, terminal = (np.stack(x) for x in zip(*results))
        return obs, reward, done, {"terminal_obs": terminal}

    def step(self, actions):
        self.send(actions)
        return self.recv()

    def _step_one(self, idx, env, action):
        if isinstance(action, np.ndarray) and action.ndim == 0:
            action = action.item()
        t0 = self._clock()
        obs, reward, done, _ = env.step(action)
        terminal = np.asarray(obs, np.float32)
        if done:
            obs = env.reset()
        # per-lane step time -> straggler EWMA (tracker.record is a dict
        # write per key; lanes never share a key, so no lock needed)
        self.tracker.record(idx, self._clock() - t0)
        return (np.asarray(obs, np.float32), np.float32(reward), bool(done),
                terminal)

    def stragglers(self):
        """StragglerReports for lanes persistently above the median step
        time (advice: "profile", then "demote" after `patience` strikes)."""
        return self.tracker.reports()

    # -- random-policy harness (PythonRunner parity) ----------------------------
    def run_random(self, num_steps: int, seed: int = 0, render: bool = False):
        """Per-env random rollout, one worker each; == PythonRunner.run per env.

        Returns (total_reward (B,), episodes (B,)). Env i uses seed+i, so
        a 1-env pool reproduces `PythonRunner(factory).run(n, seed=seed)`
        exactly.
        """
        futs = [self._exec.submit(self._run_one, env, num_steps, seed + i, render)
                for i, env in enumerate(self._envs)]
        totals, episodes = zip(*(f.result() for f in futs))
        return np.asarray(totals, np.float32), np.asarray(episodes, np.int32)

    @staticmethod
    def _run_one(env, num_steps: int, seed: int, render: bool):
        env.seed(seed)
        env.reset()
        total, episodes = 0.0, 0
        for _ in range(num_steps):
            _, r, done, _ = env.step(env.action_space_sample())
            if render:
                env.render()
            total += r
            if done:
                episodes += 1
                env.reset()
        return total, episodes

    def close(self) -> None:
        self._exec.shutdown(wait=False)
