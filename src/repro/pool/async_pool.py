"""AsyncEnvPool — EnvPool's async mode over the fused megastep engine.

Lock-step pools step all B lanes together, so one slow consumer stalls the
whole batch. AsyncEnvPool is the async mode of the EnvPool paper: clients
`send(actions, ids)` for the lanes that are ready and `recv()` advances
exactly those lanes. Internally the batch is a fixed table of *slots*
(lanes), an `active` mask gates which slot rows move — the masked-active
continuous-batching pattern serving/engine.py uses for decode slots — and
departed sessions are recycled by splicing a freshly reset session's state
into the freed slot rows. The whole lifecycle (masked step, slot splice,
bulk reset) runs on the donated XLA-resident state pytree, so the
zero-host-transfer property of the lock-step pool is preserved
(benchmarks/fig_async.py certifies the compiled core's HLO).

Sessions and determinism
------------------------
Each slot hosts one *session*: an independent AutoReset episode stream with
its own key chain. `admit(seed=s)` seeds a lane exactly the way a 1-env
lock-step `EnvPool.reset(seed=s)` seeds its only lane, and the masked step
splits its step key across slots exactly the way `Vec.step` does. Two
consequences, both load-bearing for the test suite:

  - every fused env's dynamics are action-deterministic (randomness enters
    only through the in-state AutoReset key chain), so a session's
    trajectory is **bit-identical to the same seed run alone through the
    lock-step pool**, no matter how other slots are scheduled or recycled
    (tests/test_async_pool.py replays scripted traffic against that oracle);
  - with every lane active, the lock-step facade (`reset(seed)` /
    `step(actions)`) reproduces `EnvPool(..., backend="vmap")` exactly —
    including key-dependent envs — which is what lets the async backend ride
    the committed golden traces (tests/test_golden.py) and the conformance
    matrix unchanged.

Threading: `send` / `recv` are safe to call from many client threads;
`recv(max_wait=, min_ready=)` blocks until at least `min_ready` lanes have
actions staged (or the wait times out, stepping whatever is ready).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env, supports_fused_step
from repro.core.registry import make as registry_make
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset


class AsyncUnsupportedError(TypeError):
    """Raised when an env cannot be hosted by the async pool.

    Named (rather than a bare TypeError) so the registry-completeness sweep
    can assert every id either builds or fails *loudly* with this error —
    silent fallbacks would make `backend="async"` coverage unfalsifiable.
    """


class AsyncEnvPool:
    """Session-per-slot async pool: `send(actions, ids)` / `recv() -> ids`.

    >>> pool = AsyncEnvPool("CartPole-v1", num_slots=64)
    >>> sid, obs = pool.admit(seed=7)            # splice a fresh session in
    >>> pool.send(actions, ids=[sid])
    >>> obs, rew, done, info, ids = pool.recv()  # only ready lanes stepped
    >>> pool.release(sid)                        # free the slot for refill

    Ids are slot indices (0..num_slots-1); the session-to-slot mapping for
    *named* clients lives one level up in serving/env_service.EnvService.

    backend: "auto" resolves to the fused megastep engine when the env
    supports it ("pallas": Pallas on TPU, jnp rows elsewhere) and the
    masked vmap step otherwise; "vmap"/"pallas"/"pallas_interpret"/"jnp"
    pin one (same names as EnvPool).
    """

    def __init__(self, env: Union[Env, str], num_slots: int,
                 backend: str = "auto", **env_kwargs):
        if isinstance(env, str):
            env = registry_make(env, **env_kwargs)
        elif env_kwargs:
            raise ValueError(f"env_kwargs {sorted(env_kwargs)} only apply "
                             "when building from a registry id")
        if not (hasattr(env, "reset") and hasattr(env, "observation_space")):
            raise AsyncUnsupportedError(
                f"async pool needs a functional Env (reset/step/spaces); "
                f"got {type(env).__name__}")
        self.env = env
        self.num_slots = int(num_slots)
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if backend == "auto":
            backend = "pallas" if supports_fused_step(env) else "vmap"
        from repro.pool.envpool import FUSED_BACKENDS  # avoid import cycle

        if backend in FUSED_BACKENDS:
            if not supports_fused_step(env):
                raise AsyncUnsupportedError(
                    f"backend={backend!r} needs fused megastep support, but "
                    f"{env.name} has none; use backend='vmap'")
            self._kernel_backend = "auto" if backend == "pallas" else backend
        elif backend == "vmap":
            self._kernel_backend = None
        else:
            raise ValueError(f"unknown async step backend {backend!r}")
        self.backend = backend
        self.aenv = AutoReset(env)

        self._cond = threading.Condition()
        self._carry = None                       # (state pytree, obs), donated
        self._active = np.zeros(self.num_slots, bool)
        self._pending: Dict[int, np.ndarray] = {}  # slot -> staged action
        self._key = None                         # facade step-key chain
        self._recv_key = jax.random.PRNGKey(0x5C0)  # fallback recv key chain

        self._jit_init = jax.jit(self._init_impl)
        self._jit_admit = jax.jit(self._admit_impl, donate_argnums=(0,))
        self._jit_step = jax.jit(self._step_impl, donate_argnums=(0,))
        self._jit_restore_lane = jax.jit(self._restore_lane_impl,
                                         donate_argnums=(0,))

    # -- spaces / metadata ---------------------------------------------------
    @property
    def observation_space(self):
        return self.env.observation_space

    @property
    def action_space(self):
        return self.env.action_space

    @property
    def num_envs(self) -> int:  # pool-protocol alias
        return self.num_slots

    def __len__(self) -> int:
        return self.num_slots

    def __repr__(self) -> str:  # pragma: no cover
        return (f"AsyncEnvPool({self.env.name}, num_slots={self.num_slots}, "
                f"active={int(self._active.sum())})")

    @property
    def active(self) -> np.ndarray:
        """(num_slots,) bool — which lanes host a running session."""
        return self._active.copy()

    def free_slots(self) -> List[int]:
        return [i for i in range(self.num_slots) if not self._active[i]]

    # -- device programs -----------------------------------------------------
    def _init_impl(self, key):
        """Bulk reset, bit-identical to `EnvPool._xla_init`'s venv.reset."""
        keys = jax.random.split(key, self.num_slots)
        state, obs = jax.vmap(self.aenv.reset)(keys)
        return state, obs

    def _admit_impl(self, carry, lane_key, slot):
        """Splice one freshly reset session into `slot`'s rows (the
        prefill-into-slot move of serving/engine.py, for env lanes)."""
        state, obs = carry
        fresh_state, fresh_obs = self.aenv.reset(lane_key)
        state = jax.tree.map(lambda full, one: full.at[slot].set(one),
                             state, fresh_state)
        obs = obs.at[slot].set(fresh_obs)
        return (state, obs), fresh_obs

    def _step_impl(self, carry, actions, active, key):
        """One masked step: only `active` lanes advance; the rest keep their
        state (and AutoReset key chain) and report zero outputs."""
        state, obs = carry
        if self._kernel_backend is not None:
            new_state, ts = self.env.fused_step(
                state, actions[None], num_steps=1,
                backend=self._kernel_backend, active=active)
            first = lambda x: x[0]
            out = (ts.obs[0], ts.reward[0], ts.done[0],
                   jax.tree.map(first, ts.info))
            new_obs = jnp.where(
                active.reshape(active.shape + (1,) * (ts.obs[0].ndim - 1)),
                ts.obs[0], obs)
            return (new_state, new_obs), out

        keys = jax.random.split(key, self.num_slots)  # exactly Vec.step
        ts = jax.vmap(self.aenv.step)(state, actions, keys)

        def lane(n, o):
            m = active.reshape(active.shape + (1,) * (n.ndim - 1))
            return jnp.where(m, n, o)

        new_state = jax.tree.map(lane, ts.state, state)
        new_obs = lane(ts.obs, obs)
        reward = jnp.where(active, ts.reward, jnp.zeros_like(ts.reward))
        done = jnp.where(active, ts.done, jnp.zeros_like(ts.done))
        info = jax.tree.map(lambda v: lane(v, jnp.zeros_like(v)), ts.info)
        return (new_state, new_obs), (lane(ts.obs, jnp.zeros_like(ts.obs)),
                                      reward, done, info)

    def _restore_lane_impl(self, carry, lane, slot):
        """Splice a SAVED lane (state rows + obs) into `slot` — the resume
        half of client eviction: the episode continues exactly where the
        evicted client left it, AutoReset key chain included."""
        state, obs = carry
        state = jax.tree.map(lambda full, one: full.at[slot].set(one),
                             state, lane["state"])
        obs = obs.at[slot].set(lane["obs"])
        return (state, obs), lane["obs"]

    def step_lowered(self):
        """Lower (don't run) the masked-step core — for HLO inspection:
        fig_async certifies it contains zero host-transfer instructions."""
        acts = jnp.zeros((self.num_slots,) + tuple(self.action_space.shape),
                         self.action_space.dtype)
        with self._cond:
            self._ensure_carry()
            carry = self._carry
        return jax.jit(self._step_impl).lower(
            carry, acts, jnp.zeros(self.num_slots, bool),
            jax.random.PRNGKey(0))

    # -- slot lifecycle ------------------------------------------------------
    def _ensure_carry(self):
        if self._carry is None:
            # repro: allow[unguarded-mutation] every caller already holds self._cond (admit/reset/send paths)
            self._carry = self._jit_init(jax.random.PRNGKey(0))

    def admit(self, seed: Optional[int] = None, key=None,
              slot: Optional[int] = None) -> Tuple[int, jax.Array]:
        """Start a session in a free slot; returns `(slot_id, first_obs)`.

        `seed=s` derives the lane key exactly as `EnvPool(env, 1).reset(s)`
        derives its only lane's (so the session is bit-comparable to a solo
        lock-step run); `key=` passes an explicit AutoReset reset key (the
        golden-trace tests use this to mirror `Vec.reset`'s split).
        """
        if (seed is None) == (key is None):
            raise ValueError("admit() takes exactly one of seed= or key=")
        if key is None:
            key = jax.random.split(jax.random.PRNGKey(seed), 1)[0]
        with self._cond:
            self._ensure_carry()
            if slot is None:
                free = self.free_slots()
                if not free:
                    raise RuntimeError("no free slot; release() a session "
                                       "first (or queue in EnvService)")
                slot = free[0]
            elif self._active[slot]:
                raise ValueError(f"slot {slot} already hosts a session")
            self._carry, obs = self._jit_admit(self._carry, key,
                                               jnp.asarray(slot, jnp.int32))
            self._active[slot] = True
            return slot, obs

    def release(self, sid: int) -> None:
        """End a session: free its slot for refill (state rows stay until the
        next admit splices over them; the mask keeps them inert)."""
        with self._cond:
            if not self._active[sid]:
                raise ValueError(f"slot {sid} has no running session")
            self._active[sid] = False
            self._pending.pop(sid, None)

    def lane_state(self, sid: int) -> Dict[str, Any]:
        """Host-materialized copy of one running lane's rows (state + obs).

        The eviction half of graceful degradation (serving/env_service.py):
        a dead client's episode is checkpointed off its slot so the slot can
        refill, and `admit_lane()` later resumes the episode bit-exactly."""
        with self._cond:
            if not self._active[sid]:
                raise ValueError(f"slot {sid} has no running session")
            state, obs = self._carry
            lane = {"state": jax.tree.map(lambda x: x[sid], state),
                    "obs": obs[sid]}
            return jax.tree.map(
                lambda x: np.array(jax.device_get(x), copy=True), lane)

    def admit_lane(self, lane: Dict[str, Any],
                   slot: Optional[int] = None) -> Tuple[int, jax.Array]:
        """Resume a `lane_state()` snapshot in a free slot: `(slot, obs)`."""
        with self._cond:
            self._ensure_carry()
            if slot is None:
                free = self.free_slots()
                if not free:
                    raise RuntimeError("no free slot; release() a session "
                                       "first (or queue in EnvService)")
                slot = free[0]
            elif self._active[slot]:
                raise ValueError(f"slot {slot} already hosts a session")
            lane = jax.tree.map(jnp.asarray, lane)
            self._carry, obs = self._jit_restore_lane(
                self._carry, lane, jnp.asarray(slot, jnp.int32))
            self._active[slot] = True
            return slot, obs

    # -- snapshot / restore ----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Host snapshot of the whole slot table's carry: per-lane env state
        (AutoReset key chains included), obs, the active mask and both
        host-side key chains. Lanes with actions in flight must `recv()`
        first — a snapshot is a step boundary, not a mid-step fence."""
        with self._cond:
            self._ensure_carry()
            if self._pending:
                raise RuntimeError(
                    "snapshot with actions in flight; recv() first so the "
                    "snapshot lands on a step boundary")
            state, obs = self._carry
            has_key = self._key is not None
            tree = {
                "state": state,
                "obs": obs,
                "active": self._active.copy(),
                "recv_key": self._recv_key,
                "facade_key": (self._key if has_key
                               else jax.random.PRNGKey(0)),
                "has_facade_key": np.asarray(has_key),
            }
            return jax.tree.map(
                lambda x: np.array(jax.device_get(x), copy=True), tree)

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        """Restore a `state_dict()` snapshot (possibly into a fresh pool —
        the service-restart path of serving/env_service.py)."""
        with self._cond:
            active = np.asarray(d["active"], bool)
            if active.shape != (self.num_slots,):
                raise ValueError(
                    f"snapshot has {active.shape[0]} slots; this pool has "
                    f"{self.num_slots}")
            self._pending.clear()
            self._carry = (jax.tree.map(jnp.asarray, d["state"]),
                           jnp.asarray(d["obs"]))
            self._active = active.copy()
            self._recv_key = jnp.asarray(d["recv_key"])
            self._key = (jnp.asarray(d["facade_key"])
                         if bool(np.asarray(d["has_facade_key"])) else None)

    # -- async API -----------------------------------------------------------
    def send(self, actions, ids) -> None:
        """Stage actions for lanes `ids` (one in-flight action per lane)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        actions = np.asarray(actions)
        if actions.shape[0] != ids.shape[0]:
            raise ValueError(f"actions batch {actions.shape[0]} != "
                             f"{ids.shape[0]} ids")
        with self._cond:
            for i, sid in enumerate(ids):
                sid = int(sid)
                if not self._active[sid]:
                    raise ValueError(f"send to slot {sid}: no running session")
                if sid in self._pending:
                    raise ValueError(f"send to slot {sid}: action already "
                                     "in flight; recv() first")
                self._pending[sid] = actions[i]
            self._cond.notify_all()

    def recv(self, max_wait: Optional[float] = None, min_ready: int = 1,
             key=None):
        """Step every lane with a staged action: `(obs, rewards, dones,
        infos, ids)`, each with leading dim len(ids) (slot-ascending).

        `max_wait` (seconds) blocks until `min_ready` lanes are staged —
        sends may come from other client threads; on timeout whatever is
        ready is stepped. `max_wait=None` steps immediately and raises
        RuntimeError if nothing is in flight (no deadlock in single-thread
        use). `key` pins the per-step RNG stream (split across slots like
        `Vec.step`; env dynamics that ignore keys are unaffected).
        """
        with self._cond:
            if max_wait is not None:
                self._cond.wait_for(
                    lambda: len(self._pending) >= min_ready, timeout=max_wait)
            if not self._pending:
                raise RuntimeError("recv() with no actions in flight")
            ids = np.array(sorted(self._pending), np.int64)
            acts = np.zeros((self.num_slots,)
                            + tuple(self.action_space.shape),
                            self.action_space.dtype)
            for sid in ids:
                acts[sid] = self._pending.pop(int(sid))
            mask = np.zeros(self.num_slots, bool)
            mask[ids] = True
            if key is None:
                self._recv_key, key = jax.random.split(self._recv_key)
            self._carry, (obs, rew, done, info) = self._jit_step(
                self._carry, jnp.asarray(acts), jnp.asarray(mask), key)
            # Row selection happens host-side on the (tiny) fetched outputs:
            # a device gather would re-specialize per distinct len(ids) —
            # a fresh XLA compile every time the ready-set size changes.
            return (np.asarray(obs)[ids], np.asarray(rew)[ids],
                    np.asarray(done)[ids],
                    jax.tree.map(lambda v: np.asarray(v)[ids], info), ids)

    # -- lock-step facade ----------------------------------------------------
    # With every slot active this is EnvPool(backend="vmap") bit-for-bit
    # (same venv.reset split, same carry-key chain, same per-step splits), so
    # the conformance matrix and golden traces drive the async engine through
    # the ordinary pool protocol.
    def reset(self, seed: int = 0) -> jax.Array:
        with self._cond:
            self._pending.clear()
            self._carry = self._jit_init(jax.random.PRNGKey(seed))
            self._active[:] = True
            self._key = jax.random.fold_in(jax.random.PRNGKey(seed), 0x57EB)
            # copy: the carry (incl. this obs buffer) is donated to the next
            # step — returning the alias would hand the caller a buffer that
            # dies on their first send/recv
            return jnp.copy(self._carry[1])

    def step(self, actions) -> Tuple[jax.Array, jax.Array, jax.Array, Dict]:
        with self._cond:  # facade key chain is shared state like _pending
            if self._key is None:
                raise RuntimeError("call reset() before step()")
            if not self._active.all():
                raise RuntimeError("lock-step facade needs every slot "
                                   "active; use send/recv with a partial "
                                   "session set")
            self._key, step_key = tuple(jax.random.split(self._key))
        self.send(actions, np.arange(self.num_slots))
        obs, rew, done, info, _ = self.recv(key=step_key)
        return obs, rew, done, info

    def sample_actions(self, seed: int = 0) -> jax.Array:
        return sample_batch(self.action_space, jax.random.PRNGKey(seed),
                            self.num_slots)
