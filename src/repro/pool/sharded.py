"""ShardedEnvPool — the env batch sharded across a device mesh.

Jumanji-style scaling: the batch axis of the pool is laid out over the
mesh's data-parallel axes ("pod", "data" — repro.sharding.rules.data_axes)
with `shard_map`, so each device steps `num_envs / n_shards` envs and no
cross-device communication happens inside the step (env steps are
embarrassingly parallel; collectives only appear if the consumer reduces
across the batch). The API is identical to EnvPool — stateful Gym-style,
`xla()`, and `rollout` all work unchanged, which is what makes the
sharded pool a drop-in in benchmarks/fig4_pool_scaling.py.

RNG: every shard folds the (replicated) step key with its linear shard
index so env streams differ across shards. On a 1-device mesh the fold is
skipped, making ShardedEnvPool bit-identical to EnvPool (the parity
contract tests/test_pool.py pins).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.env import Env
from repro.core.wrappers import AutoReset, Vec
from repro.pool.envpool import EnvPool, PoolState, PoolStep
from repro.sharding.rules import data_axes


def default_pool_mesh(num_devices: Optional[int] = None) -> Mesh:
    """A 1-axis ("data",) mesh over (the first `num_devices`) local devices."""
    devices = jax.devices()
    if num_devices is not None:
        devices = devices[:num_devices]
    return jax.make_mesh((len(devices),), ("data",), devices=devices)


class ShardedEnvPool(EnvPool):
    """EnvPool with the batch dim sharded over the mesh's data axes."""

    def __init__(self, env: Union[Env, str], num_envs: int,
                 mesh: Optional[Mesh] = None, backend: str = "vmap",
                 unroll: int = 1, **env_kwargs):
        self.mesh = mesh if mesh is not None else default_pool_mesh()
        self.axes: Tuple[str, ...] = (data_axes(self.mesh)
                                      or (self.mesh.axis_names[0],))
        self.n_shards = int(np.prod([self.mesh.shape[a] for a in self.axes]))
        if num_envs % self.n_shards:
            raise ValueError(
                f"num_envs={num_envs} must divide evenly over the "
                f"{self.n_shards}-way data axes {self.axes} of the mesh")
        super().__init__(env, num_envs, backend=backend, unroll=unroll,
                         **env_kwargs)
        self._local = Vec(AutoReset(self.env), self.num_envs // self.n_shards)
        self._bspec = P(self.axes)        # batch dim over the data axes
        self._cspec = P(None, self.axes)  # (K, B, ...) step-chunk arrays

    def _put_carry(self, d):
        """Re-place a (gathered, host) carry snapshot onto THIS pool's mesh:
        batch-leading leaves shard over the data axes, the carry key
        replicates. Snapshots are mesh-agnostic (checkpoint/manager.py), so
        this is the rebuild-shardings leg of the elastic restore path — a
        snapshot taken on a bigger mesh restores here unchanged."""
        batch_sh = NamedSharding(self.mesh, self._bspec)
        repl_sh = NamedSharding(self.mesh, P())
        return {
            "env_state": jax.tree.map(
                lambda x: jax.device_put(np.asarray(x), batch_sh),
                d["env_state"]),
            "obs": jax.device_put(np.asarray(d["obs"]), batch_sh),
            "key": jax.device_put(np.asarray(d["key"]), repl_sh),
        }

    def _shard_key(self, key: jax.Array) -> jax.Array:
        """Per-shard RNG stream; identity on a 1-device mesh (exact parity)."""
        if self.n_shards == 1:
            return key
        idx = jnp.asarray(0, jnp.int32)
        for a in self.axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return jax.random.fold_in(key, idx)

    # -- XLA-resident pure API, shard_mapped ----------------------------------
    def _xla_init(self, key: jax.Array) -> PoolState:
        def local_reset(k):
            return self._local.reset(self._shard_key(k))

        state, obs = shard_map(
            local_reset, mesh=self.mesh, in_specs=P(),
            out_specs=(self._bspec, self._bspec), check_rep=False,
        )(key)
        return PoolState(state, obs, jax.random.fold_in(key, 0x57EB))

    def _step_many_core(self, env_state, actions, key, venv=None):
        """The K-step block, shard_mapped: each shard runs the fused megastep
        kernel (or the scanned vmap step) on its `num_envs / n_shards` slice
        of the batch — one kernel launch per shard per chunk, still with no
        collectives in the body."""
        def local_many(state, a, k):
            state, (obs, rew, done, info) = EnvPool._step_many_core(
                self, state, a, self._shard_key(k), venv=self._local)
            return state, obs, rew, done, info

        state, obs, rew, done, info = shard_map(
            local_many, mesh=self.mesh,
            in_specs=(self._bspec, self._cspec, P()),
            out_specs=(self._bspec, self._cspec, self._cspec, self._cspec,
                       self._cspec),
            check_rep=False,
        )(env_state, actions, key)
        return state, (obs, rew, done, info)

    def _xla_step(self, carry: PoolState, actions: jax.Array,
                  key: Optional[jax.Array] = None) -> Tuple[PoolState, PoolStep]:
        if self._fused:  # route through the shard_mapped megastep block
            return EnvPool._xla_step(self, carry, actions, key)
        if key is None:
            next_key, key = jax.random.split(carry.key)
        else:
            next_key = carry.key

        def local_step(state, a, k):
            ts = self._local.step(state, a, self._shard_key(k))
            return ts.state, ts.obs, ts.reward, ts.done, ts.info

        state, obs, reward, done, info = shard_map(
            local_step, mesh=self.mesh,
            in_specs=(self._bspec, self._bspec, P()),
            out_specs=(self._bspec, self._bspec, self._bspec, self._bspec,
                       self._bspec),
            check_rep=False,
        )(carry.env_state, actions, key)
        return (PoolState(state, obs, next_key),
                PoolStep(obs, reward, done, info))
