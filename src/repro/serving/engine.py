"""Batched serving engine: prefill + decode with continuous slot refill.

The decode step is one compiled program over a fixed-size slot batch
(padding-free steady state); finished sequences free their slot and the
host-side scheduler refills it by prefilling the next queued request into
the same cache rows. This is the standard continuous-batching shape
(vLLM-style, simplified to fixed slots) expressed in pure JAX:
  - `prefill_into_slot` writes one request's cache rows at its slot index;
  - `decode_step` advances every active slot by one token;
  - inactive slots are masked by `active` so they cost no host logic.

Slot bookkeeping (ownership, FIFO admission, queue-wait/residency
accounting) is the shared `serving/slots.SlotTable` — the same table the
env service (serving/env_service.py) schedules env sessions with, so the
refill-latency accounting that used to exist only there now covers this
engine too (`ServeEngine.stats()`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving.slots import SlotTable


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: Optional[list] = None


class EngineState(NamedTuple):
    caches: Any
    tokens: jax.Array      # (slots, 1) last token per slot
    pos: jax.Array         # (slots,) next absolute position per slot
    active: jax.Array      # (slots,) bool


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 8, max_seq: int = 2048,
                 temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.temperature = temperature
        self._requests: Dict[int, Request] = {}
        caches = lm.init_cache(cfg, slots, max_seq)
        self.state = EngineState(
            caches=caches,
            tokens=jnp.zeros((slots, 1), jnp.int32),
            pos=jnp.zeros((slots,), jnp.int32),
            active=jnp.zeros((slots,), bool),
        )
        self.slots_table = SlotTable(slots)
        self._decode = jax.jit(self._decode_impl)

    # -- device programs -------------------------------------------------
    def _decode_impl(self, params, state: EngineState):
        # one compiled step advances every slot; positions are PER-SLOT (the
        # attention cache paths accept vector cache_pos), so heterogeneous
        # requests share one program — continuous batching with fixed shapes.
        logits, caches = lm.decode_step(self.cfg, params, state.caches,
                                        state.tokens, state.pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tokens = jnp.where(state.active, next_tok, state.tokens[:, 0])[:, None]
        pos = jnp.where(state.active, state.pos + 1, state.pos)
        return EngineState(caches, tokens, pos, state.active), next_tok

    # -- host scheduler ----------------------------------------------------
    def submit(self, req: Request) -> None:
        req.output = []
        self._requests[req.rid] = req
        self.slots_table.submit(req.rid)

    def _free_slots(self) -> List[int]:
        return self.slots_table.free_slots()

    def _admit(self) -> None:
        for slot, rid in self.slots_table.admit():
            req = self._requests[rid]
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            # prefill this request alone (batch 1) then splice its cache rows
            logits, cache1 = lm.prefill(self.cfg, self.params,
                                        {"tokens": prompt}, self.max_seq)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            def splice(full, one):
                return full.at[:, slot:slot + 1].set(one) if full.ndim >= 2 else full

            caches = jax.tree.map(splice, self.state.caches, cache1)
            self.state = EngineState(
                caches=caches,
                tokens=self.state.tokens.at[slot, 0].set(tok[0]),
                pos=self.state.pos.at[slot].set(prompt.shape[1]),
                active=self.state.active.at[slot].set(True),
            )
            req.output.append(int(tok[0]))

    def step(self) -> None:
        """One scheduler tick: admit, decode, retire."""
        self._admit()
        self.state, next_tok = self._decode(self.params, self.state)
        toks = np.asarray(next_tok)
        for rid in self.slots_table.running():
            slot = self.slots_table.slot_of(rid)
            req = self._requests[rid]
            req.output.append(int(toks[slot]))
            done = len(req.output) >= req.max_new_tokens or (
                req.eos_id is not None and toks[slot] == req.eos_id
            ) or int(self.state.pos[slot]) >= self.max_seq - 1
            if done:
                self.slots_table.release(rid)
                del self._requests[rid]
                self.state = self.state._replace(
                    active=self.state.active.at[slot].set(False))

    def run(self, max_ticks: int = 1000) -> None:
        ticks = 0
        while (self.slots_table.queued_count
               or self.slots_table.active_count) and ticks < max_ticks:
            self.step()
            ticks += 1

    def stats(self) -> Dict[str, float]:
        """Queue-wait / residency / occupancy accounting (SlotTable) — the
        refill-latency numbers that previously existed only for env serving."""
        return self.slots_table.stats()
