"""serving subsystem."""
