"""serving subsystem: LM decode serving (serving/engine.py) and env session
serving (serving/env_service.py) over the shared continuous-batching slot
table (serving/slots.py)."""
from repro.serving.env_service import EnvService, Session
from repro.serving.slots import SlotTable, percentile

__all__ = ["EnvService", "Session", "SlotTable", "percentile"]
