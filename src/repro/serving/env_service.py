"""EnvService — session-multiplexing env serving over AsyncEnvPool.

The serving analogue of ServeEngine (serving/engine.py), with env sessions
in place of decode requests: many independent client sessions — each its
own AutoReset episode stream with its own key chain and step budget — are
multiplexed onto ONE fused batch. The host scheduler is the same
continuous-batching loop:

  submit -> FIFO admission queue (serving/slots.SlotTable, shared with
  ServeEngine) -> a free slot's rows are *reset-spliced* with the session's
  seed (the prefill-into-slot move) -> every tick steps the active lanes
  through the pool's masked step -> budget-exhausted sessions retire and
  free their slot for the next queued session.

Graceful degradation (tests/test_supervisor.py)
-----------------------------------------------
A client that stops answering must not hold a device lane hostage. Per
tick, each session's action round-trip is measured against
`action_timeout_s` (a FaultInjector "stall" fault forces the same path);
a timed-out session backs off its lane for `2**(retries-1)` ticks —
the masked step simply doesn't move that slot — and after `max_retries`
consecutive timeouts it is EVICTED: its lane rows (env state, AutoReset
key chain, obs) are checkpointed off the device (`pool.lane_state`), the
slot refills from the queue, and a later `reconnect(sid)` re-queues the
session so `admit_lane` resumes the episode exactly where it stopped.

Service restart: `drain_to_checkpoint(manager)` persists the whole slot
table's carry, every parked (evicted) lane, and the host bookkeeping
(session progress, queue order, slot seating, default-policy RNG states)
through CheckpointManager; `EnvService.restore_service(...)` rebuilds a
fresh service from that checkpoint with every in-flight session resumed
in its original slot — policies are code, so the caller re-supplies the
Session objects and the checkpoint restores their progress.

Telemetry: per-tick recv latency (p50/p99 via `stats()` — the fig_async
numbers), per-session queue wait and residency (SlotTable), and a
runtime/straggler.StragglerTracker over client action-latency so
persistently slow consumers — the exact workload async mode exists to
isolate — are flagged with the profile/demote advice instead of silently
dragging the batch.

The clock is injectable: the traffic-replay tests drive a scripted clock
so latency accounting, timeouts and injected stalls are deterministic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np

from repro.core.env import Env
from repro.core.spaces import Box, Discrete, MultiDiscrete
from repro.pool.async_pool import AsyncEnvPool
from repro.runtime.failures import FaultInjector
from repro.runtime.straggler import StragglerTracker
from repro.serving.slots import SlotTable, percentile


def _np_sample(space, rng: np.random.Generator):
    """Cheap host-side action sampling (the synthetic-client default policy).

    Sessions number in the thousands; per-session jax dispatches for action
    sampling would bench the host RNG, not the pool, so the default client
    uses numpy. Deterministic tests pass explicit `policy=` scripts instead.
    """
    if isinstance(space, Discrete):
        return np.asarray(rng.integers(space.n), np.dtype(space.dtype))
    if isinstance(space, MultiDiscrete):
        return rng.integers(np.zeros_like(np.asarray(space.nvec)),
                            np.asarray(space.nvec)).astype(space.dtype)
    if isinstance(space, Box):
        lo = np.nan_to_num(np.asarray(space.low, np.float64), neginf=-1.0)
        hi = np.nan_to_num(np.asarray(space.high, np.float64), posinf=1.0)
        return rng.uniform(lo, hi, size=space.shape).astype(space.dtype)
    raise TypeError(f"no default sampler for space {type(space).__name__}")


@dataclasses.dataclass
class Session:
    """One client: seed, step budget, and an optional action policy.

    `policy(obs, t) -> action` is called once per tick while running; None
    means sample uniformly from the action space with a per-session numpy
    generator. Results accumulate in place (the Request.output idiom of
    serving/engine.py).
    """

    sid: int
    seed: int
    num_steps: int
    policy: Optional[Callable] = None
    # -- filled by the service --------------------------------------------
    steps: int = 0
    total_reward: float = 0.0
    episodes: int = 0
    retries: int = 0        # consecutive action timeouts (0 after a success)
    evictions: int = 0
    first_obs: Optional[np.ndarray] = None
    _rng: Optional[np.random.Generator] = None
    _last_obs: Optional[np.ndarray] = None
    _backoff: int = 0       # ticks this lane still idles before a retry

    def action(self, space):
        if self.policy is not None:
            return self.policy(self._last_obs, self.steps)
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return _np_sample(space, self._rng)


class EnvService:
    """Continuous-batching env server: many sessions, one fused batch.

    >>> svc = EnvService("CartPole-v1", num_slots=64)
    >>> for i in range(1000):
    ...     svc.submit(Session(sid=i, seed=i, num_steps=100))
    >>> svc.run()            # admit / step / retire until all served
    >>> svc.stats()["recv_p99_s"]
    """

    def __init__(self, env: Union[Env, str], num_slots: int, *,
                 backend: str = "auto", tracker: Optional[StragglerTracker] = None,
                 clock: Optional[Callable[[], float]] = None,
                 action_timeout_s: Optional[float] = None,
                 max_retries: int = 3,
                 injector: Optional[FaultInjector] = None):
        self.pool = AsyncEnvPool(env, num_slots, backend=backend)
        self.num_slots = num_slots
        self._clock = clock or time.monotonic
        self.slots = SlotTable(num_slots, clock=self._clock)
        self.tracker = tracker or StragglerTracker()
        self.action_timeout_s = action_timeout_s
        self.max_retries = max_retries
        self.injector = injector
        self._sessions: Dict[int, Session] = {}
        #: sid -> saved lane rows: evicted sessions parked off-device awaiting
        #: reconnect(), plus restored/reconnected sessions queued for a slot —
        #: _admit() resumes these via pool.admit_lane instead of a fresh reset
        self._lanes: Dict[int, Dict[str, Any]] = {}
        self._evicted: set = set()   # parked AND not re-queued yet
        #: sid -> count of fired "stall" faults awaiting a collection attempt
        self._stalled: Dict[int, int] = {}
        self._draining = False
        self.recv_latencies: List[float] = []
        self.ticks = 0
        self.steps_served = 0
        self.timeouts = 0
        self.evictions = 0
        self.eviction_log: Dict[int, str] = {}
        # latest StragglerReport per flagged sid; sessions retire (and the
        # tracker forgets them) before stats() is usually read, so the policy
        # is evaluated every tick and flagged sessions logged here
        self.straggler_log: Dict[int, object] = {}

    # -- client API ----------------------------------------------------------
    def submit(self, session: Session) -> None:
        if session.sid in self._sessions:
            raise ValueError(f"session {session.sid} already submitted")
        if session.num_steps < 1:
            raise ValueError("num_steps budget must be >= 1")
        if self._draining:
            raise RuntimeError("service is draining; not accepting sessions")
        self._sessions[session.sid] = session
        self.slots.submit(session.sid)

    def reconnect(self, sid: int, policy: Optional[Callable] = None) -> None:
        """Re-queue an evicted session; its saved lane resumes the episode.

        The client came back: clear the timeout record (and optionally swap
        the policy), put the sid back in the admission queue. On admission
        the parked lane is spliced into a free slot, so the episode continues
        from the exact step the eviction interrupted.
        """
        if sid not in self._evicted:
            raise ValueError(f"session {sid} is not evicted")
        if self._draining:
            raise RuntimeError("service is draining; not accepting sessions")
        sess = self._sessions[sid]
        sess.retries = 0
        sess._backoff = 0
        if policy is not None:
            sess.policy = policy
        self._evicted.discard(sid)
        self.slots.submit(sid)

    @property
    def queued(self) -> int:
        return self.slots.queued_count

    @property
    def running(self) -> int:
        return self.slots.active_count

    @property
    def evicted(self) -> List[int]:
        """Sids parked off-device awaiting `reconnect()`."""
        return sorted(self._evicted)

    # -- scheduler loop -------------------------------------------------------
    def _admit(self) -> None:
        for slot, sid in self.slots.admit():
            sess = self._sessions[sid]
            if sid in self._lanes:  # resume a parked lane, not a fresh reset
                _, obs = self.pool.admit_lane(self._lanes.pop(sid), slot=slot)
            else:
                _, obs = self.pool.admit(seed=sess.seed, slot=slot)
            if sess.first_obs is None:
                sess.first_obs = np.asarray(obs)
            sess._last_obs = np.asarray(obs)

    def _due_stalls(self) -> Dict[int, int]:
        """Sids whose "stall" fault fired: their NEXT collection attempts
        time out, one per fault. Buffered (counted) rather than tick-scoped
        — faults that fire while a lane is backing off still hit the
        following real attempts, like a client that stays dead."""
        if self.injector is not None:
            for f in self.injector.due(kinds=("stall",)):
                self._stalled[f.arg] = self._stalled.get(f.arg, 0) + 1
        return self._stalled

    def _on_timeout(self, sid: int) -> None:
        """One missed action: back the lane off exponentially; evict after
        `max_retries` consecutive misses."""
        sess = self._sessions[sid]
        sess.retries += 1
        self.timeouts += 1
        if sess.retries > self.max_retries:
            self._evict(sid, f"{sess.retries} consecutive action timeouts")
        else:
            sess._backoff = 2 ** (sess.retries - 1)

    def _evict(self, sid: int, reason: str) -> None:
        """Park a dead client's episode off its slot so the slot can refill."""
        slot = self.slots.slot_of(sid)
        self._lanes[sid] = self.pool.lane_state(slot)
        self.pool.release(slot)
        self.slots.release(sid)
        self.tracker.forget(sid)
        self._stalled.pop(sid, None)
        self._evicted.add(sid)
        sess = self._sessions[sid]
        sess.evictions += 1
        sess._backoff = 0
        self.evictions += 1
        self.eviction_log[sid] = reason

    def tick(self) -> bool:
        """One scheduler tick: admit, collect actions, masked step, retire.

        Returns False when there is nothing to do (drained/idle).
        """
        if not self._draining:
            self._admit()
        running = self.slots.running()
        if not running:
            return False
        self.ticks += 1
        stalled = self._due_stalls()

        acts, slot_ids = [], []
        for sid in running:
            sess = self._sessions[sid]
            if sess._backoff > 0:     # lane idles; masked step skips it
                sess._backoff -= 1
                continue
            if stalled.get(sid):      # injected dead client: no action comes
                self._stalled[sid] -= 1
                if not self._stalled[sid]:
                    del self._stalled[sid]
                self._on_timeout(sid)
                continue
            t0 = self._clock()
            act = np.asarray(sess.action(self.pool.action_space))
            dt = self._clock() - t0
            # the client's action round-trip is the consumer latency the
            # straggler policy watches (slow consumers stall lock-step pools;
            # here they only slow their own lane)
            self.tracker.record(sid, dt)
            if self.action_timeout_s is not None and dt > self.action_timeout_s:
                self._on_timeout(sid)  # stale action discarded
                continue
            sess.retries = 0
            acts.append(act)
            slot_ids.append(self.slots.slot_of(sid))

        if not acts:  # every lane backing off / timed out this tick
            for rep in self.tracker.reports():
                self.straggler_log[rep.host_id] = rep
            return bool(self.slots.active_count or self.slots.queued_count)
        self.pool.send(np.stack(acts), np.asarray(slot_ids))

        t0 = self._clock()
        obs, rew, done, info, out_slots = self.pool.recv()
        self.recv_latencies.append(self._clock() - t0)

        obs_np, rew_np = np.asarray(obs), np.asarray(rew)
        done_np = np.asarray(done)
        for i, slot in enumerate(out_slots):
            sid = self.slots.owner(int(slot))
            sess = self._sessions[sid]
            sess.steps += 1
            self.steps_served += 1
            sess.total_reward += float(rew_np[i])
            sess.episodes += int(done_np[i])
            sess._last_obs = obs_np[i]
            if sess.steps >= sess.num_steps:
                self._retire(int(sid))
        for rep in self.tracker.reports():
            self.straggler_log[rep.host_id] = rep
        return True

    def _retire(self, sid: int) -> None:
        self.pool.release(self.slots.slot_of(sid))
        self.slots.release(sid)
        self.tracker.forget(sid)

    def run(self, max_ticks: int = 100_000) -> int:
        """Serve until every submitted session's budget is spent."""
        ticks = 0
        while (self.slots.queued_count or self.slots.active_count) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    def drain(self, max_ticks: int = 100_000) -> int:
        """Graceful drain: stop admitting, finish the running sessions.

        Queued-but-never-admitted sessions stay queued (a later `resume` is
        just `self._draining = False`); running ones run to budget.
        """
        self._draining = True
        ticks = 0
        while self.slots.active_count and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    # -- checkpointed restart -------------------------------------------------
    def drain_to_checkpoint(self, manager, step: int = 0,
                            blocking: bool = True) -> str:
        """Freeze the service into a checkpoint WITHOUT finishing sessions.

        Stops admission, then persists the whole slot table's carry
        (`pool.state_dict()` — every running lane at its current step),
        every parked lane, and the host bookkeeping as `meta.json`:
        per-session progress, slot seating, queue order, and the default
        policy's numpy RNG state (so even un-scripted clients resume
        bit-exactly). `restore_service` is the other half.
        """
        self._draining = True
        tree = {
            "pool": self.pool.state_dict(),
            "parked": {str(sid): lane for sid, lane in self._lanes.items()},
        }
        sessions: Dict[str, Dict[str, Any]] = {}
        for sid, sess in self._sessions.items():
            if sess.sid not in self.slots and sid not in self._lanes \
                    and sid not in self.slots._queued_ids:
                continue  # retired: nothing in flight to preserve
            status = ("running" if sid in self.slots
                      else "evicted" if sid in self._evicted else "queued")
            sessions[str(sid)] = {
                "seed": sess.seed, "num_steps": sess.num_steps,
                "steps": sess.steps, "total_reward": sess.total_reward,
                "episodes": sess.episodes, "retries": sess.retries,
                "evictions": sess.evictions, "status": status,
                "slot": (self.slots.slot_of(sid)
                         if sid in self.slots else None),
                "rng_state": (sess._rng.bit_generator.state
                              if sess._rng is not None else None),
            }
        meta = {
            "service": {
                "num_slots": self.num_slots,
                "ticks": self.ticks,
                "steps_served": self.steps_served,
                "queue": [rid for rid, _ in self.slots._queue],
                "parked": sorted(self._lanes),
                "sessions": sessions,
            }
        }
        return manager.save(step, tree, blocking=blocking, meta=meta)

    @classmethod
    def restore_service(cls, env: Union[Env, str], num_slots: int,
                        manager, sessions: List[Session], *,
                        step: Optional[int] = None, **kwargs) -> "EnvService":
        """Rebuild a service from `drain_to_checkpoint` with every in-flight
        session resumed: running sessions re-seat in their ORIGINAL slots
        (slot index feeds the per-slot RNG split), queued sessions re-queue
        in order, evicted ones stay parked awaiting `reconnect()`.

        Policies are code and cannot be checkpointed — the caller re-supplies
        the `Session` objects (matched by sid); the checkpoint restores their
        progress, RNG state and lanes. Sessions in the checkpoint but missing
        from `sessions` raise; extra sessions may be `submit()`ed after.
        """
        meta = manager.read_meta(step)
        if not meta or "service" not in meta:
            raise ValueError("checkpoint has no EnvService meta; was it "
                             "written by drain_to_checkpoint()?")
        m = meta["service"]
        if m["num_slots"] != num_slots:
            raise ValueError(f"checkpoint has {m['num_slots']} slots; "
                             f"asked to restore with {num_slots}")
        svc = cls(env, num_slots, **kwargs)
        # templates: a fresh pool snapshot has the right shapes; one lane of
        # it (row 0) templates each parked lane
        pool_tmpl = svc.pool.state_dict()
        lane_tmpl = {"state": jax.tree.map(lambda x: x[0], pool_tmpl["state"]),
                     "obs": pool_tmpl["obs"][0]}
        template = {"pool": pool_tmpl,
                    "parked": {str(k): lane_tmpl for k in m["parked"]}}
        tree = manager.restore(template, step=step)
        svc.pool.load_state_dict(
            jax.tree.map(np.asarray, tree["pool"]))
        svc._lanes = {int(k): jax.tree.map(np.asarray, v)
                      for k, v in tree["parked"].items()}
        svc.ticks = m["ticks"]
        svc.steps_served = m["steps_served"]

        by_sid = {s.sid: s for s in sessions}
        pool_obs = np.asarray(tree["pool"]["obs"])
        for sid_str, rec in m["sessions"].items():
            sid = int(sid_str)
            if sid not in by_sid:
                raise ValueError(f"checkpoint session {sid} missing from the "
                                 "supplied sessions")
            sess = by_sid[sid]
            sess.steps = rec["steps"]
            sess.total_reward = rec["total_reward"]
            sess.episodes = rec["episodes"]
            sess.retries = rec["retries"]
            sess.evictions = rec["evictions"]
            if rec["rng_state"] is not None:
                sess._rng = np.random.default_rng(sess.seed)
                sess._rng.bit_generator.state = rec["rng_state"]
            svc._sessions[sid] = sess
            if rec["status"] == "running":
                svc.slots.place(sid, rec["slot"])
                sess._last_obs = pool_obs[rec["slot"]]
            elif rec["status"] == "evicted":
                svc._evicted.add(sid)
                sess._last_obs = np.asarray(svc._lanes[sid]["obs"])
        for sid in m["queue"]:  # FIFO order survives the restart
            svc.slots.submit(sid)
        return svc

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> Dict:
        out = dict(self.slots.stats())
        out.update({
            "ticks": self.ticks,
            "steps_served": self.steps_served,
            "recv_p50_s": percentile(self.recv_latencies, 50),
            "recv_p99_s": percentile(self.recv_latencies, 99),
            "timeouts": self.timeouts,
            "evictions": self.evictions,
            "evicted": self.evicted,
            "stragglers": [dataclasses.asdict(r)
                           for r in self.straggler_log.values()],
        })
        return out
