"""EnvService — session-multiplexing env serving over AsyncEnvPool.

The serving analogue of ServeEngine (serving/engine.py), with env sessions
in place of decode requests: many independent client sessions — each its
own AutoReset episode stream with its own key chain and step budget — are
multiplexed onto ONE fused batch. The host scheduler is the same
continuous-batching loop:

  submit -> FIFO admission queue (serving/slots.SlotTable, shared with
  ServeEngine) -> a free slot's rows are *reset-spliced* with the session's
  seed (the prefill-into-slot move) -> every tick steps the active lanes
  through the pool's masked step -> budget-exhausted sessions retire and
  free their slot for the next queued session.

Telemetry: per-tick recv latency (p50/p99 via `stats()` — the fig_async
numbers), per-session queue wait and residency (SlotTable), and a
runtime/straggler.StragglerTracker over client action-latency so
persistently slow consumers — the exact workload async mode exists to
isolate — are flagged with the profile/demote advice instead of silently
dragging the batch.

The clock is injectable: the traffic-replay tests drive a scripted clock
so latency accounting is deterministic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.core.env import Env
from repro.core.spaces import Box, Discrete, MultiDiscrete
from repro.pool.async_pool import AsyncEnvPool
from repro.runtime.straggler import StragglerTracker
from repro.serving.slots import SlotTable, percentile


def _np_sample(space, rng: np.random.Generator):
    """Cheap host-side action sampling (the synthetic-client default policy).

    Sessions number in the thousands; per-session jax dispatches for action
    sampling would bench the host RNG, not the pool, so the default client
    uses numpy. Deterministic tests pass explicit `policy=` scripts instead.
    """
    if isinstance(space, Discrete):
        return np.asarray(rng.integers(space.n), np.dtype(space.dtype))
    if isinstance(space, MultiDiscrete):
        return rng.integers(np.zeros_like(np.asarray(space.nvec)),
                            np.asarray(space.nvec)).astype(space.dtype)
    if isinstance(space, Box):
        lo = np.nan_to_num(np.asarray(space.low, np.float64), neginf=-1.0)
        hi = np.nan_to_num(np.asarray(space.high, np.float64), posinf=1.0)
        return rng.uniform(lo, hi, size=space.shape).astype(space.dtype)
    raise TypeError(f"no default sampler for space {type(space).__name__}")


@dataclasses.dataclass
class Session:
    """One client: seed, step budget, and an optional action policy.

    `policy(obs, t) -> action` is called once per tick while running; None
    means sample uniformly from the action space with a per-session numpy
    generator. Results accumulate in place (the Request.output idiom of
    serving/engine.py).
    """

    sid: int
    seed: int
    num_steps: int
    policy: Optional[Callable] = None
    # -- filled by the service --------------------------------------------
    steps: int = 0
    total_reward: float = 0.0
    episodes: int = 0
    first_obs: Optional[np.ndarray] = None
    _rng: Optional[np.random.Generator] = None
    _last_obs: Optional[np.ndarray] = None

    def action(self, space):
        if self.policy is not None:
            return self.policy(self._last_obs, self.steps)
        if self._rng is None:
            self._rng = np.random.default_rng(self.seed)
        return _np_sample(space, self._rng)


class EnvService:
    """Continuous-batching env server: many sessions, one fused batch.

    >>> svc = EnvService("CartPole-v1", num_slots=64)
    >>> for i in range(1000):
    ...     svc.submit(Session(sid=i, seed=i, num_steps=100))
    >>> svc.run()            # admit / step / retire until all served
    >>> svc.stats()["recv_p99_s"]
    """

    def __init__(self, env: Union[Env, str], num_slots: int, *,
                 backend: str = "auto", tracker: Optional[StragglerTracker] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.pool = AsyncEnvPool(env, num_slots, backend=backend)
        self.num_slots = num_slots
        self._clock = clock or time.monotonic
        self.slots = SlotTable(num_slots, clock=self._clock)
        self.tracker = tracker or StragglerTracker()
        self._sessions: Dict[int, Session] = {}
        self._draining = False
        self.recv_latencies: List[float] = []
        self.ticks = 0
        self.steps_served = 0
        # latest StragglerReport per flagged sid; sessions retire (and the
        # tracker forgets them) before stats() is usually read, so the policy
        # is evaluated every tick and flagged sessions logged here
        self.straggler_log: Dict[int, object] = {}

    # -- client API ----------------------------------------------------------
    def submit(self, session: Session) -> None:
        if session.sid in self._sessions:
            raise ValueError(f"session {session.sid} already submitted")
        if session.num_steps < 1:
            raise ValueError("num_steps budget must be >= 1")
        if self._draining:
            raise RuntimeError("service is draining; not accepting sessions")
        self._sessions[session.sid] = session
        self.slots.submit(session.sid)

    @property
    def queued(self) -> int:
        return self.slots.queued_count

    @property
    def running(self) -> int:
        return self.slots.active_count

    # -- scheduler loop -------------------------------------------------------
    def _admit(self) -> None:
        for slot, sid in self.slots.admit():
            sess = self._sessions[sid]
            _, obs = self.pool.admit(seed=sess.seed, slot=slot)
            sess.first_obs = np.asarray(obs)
            sess._last_obs = sess.first_obs

    def tick(self) -> bool:
        """One scheduler tick: admit, collect actions, masked step, retire.

        Returns False when there is nothing to do (drained/idle).
        """
        if not self._draining:
            self._admit()
        running = self.slots.running()
        if not running:
            return False
        self.ticks += 1

        acts, slot_ids = [], []
        for sid in running:
            sess = self._sessions[sid]
            t0 = self._clock()
            acts.append(np.asarray(sess.action(self.pool.action_space)))
            # the client's action round-trip is the consumer latency the
            # straggler policy watches (slow consumers stall lock-step pools;
            # here they only slow their own lane)
            self.tracker.record(sid, self._clock() - t0)
            slot_ids.append(self.slots.slot_of(sid))
        self.pool.send(np.stack(acts), np.asarray(slot_ids))

        t0 = self._clock()
        obs, rew, done, info, out_slots = self.pool.recv()
        self.recv_latencies.append(self._clock() - t0)

        obs_np, rew_np = np.asarray(obs), np.asarray(rew)
        done_np = np.asarray(done)
        for i, slot in enumerate(out_slots):
            sid = self.slots.owner(int(slot))
            sess = self._sessions[sid]
            sess.steps += 1
            self.steps_served += 1
            sess.total_reward += float(rew_np[i])
            sess.episodes += int(done_np[i])
            sess._last_obs = obs_np[i]
            if sess.steps >= sess.num_steps:
                self._retire(int(sid))
        for rep in self.tracker.reports():
            self.straggler_log[rep.host_id] = rep
        return True

    def _retire(self, sid: int) -> None:
        self.pool.release(self.slots.slot_of(sid))
        self.slots.release(sid)
        self.tracker.forget(sid)

    def run(self, max_ticks: int = 100_000) -> int:
        """Serve until every submitted session's budget is spent."""
        ticks = 0
        while (self.slots.queued_count or self.slots.active_count) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    def drain(self, max_ticks: int = 100_000) -> int:
        """Graceful drain: stop admitting, finish the running sessions.

        Queued-but-never-admitted sessions stay queued (a later `resume` is
        just `self._draining = False`); running ones run to budget.
        """
        self._draining = True
        ticks = 0
        while self.slots.active_count and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> Dict:
        out = dict(self.slots.stats())
        out.update({
            "ticks": self.ticks,
            "steps_served": self.steps_served,
            "recv_p50_s": percentile(self.recv_latencies, 50),
            "recv_p99_s": percentile(self.recv_latencies, 99),
            "stragglers": [dataclasses.asdict(r)
                           for r in self.straggler_log.values()],
        })
        return out
