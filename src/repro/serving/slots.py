"""Slot bookkeeping shared by the continuous-batching engines.

ServeEngine (serving/engine.py) and EnvService (serving/env_service.py)
run the same host-side pattern: a fixed number of device-resident slots,
a FIFO admission queue, and continuous refill — when an occupant finishes,
its slot is freed and the next queued request is prefilled / reset into
the same rows. The bookkeeping used to live inline in ServeEngine
(`_slot_req` + `_free_slots`, untested), and the latency accounting only
in the env service; `SlotTable` is the single shared copy of both.

Accounting: the table records, per occupant, the queue wait (submit ->
admit) and the slot residency (admit -> release). The clock is injectable
so tests drive a scripted one (tests/test_slots.py) — the same
deterministic-clock idea the traffic-replay harness uses.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple


def percentile(values, q: float) -> float:
    """Nearest-rank percentile without a numpy dependency on the hot path."""
    if not values:
        return float("nan")
    xs = sorted(values)
    rank = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return float(xs[rank])


class SlotTable:
    """FIFO admission queue + slot ownership map + wait/residency accounting.

    Ids are opaque (request rids, session sids). Invariants (property-tested
    in tests/test_property.py):

      - a slot has at most one owner, an id at most one slot;
      - `admit()` never leaves a slot free while the queue is non-empty;
      - admission is FIFO over ids, filling the lowest free slots first
        (the ServeEngine ordering, now pinned by tests).
    """

    def __init__(self, num_slots: int, clock: Optional[Callable[[], float]] = None):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.num_slots = int(num_slots)
        self._clock = clock or time.monotonic
        self._owner: List[Optional[Any]] = [None] * self.num_slots
        self._slot_of: Dict[Any, int] = {}
        self._queue: Deque[Tuple[Any, float]] = deque()
        self._queued_ids: set = set()
        self._admitted_at: Dict[Any, float] = {}
        self.queue_waits: List[float] = []
        self.residencies: List[float] = []
        self.admitted = 0
        self.released = 0

    # -- queries ---------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._owner) if r is None]

    def owner(self, slot: int) -> Optional[Any]:
        return self._owner[slot]

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    def running(self) -> List[Any]:
        """Occupant ids in slot order."""
        return [r for r in self._owner if r is not None]

    @property
    def active_count(self) -> int:
        return len(self._slot_of)

    @property
    def queued_count(self) -> int:
        return len(self._queue)

    def __contains__(self, rid) -> bool:
        return rid in self._slot_of

    # -- lifecycle -------------------------------------------------------
    def submit(self, rid) -> None:
        """Queue an id for admission (FIFO)."""
        if rid in self._queued_ids or rid in self._slot_of:
            raise ValueError(f"id {rid!r} already queued or running")
        self._queue.append((rid, self._clock()))
        self._queued_ids.add(rid)

    def admit(self) -> List[Tuple[int, Any]]:
        """Fill free slots from the queue head: [(slot, rid), ...].

        Queue order is preserved; the earliest queued id takes the lowest
        free slot (exactly the ServeEngine `_admit` loop ordering).
        """
        out = []
        now = self._clock()
        for slot in self.free_slots():
            if not self._queue:
                break
            rid, t_submit = self._queue.popleft()
            self._queued_ids.discard(rid)
            self._owner[slot] = rid
            self._slot_of[rid] = slot
            self._admitted_at[rid] = now
            self.queue_waits.append(now - t_submit)
            self.admitted += 1
            out.append((slot, rid))
        return out

    def place(self, rid, slot: int) -> None:
        """Seat `rid` directly in `slot`, bypassing the queue.

        The service-restart path (EnvService.restore_service) uses this to
        re-seat checkpointed sessions in their ORIGINAL slots — slot index
        feeds the per-slot RNG split, so keeping it is part of resuming
        key-dependent envs bit-exactly. Not for normal admission: `admit()`
        owns the FIFO/lowest-slot ordering.
        """
        if rid in self._queued_ids or rid in self._slot_of:
            raise ValueError(f"id {rid!r} already queued or running")
        if self._owner[slot] is not None:
            raise ValueError(
                f"slot {slot} already owned by {self._owner[slot]!r}")
        self._owner[slot] = rid
        self._slot_of[rid] = slot
        self._admitted_at[rid] = self._clock()
        self.admitted += 1

    def release(self, rid) -> int:
        """Free the slot owned by `rid`; returns the slot index."""
        slot = self._slot_of.pop(rid)
        self._owner[slot] = None
        self.residencies.append(self._clock() - self._admitted_at.pop(rid))
        self.released += 1
        return slot

    # -- accounting ------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "admitted": self.admitted,
            "released": self.released,
            "running": self.active_count,
            "queued": self.queued_count,
            "queue_wait_p50": percentile(self.queue_waits, 50),
            "queue_wait_p99": percentile(self.queue_waits, 99),
            "residency_p50": percentile(self.residencies, 50),
            "residency_p99": percentile(self.residencies, 99),
        }

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SlotTable({self.active_count}/{self.num_slots} running, "
                f"{self.queued_count} queued)")
