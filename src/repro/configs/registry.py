"""Architecture registry: full configs, reduced smoke configs, input specs.

Every assigned arch ships:
  - `full()`    : the exact published configuration (dry-run only — params
                  are never materialised on this host; ShapeDtypeStructs).
  - `reduced()` : same family/pattern, tiny dims — one CPU train step in the
                  smoke tests.
  - `input_specs(cfg, shape, multi_pod)` (below): ShapeDtypeStruct stand-ins
    for every model input of a (train|prefill|decode) step.

Skips (see DESIGN.md §5): long_500k for pure full-attention archs.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, shape_by_name

ARCH_IDS = (
    "yi-6b",
    "minicpm3-4b",
    "h2o-danube-1.8b",
    "gemma3-27b",
    "xlstm-350m",
    "chameleon-34b",
    "zamba2-2.7b",
    "whisper-base",
    "olmoe-1b-7b",
    "granite-moe-1b-a400m",
)

_MODULES = {
    "yi-6b": "yi_6b",
    "minicpm3-4b": "minicpm3_4b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "gemma3-27b": "gemma3_27b",
    "xlstm-350m": "xlstm_350m",
    "chameleon-34b": "chameleon_34b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-base": "whisper_base",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}

# long_500k requires sub-quadratic attention / bounded state.
LONG_CONTEXT_OK = {"xlstm-350m", "zamba2-2.7b", "h2o-danube-1.8b", "gemma3-27b"}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced() if reduced else mod.full()


def cell_supported(arch: str, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason string."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "pure full-attention arch: 500k-token decode is skipped (DESIGN.md §5)"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str,
                max_seq: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one step's model inputs (no allocation)."""
    if isinstance(shape, str):
        shape = shape_by_name(shape)
    b, l = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, l), i32),
            "labels": jax.ShapeDtypeStruct((b, l), i32),
        }
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, l), i32)}
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        # one new token against a seq_len-deep cache
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)
