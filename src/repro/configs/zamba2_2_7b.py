"""zamba2-2.7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Stack = 6 × (8 Mamba2 + shared-attn site); the attention+FFN weights are
SHARED across the 6 sites (zamba2's parameter-reuse trick) — per-site LoRA
deltas are omitted (DESIGN.md simplifications).
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
        d_ff=10240, vocab_size=32000,
        segments=(((("mamba2",) * 8 + ("attn_shared",)), 6),),
        ssm_state=64, ssm_chunk=256, expand=2,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-reduced", family="hybrid",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        segments=((("mamba2", "mamba2", "attn_shared"), 2),),
        ssm_state=8, ssm_chunk=8, expand=2, tie_embeddings=True, dtype="float32",
    )
