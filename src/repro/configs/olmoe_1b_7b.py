"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16) per-expert d_ff=1024 vocab=50304.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=1024, vocab_size=50304,
        segments=((("full_moe",), 16),),
        num_experts=64, num_experts_per_tok=8, capacity_factor=1.25,
        tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-reduced", family="moe",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512,
        segments=((("full_moe",), 2),),
        num_experts=8, num_experts_per_tok=2, capacity_factor=2.0,
        tie_embeddings=False, dtype="float32",
    )
