"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, window=4096.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        d_model=2560, num_heads=32, num_kv_heads=8, head_dim=80,
        d_ff=6912, vocab_size=32000,
        segments=((("swa",), 24),),
        window=4096, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-reduced", family="dense",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=112, vocab_size=512,
        segments=((("swa",), 2),),
        window=8, tie_embeddings=False, dtype="float32",
    )
