"""minicpm3-4b — MLA dense decoder [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448; MLA ranks from the HF
config: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        segments=((("mla",), 62),),
        q_lora_rank=768, kv_lora_rank=256,
        qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-reduced", family="dense",
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=512,
        segments=((("mla",), 2),),
        q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        tie_embeddings=True, dtype="float32",
    )
