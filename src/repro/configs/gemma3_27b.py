"""gemma3-27b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-* family; unverified].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, head_dim=128,
local window=1024, qk-norm. Stack = 10 × (5 local + 1 global) + 2 local.
The two-tier KV cache (ring caches for the 52 local layers, full-depth for
the 10 global ones) is what makes the long_500k cell fit (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        d_model=5376, num_heads=32, num_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        segments=(
            (("swa", "swa", "swa", "swa", "swa", "full"), 10),
            (("swa",), 2),
        ),
        window=1024, qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-reduced", family="dense",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512,
        segments=((("swa", "swa", "full"), 2),),
        window=8, qk_norm=True, tie_embeddings=True, dtype="float32",
    )
