"""whisper-base — encoder-decoder ASR backbone [arXiv:2212.04356; unverified].

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865. The conv
log-mel frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, d). RMSNorm replaces LayerNorm (DESIGN.md
simplifications); decode shapes exercise the decoder with self- and
cross-attention caches.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=51865,
        segments=((("dec",), 6),),
        encoder_segments=((("enc",), 6),),
        encoder_len=1500, tie_embeddings=True, frontend="audio_frames",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-reduced", family="audio",
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        segments=((("dec",), 2),),
        encoder_segments=((("enc",), 2),),
        encoder_len=24, tie_embeddings=True, frontend="audio_frames", dtype="float32",
    )
