"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (xLSTM blocks carry their own projections)
vocab=50304. Stack = 3 × (7 mLSTM + 1 sLSTM) (the paper's sparse-sLSTM
placement). Recurrent state is O(1) in sequence → long_500k runs.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        d_model=1024, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        segments=(((("mlstm",) * 7 + ("slstm",)), 3),),
        expand=2, ssm_chunk=256, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-reduced", family="ssm",
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=512,
        segments=((("mlstm", "mlstm", "slstm"), 2),),
        expand=2, ssm_chunk=8, tie_embeddings=True, dtype="float32",
    )
