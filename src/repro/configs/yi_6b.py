"""yi-6b — llama-arch GQA dense decoder [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        d_model=4096, num_heads=32, num_kv_heads=4, head_dim=128,
        d_ff=11008, vocab_size=64000,
        segments=((("full",), 32),),
        rope_theta=10_000.0, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-reduced", family="dense",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=176, vocab_size=512,
        segments=((("full",), 2),),
        tie_embeddings=False, dtype="float32",
    )
