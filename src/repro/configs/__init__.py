"""configs subsystem."""
