"""The paper's own experiment configuration (Table I) + a tuned variant.

`PAPER_TABLE_I` reproduces the carbon-emission experiment exactly;
`TUNED` is the configuration that reliably solves CartPole-v1 on this host
(recorded separately in EXPERIMENTS.md so the faithful config stays intact).
"""
from repro.rl.dqn import DQNConfig

# Table I: Discount 0.99 | Units 32,32 | elu | Adam | Huber | batch 32 |
# lr 3e-4 | target update 150 | memory 50 000 | eps 1.0 -> 0.01
PAPER_TABLE_I = DQNConfig(
    discount=0.99,
    units=(32, 32),
    activation="elu",
    batch_size=32,
    lr=3e-4,
    target_update_freq=150,
    memory_size=50_000,
    exploration_start=1.0,
    exploration_final=0.01,
)

TUNED = DQNConfig(
    discount=0.99,
    units=(64, 64),
    activation="elu",
    batch_size=64,
    lr=1e-3,
    target_update_freq=500,
    memory_size=50_000,
    exploration_start=1.0,
    exploration_final=0.01,
    exploration_steps=15_000,
    learn_start=500,
    num_envs=4,
)
