"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
codes in one table). The VQ tokenizer frontend is a STUB: input_specs()
supplies already-tokenised mixed streams (frontend="vq_tokens"); qk-norm as
in the paper.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
        d_ff=22016, vocab_size=65536,
        segments=((("full",), 48),),
        qk_norm=True, tie_embeddings=False, frontend="vq_tokens",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-reduced", family="vlm",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=176, vocab_size=512,
        segments=((("full",), 2),),
        qk_norm=True, tie_embeddings=False, frontend="vq_tokens", dtype="float32",
    )
