"""granite-moe-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155.
"""
from repro.configs.base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155,
        segments=((("full_moe",), 24),),
        num_experts=32, num_experts_per_tok=8, capacity_factor=1.25,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-reduced", family="moe",
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512,
        segments=((("full_moe",), 2),),
        num_experts=8, num_experts_per_tok=2, capacity_factor=2.0,
        tie_embeddings=True, dtype="float32",
    )
