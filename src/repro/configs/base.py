"""Model/shape configuration schema shared by all 10 assigned architectures.

A model is a sequence of SEGMENTS. Each segment is (block_types, repeat):
the `block_types` tuple is applied in order inside one scan body, and the
body is `lax.scan`ned `repeat` times with stacked parameters — so HLO size is
O(pattern length), not O(depth). Heterogeneous stacks (gemma3 5:1
local:global, zamba2 Mamba2+shared-attn, xlstm mLSTM/sLSTM) are expressed as
multi-block segments.

Block type vocabulary:
  "full"      GQA full causal attention + dense SwiGLU FFN
  "swa"       GQA sliding-window attention + dense SwiGLU FFN
  "mla"       Multi-head Latent Attention (DeepSeek/MiniCPM3) + dense FFN
  "full_moe"  GQA full attention + top-k MoE FFN
  "mlstm"     xLSTM matrix-memory block (chunked gated linear attention)
  "slstm"     xLSTM scalar-memory recurrent block
  "mamba2"    Mamba2 SSD block (chunked scan + short conv + gate)
  "attn_shared" zamba2-style attention block with SHARED weights across sites
  "enc"       bidirectional encoder attention + FFN (whisper encoder)
  "dec"       causal self-attn + cross-attn + FFN (whisper decoder)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Segment = Tuple[Tuple[str, ...], int]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Segment, ...]
    head_dim: Optional[int] = None
    # attention
    window: int = 0                  # sliding-window size for "swa" blocks
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # chameleon/gemma3-style qk layernorm
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False     # weight-absorbed latent attention (§Perf)
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0          # >1 = shard-local grouped dispatch (§Perf)
    # SSM
    ssm_state: int = 0               # N (state size per head) for mamba2
    ssm_chunk: int = 256             # chunk length for the chunked scan
    conv_width: int = 4              # mamba2 short-conv width
    expand: int = 2                  # mamba2/mLSTM up-projection factor
    # encoder-decoder (whisper)
    encoder_segments: Tuple[Segment, ...] = ()
    encoder_len: int = 1500          # stub frontend frame count
    # misc
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    tie_embeddings: bool = True
    frontend: str = "none"           # none | audio_frames (stub) | vq_tokens (stub)

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(len(blocks) * rep for blocks, rep in self.segments)

    @property
    def is_encoder_decoder(self) -> bool:
        return bool(self.encoder_segments)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline bookkeeping)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.hd, self.num_heads, self.num_kv_heads
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d

        def attn_params():
            return d * hq * hd + 2 * d * hkv * hd + hq * hd * d + 2 * d  # qkvo + norms

        def mla_params():
            qr, kvr = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vd = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            p = d * qr + qr * hq * (nope + rope)            # q path
            p += d * (kvr + rope) + kvr * hq * (nope + vd)  # kv path
            p += hq * vd * d + 2 * d + qr + kvr             # o + norms
            return p

        def ffn_params():
            return d * 2 * ff + ff * d + d

        def moe_params():
            e = self.num_experts
            return d * e + e * (d * 2 * ff + ff * d) + d

        def mamba_params():
            di = d * self.expand
            return d * (2 * di + 2 * self.ssm_state + self.num_heads) + di * d + 3 * di + d

        def xlstm_params(kind):
            di = d * self.expand
            if kind == "mlstm":
                return d * 2 * di + di * (3 * di // 1) // 1 + di * d + d  # approx
            return 4 * (d * d + d * d) + d * 2 * (4 * d // 3) + d  # approx

        per_block = {
            "full": attn_params() + ffn_params(),
            "swa": attn_params() + ffn_params(),
            "enc": attn_params() + ffn_params(),
            "dec": 2 * attn_params() + ffn_params(),
            "mla": mla_params() + ffn_params(),
            "full_moe": attn_params() + moe_params(),
            "mamba2": mamba_params(),
            "mlstm": xlstm_params("mlstm"),
            "slstm": xlstm_params("slstm"),
            "attn_shared": 0,  # counted once below
        }
        shared_sites = 0
        for blocks, rep in self.segments + self.encoder_segments:
            for b in blocks:
                n += per_block[b] * rep
                if b == "attn_shared":
                    shared_sites += rep
        if shared_sites:
            n += attn_params() + ffn_params()  # one shared copy
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of E experts)."""
        if not self.num_experts:
            return self.param_count()
        d, ff, e, k = self.d_model, self.d_ff, self.num_experts, self.num_experts_per_tok
        inactive_per_moe = (e - k) * (d * 2 * ff + ff * d)
        moe_blocks = sum(
            sum(1 for b in blocks if b == "full_moe") * rep for blocks, rep in self.segments
        )
        return self.param_count() - moe_blocks * inactive_per_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
