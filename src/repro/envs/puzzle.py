"""Puzzle runtime — Simon Tatham collection analogue (paper §IV-D).

LightsOut on an N×N board: pressing a cell toggles it and its von-Neumann
neighbours; the episode ends when all lights are off. Like the paper's
puzzles, a heuristic solver ships with the env ("All puzzles include a
heuristic-based solver, enabling transfer and curriculum learning research"):
`solve()` does GF(2) Gaussian elimination host-side and returns an optimal
press set usable for imitation/curriculum.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete


class LightsOutState(NamedTuple):
    board: jax.Array  # (N, N) int32 in {0, 1}
    t: jax.Array


def _toggle(board: jax.Array, action: jax.Array, n: int) -> jax.Array:
    r, c = action // n, action % n
    rows = jnp.arange(n)
    cols = jnp.arange(n)
    rr = rows[:, None]
    cc = cols[None, :]
    cross = ((rr == r) & (jnp.abs(cc - c) <= 1)) | ((cc == c) & (jnp.abs(rr - r) <= 1))
    return board ^ cross.astype(board.dtype)


class LightsOut(Env):
    def __init__(self, n: int = 5, scramble_presses: int = 6):
        self.n = n
        self.scramble_presses = scramble_presses
        self.observation_space = Box(low=0.0, high=1.0, shape=(n * n,))
        self.action_space = Discrete(n * n)
        self.frame_shape = (84, 84)

    def reset(self, key):
        # Scramble from solved by K random presses => always solvable.
        presses = jax.random.randint(key, (self.scramble_presses,), 0, self.n * self.n)
        board = jnp.zeros((self.n, self.n), jnp.int32)
        board = jax.lax.fori_loop(
            0, self.scramble_presses, lambda i, b: _toggle(b, presses[i], self.n), board
        )
        state = LightsOutState(board, jnp.asarray(0, jnp.int32))
        return state, self._obs(state)

    def _obs(self, s: LightsOutState):
        return s.board.reshape(-1).astype(jnp.float32)

    def step(self, state: LightsOutState, action, key):
        board = _toggle(state.board, action, self.n)
        done = jnp.sum(board) == 0
        reward = jnp.where(done, 10.0, -1.0).astype(jnp.float32)
        ns = LightsOutState(board, state.t + 1)
        return Timestep(ns, self._obs(ns), reward, done, {})

    def render(self, state: LightsOutState):
        from repro.kernels.raster import rasterize_single

        n = self.n
        centers = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n
        cx = jnp.tile(centers, n)
        cy = jnp.repeat(centers, n)
        r = jnp.full((n * n,), 0.35 / n, jnp.float32)
        segs = jnp.stack([cx, cy, cx, cy, r], axis=-1)
        intens = state.board.reshape(-1).astype(jnp.float32) * 0.8 + 0.15
        return rasterize_single(segs, intens, *self.frame_shape)

    # -- heuristic solver (host-side; paper §IV-D) ---------------------------
    def solve(self, board: np.ndarray) -> list:
        """GF(2) linear solve: returns cell indices to press (optimal set)."""
        n = self.n
        m = n * n
        a = np.zeros((m, m), np.uint8)
        for act in range(m):
            r, c = divmod(act, n)
            for dr, dc in ((0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)):
                rr, cc = r + dr, c + dc
                if 0 <= rr < n and 0 <= cc < n:
                    a[rr * n + cc, act] = 1
        b = np.asarray(board, np.uint8).reshape(-1).copy()
        # Gaussian elimination over GF(2).
        aug = np.concatenate([a, b[:, None]], axis=1)
        row = 0
        pivots = []
        for col in range(m):
            pivot = next((r for r in range(row, m) if aug[r, col]), None)
            if pivot is None:
                continue
            aug[[row, pivot]] = aug[[pivot, row]]
            for r in range(m):
                if r != row and aug[r, col]:
                    aug[r] ^= aug[row]
            pivots.append(col)
            row += 1
        if any(aug[r, -1] for r in range(row, m)):
            raise ValueError("unsolvable board")
        x = np.zeros(m, np.uint8)
        for r, col in enumerate(pivots):
            x[col] = aug[r, -1]
        return [i for i in range(m) if x[i]]
