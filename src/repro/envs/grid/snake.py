"""Snake — the classic grid game with a deterministic procedural food chain.

The body is a per-cell age grid (cell value = steps until that segment
vacates; the head cell holds the current length), so the whole game is
element-wise arithmetic over the board — exactly the shape the megastep
kernel wants.

Food placement is the interesting bit: the fused kernel is random-free
(kernels/envstep/megastep.py — randomness would break vmap/fused
bit-parity), so food cannot be resampled with `jax.random` inside `step`.
Instead `reset` draws a per-cell priority field `prio` (part of the level,
regenerated per episode on the AutoReset key chain), and the k-th food
spawns at the free cell minimising frac(prio + k·φ) — a deterministic
low-discrepancy sequence over the board that both the vmap env and the
row-major kernel compute with the same min-reductions, bit for bit.

Rewards: +1 eat, -1 death (wall or body), 0 otherwise; the episode also
ends if the body fills the board. Observation: cell-code grid,
`MultiDiscrete`: 0 empty, 1 body, 2 head, 3 food.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Discrete, MultiDiscrete
from repro.envs.grid.common import grid_scene, move_deltas

PHI = 0.6180339887498949   # golden-ratio conjugate: the food hop per eat
EAT_REWARD = 1.0
DEATH_REWARD = -1.0
INTENS = (0.0, 0.55, 1.0, 0.8)   # empty, body, head, food


class SnakeState(NamedTuple):
    ages: jax.Array    # (n*n,) int32 — 0 empty, else steps-to-vacate
    head: jax.Array    # () int32 cell index
    food: jax.Array    # () int32 cell index
    length: jax.Array  # () int32
    eaten: jax.Array   # () int32 — k, indexes the food sequence
    prio: jax.Array    # (n*n,) float32 — this episode's food priorities


def place_food(prio, ages, head, k):
    """Free cell minimising frac(prio + k·φ); ties broken by lowest index.

    Written as element-wise ops + min-reductions over the cell axis so the
    row-major fused spec (kernels/envstep/specs.py) is the same math.
    """
    m = prio.shape[-1]
    idx = jnp.arange(m, dtype=jnp.float32)
    vals = prio + k.astype(jnp.float32) * PHI
    vals = vals - jnp.floor(vals)
    free = (ages == 0) & (jnp.arange(m) != head)
    v = jnp.where(free, vals, 2.0)
    vmin = jnp.min(v)
    return jnp.min(jnp.where(v == vmin, idx, float(m))).astype(jnp.int32)


class Snake(Env):
    def __init__(self, n: int = 6):
        self.n = n
        self.m = n * n
        self.observation_space = MultiDiscrete((4,) * self.m)
        self.action_space = Discrete(4)
        self.frame_shape = (84, 84)
        self.reward_range = (DEATH_REWARD, EAT_REWARD)

    def reset(self, key):
        center = (self.n // 2) * self.n + self.n // 2
        prio = jax.random.uniform(key, (self.m,))
        head = jnp.asarray(center, jnp.int32)
        ages = jnp.zeros((self.m,), jnp.int32).at[center].set(1)
        food = place_food(prio, ages, head, jnp.asarray(0, jnp.int32))
        state = SnakeState(ages, head, food, jnp.asarray(1, jnp.int32),
                           jnp.asarray(0, jnp.int32), prio)
        return state, self._obs(state)

    def _obs(self, s: SnakeState):
        idx = jnp.arange(self.m)
        codes = jnp.where(idx == s.head, 2,
                          jnp.where(s.ages > 0, 1,
                                    jnp.where(idx == s.food, 3, 0)))
        return codes.astype(jnp.int32)

    def step(self, state: SnakeState, action, key):
        n, m = self.n, self.m
        idx = jnp.arange(m)
        dr, dc = move_deltas(action)
        r, c = state.head // n, state.head % n
        nr, nc = r + dr, c + dc
        inb = (nr >= 0) & (nr < n) & (nc >= 0) & (nc < n)
        cand = (jnp.clip(nr, 0, n - 1) * n
                + jnp.clip(nc, 0, n - 1)).astype(jnp.int32)
        eat = inb & (cand == state.food)
        # Tail vacates one cell unless eating (the snake grows by standing
        # still at the back); moving into the just-vacated tail cell is legal.
        ages2 = jnp.maximum(state.ages - jnp.where(eat, 0, 1), 0)
        hit_body = ages2[cand] > 0
        die = ~inb | hit_body
        new_len = (state.length + eat).astype(jnp.int32)
        ages3 = jnp.where(idx == cand, new_len, ages2).astype(jnp.int32)
        win = new_len >= m
        done = die | win
        eaten = (state.eaten + eat).astype(jnp.int32)
        placed = place_food(state.prio, ages3, cand, eaten)
        food = jnp.where(eat & ~done, placed, state.food).astype(jnp.int32)
        reward = (eat.astype(jnp.float32) * EAT_REWARD
                  + die.astype(jnp.float32) * DEATH_REWARD)
        ns = SnakeState(ages3, cand, food, new_len, eaten, state.prio)
        return Timestep(ns, self._obs(ns), reward, done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: SnakeState):
        return grid_scene(self._obs(state), self.n, self.n, INTENS)

    def render(self, state: SnakeState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
