"""Shared helpers for the procedural gridworld suite (envs/grid).

Every grid game regenerates its *level* (hole/cliff/wall layout, goal
position) per episode inside `reset(key)`. Because `reset` is pure JAX, the
same AutoReset threefry chain that gives the megastep kernel vmap/fused
bit-parity (kernels/envstep/ops.py precomputes the fresh reset states with
the identical `jax.random` call sequence) also drives on-device procedural
generation: every autoreset boundary is a brand-new level, with zero host
involvement.

Solvability is by construction, not rejection sampling: `carve_path` marks a
random monotone lattice path from the start to the goal, and generators
never place an obstacle on a carved cell — so FrozenLake/Maze levels are
always solvable (tests/test_property.py checks this with a host-side BFS).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def carve_path(key, n_rows: int, n_cols: int, goal_r, goal_c) -> jax.Array:
    """Random monotone lattice path (0,0) -> (goal_r, goal_c).

    Returns a flat (n_rows * n_cols,) int32 mask with 1 on every path cell
    (start and goal included). The walk takes one row- or col-step toward
    the goal per iteration, choosing the axis at random while both are
    needed; the loop runs the worst-case n_rows + n_cols - 2 steps and
    no-ops once the goal is reached, so `goal_r`/`goal_c` may be traced.
    """
    m = n_rows * n_cols
    steps = n_rows + n_cols - 2
    u = jax.random.uniform(key, (steps,))
    goal_r = jnp.asarray(goal_r, jnp.int32)
    goal_c = jnp.asarray(goal_c, jnp.int32)

    def body(i, carry):
        r, c, mask = carry
        need_r = goal_r - r
        need_c = goal_c - c
        go_row = (need_r != 0) & ((need_c == 0) | (u[i] < 0.5))
        go_col = (~go_row) & (need_c != 0)
        r = r + jnp.where(go_row, jnp.sign(need_r), 0)
        c = c + jnp.where(go_col, jnp.sign(need_c), 0)
        return r, c, mask.at[r * n_cols + c].set(1)

    mask0 = jnp.zeros((m,), jnp.int32).at[0].set(1)
    zero = jnp.asarray(0, jnp.int32)
    _, _, mask = jax.lax.fori_loop(0, steps, body, (zero, zero, mask0))
    return mask


def move_deltas(action):
    """Gym FrozenLake action order: 0 left, 1 down, 2 right, 3 up."""
    a = jnp.asarray(action)
    dr = jnp.where(a == 1, 1, 0) - jnp.where(a == 3, 1, 0)
    dc = jnp.where(a == 2, 1, 0) - jnp.where(a == 0, 1, 0)
    return dr, dc


def grid_scene(codes, n_rows: int, n_cols: int, intens_table):
    """Per-cell capsule scene (kernels/raster contract): one point capsule
    at each cell centre, intensity looked up from the cell's obs code —
    the LightsOut render idiom, shared by the whole grid suite."""
    m = n_rows * n_cols
    idx = jnp.arange(m)
    cx = ((idx % n_cols).astype(jnp.float32) + 0.5) / n_cols
    cy = ((idx // n_cols).astype(jnp.float32) + 0.5) / n_rows
    rad = jnp.full((m,), 0.35 / max(n_rows, n_cols), jnp.float32)
    segs = jnp.stack([cx, cy, cx, cy, rad], axis=-1)
    intens = jnp.asarray(intens_table, jnp.float32)[codes]
    return segs.astype(jnp.float32), intens
