"""Maze — random wall field with a per-episode random goal.

Each `reset(key)` samples a fresh wall layout AND a fresh goal cell (drawn
from the far half of the board), then carves a random monotone path from the
start to the goal so every level is solvable by construction. Walls block —
moving into a wall (or off the board) leaves the agent in place. Reaching
the goal terminates with +1; every other step is reward 0.

Both the layout and the goal live in the state, so the fused megastep path
regenerates them across autoreset boundaries exactly like vmap (the fresh
reset states are precomputed on the AutoReset key chain). Observation:
cell-code grid, `MultiDiscrete`: 0 free, 1 wall, 2 goal, 3 agent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Discrete, MultiDiscrete
from repro.envs.grid.common import carve_path, grid_scene, move_deltas

WALL_P = 0.35          # per-cell wall probability (off the carved path)
GOAL_REWARD = 1.0
INTENS = (0.12, 0.55, 0.85, 1.0)   # free, wall, goal, agent


class MazeState(NamedTuple):
    pos: jax.Array     # () int32 cell index
    goal: jax.Array    # () int32 cell index — regenerated per episode
    walls: jax.Array   # (n*n,) int32 in {0, 1}


class Maze(Env):
    def __init__(self, n: int = 8):
        self.n = n
        self.m = n * n
        self.observation_space = MultiDiscrete((4,) * self.m)
        self.action_space = Discrete(4)
        self.frame_shape = (84, 84)
        self.reward_range = (0.0, GOAL_REWARD)

    def reset(self, key):
        ku, kg, kp = jax.random.split(key, 3)
        u = jax.random.uniform(ku, (self.m,))
        goal = jax.random.randint(kg, (), self.m // 2, self.m)
        path = carve_path(kp, self.n, self.n, goal // self.n, goal % self.n)
        walls = ((u < WALL_P) & (path == 0)).astype(jnp.int32)
        state = MazeState(jnp.asarray(0, jnp.int32), goal.astype(jnp.int32),
                          walls)
        return state, self._obs(state)

    def _obs(self, s: MazeState):
        idx = jnp.arange(self.m)
        codes = jnp.where(idx == s.pos, 3,
                          jnp.where(idx == s.goal, 2, s.walls))
        return codes.astype(jnp.int32)

    def step(self, state: MazeState, action, key):
        n = self.n
        dr, dc = move_deltas(action)
        r, c = state.pos // n, state.pos % n
        nr = jnp.clip(r + dr, 0, n - 1)
        nc = jnp.clip(c + dc, 0, n - 1)
        cand = (nr * n + nc).astype(jnp.int32)
        blocked = state.walls[cand] > 0
        npos = jnp.where(blocked, state.pos, cand).astype(jnp.int32)
        done = npos == state.goal
        reward = jnp.where(done, GOAL_REWARD, 0.0).astype(jnp.float32)
        ns = MazeState(npos, state.goal, state.walls)
        return Timestep(ns, self._obs(ns), reward, done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: MazeState):
        return grid_scene(self._obs(state), self.n, self.n, INTENS)

    def render(self, state: MazeState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
