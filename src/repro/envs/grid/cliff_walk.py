"""CliffWalk — Sutton & Barto's cliff, procedurally extended per episode.

The classic 4×12 cliff (bottom row between start and goal) plus random
extra cliff cells sampled each episode. Solvability is structural: a random
"safe row" `k` is drawn per episode and column 0, row k and the last column
are kept clear, so the up-across-down route always exists while the interior
hazard field changes every reset.

Stepping into a cliff cell teleports the agent back to start with reward
-100 (episode continues — Gym semantics); every other step is -1 and only
the goal terminates. Observation: cell-code grid, `MultiDiscrete`:
0 free, 1 cliff, 2 goal, 3 agent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Discrete, MultiDiscrete
from repro.envs.grid.common import grid_scene, move_deltas

CLIFF_P = 0.25         # interior extra-cliff probability (off the safe rails)
CLIFF_REWARD = -100.0
STEP_REWARD = -1.0
INTENS = (0.25, 0.0, 0.8, 1.0)   # free, cliff (dark), goal, agent


class CliffWalkState(NamedTuple):
    pos: jax.Array     # () int32 cell index
    cliff: jax.Array   # (n_rows*n_cols,) int32 in {0, 1}


class CliffWalk(Env):
    def __init__(self, n_rows: int = 4, n_cols: int = 12):
        self.n_rows = n_rows
        self.n_cols = n_cols
        self.m = n_rows * n_cols
        self.start = (n_rows - 1) * n_cols      # bottom-left
        self.observation_space = MultiDiscrete((4,) * self.m)
        self.action_space = Discrete(4)
        self.frame_shape = (84, 84)
        self.reward_range = (CLIFF_REWARD, STEP_REWARD)

    def reset(self, key):
        ku, kk = jax.random.split(key)
        u = jax.random.uniform(ku, (self.m,))
        safe_row = jax.random.randint(kk, (), 0, self.n_rows - 1)
        idx = jnp.arange(self.m)
        r, c = idx // self.n_cols, idx % self.n_cols
        safe = (c == 0) | (c == self.n_cols - 1) | (r == safe_row)
        bottom = (r == self.n_rows - 1) & (c > 0) & (c < self.n_cols - 1)
        cliff = jnp.where(safe, 0, (bottom | (u < CLIFF_P)).astype(jnp.int32))
        state = CliffWalkState(jnp.asarray(self.start, jnp.int32), cliff)
        return state, self._obs(state)

    def _obs(self, s: CliffWalkState):
        idx = jnp.arange(self.m)
        codes = jnp.where(idx == s.pos, 3,
                          jnp.where(idx == self.m - 1, 2, s.cliff))
        return codes.astype(jnp.int32)

    def step(self, state: CliffWalkState, action, key):
        dr, dc = move_deltas(action)
        r, c = state.pos // self.n_cols, state.pos % self.n_cols
        nr = jnp.clip(r + dr, 0, self.n_rows - 1)
        nc = jnp.clip(c + dc, 0, self.n_cols - 1)
        npos = (nr * self.n_cols + nc).astype(jnp.int32)
        fell = state.cliff[npos] > 0
        goal = npos == self.m - 1
        pos = jnp.where(fell, self.start, npos).astype(jnp.int32)
        reward = jnp.where(fell, CLIFF_REWARD, STEP_REWARD).astype(jnp.float32)
        ns = CliffWalkState(pos, state.cliff)
        return Timestep(ns, self._obs(ns), reward, goal, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: CliffWalkState):
        return grid_scene(self._obs(state), self.n_rows, self.n_cols, INTENS)

    def render(self, state: CliffWalkState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
