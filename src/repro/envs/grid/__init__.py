"""Procedural gridworld suite — per-episode level generation (Jumanji-style
scalable scenarios on the CaiRL execution model).

Four games, all pure-JAX element-wise dynamics with the *level itself*
(hole/cliff/wall layout, goal position, food priorities) resampled inside
`reset(key)` — which means the AutoReset key chain regenerates levels on
device, bit-identically between the vmap and fused megastep paths (see
envs/grid/common.py and kernels/envstep/specs.py).
"""
from repro.envs.grid.cliff_walk import CliffWalk
from repro.envs.grid.frozen_lake import FrozenLake
from repro.envs.grid.maze import Maze
from repro.envs.grid.snake import Snake

__all__ = ["CliffWalk", "FrozenLake", "Maze", "Snake"]
