"""FrozenLake — procedurally-generated per episode.

Gym's FrozenLake with the map itself resampled on every `reset(key)`: each
episode draws a fresh hole field (density `HOLE_P`) and carves a random
monotone path start -> goal so the level is always solvable (Jumanji-style
per-episode level generation, but on the AutoReset key chain so the fused
megastep path regenerates levels bit-identically to vmap).

Deterministic transitions (no slip) keep the dynamics action-deterministic,
which is what lets the megastep kernel fuse them (kernels/envstep/specs.py
mirrors `step` operation-for-operation). Observation is the full cell-code
grid — the layout IS the observation — as a `MultiDiscrete` vector:
0 frozen, 1 hole, 2 goal, 3 agent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Discrete, MultiDiscrete
from repro.envs.grid.common import carve_path, grid_scene, move_deltas

HOLE_P = 0.3          # per-cell hole probability (off the carved path)
GOAL_REWARD = 1.0
INTENS = (0.25, 0.0, 0.8, 1.0)   # frozen, hole (dark), goal, agent


class FrozenLakeState(NamedTuple):
    pos: jax.Array     # () int32 cell index
    holes: jax.Array   # (n*n,) int32 in {0, 1} — this episode's level


class FrozenLake(Env):
    def __init__(self, n: int = 4):
        self.n = n
        self.m = n * n
        self.observation_space = MultiDiscrete((4,) * self.m)
        self.action_space = Discrete(4)
        self.frame_shape = (84, 84)
        self.reward_range = (0.0, GOAL_REWARD)

    def reset(self, key):
        kh, kp = jax.random.split(key)
        u = jax.random.uniform(kh, (self.m,))
        path = carve_path(kp, self.n, self.n, self.n - 1, self.n - 1)
        holes = ((u < HOLE_P) & (path == 0)).astype(jnp.int32)
        state = FrozenLakeState(jnp.asarray(0, jnp.int32), holes)
        return state, self._obs(state)

    def _obs(self, s: FrozenLakeState):
        idx = jnp.arange(self.m)
        codes = jnp.where(idx == s.pos, 3,
                          jnp.where(idx == self.m - 1, 2, s.holes))
        return codes.astype(jnp.int32)

    def step(self, state: FrozenLakeState, action, key):
        n = self.n
        dr, dc = move_deltas(action)
        r, c = state.pos // n, state.pos % n
        nr = jnp.clip(r + dr, 0, n - 1)
        nc = jnp.clip(c + dc, 0, n - 1)
        npos = (nr * n + nc).astype(jnp.int32)
        hole = state.holes[npos] > 0
        goal = npos == self.m - 1
        done = hole | goal
        reward = jnp.where(goal, GOAL_REWARD, 0.0).astype(jnp.float32)
        ns = FrozenLakeState(npos, state.holes)
        return Timestep(ns, self._obs(ns), reward, done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: FrozenLakeState):
        return grid_scene(self._obs(state), self.n, self.n, INTENS)

    def render(self, state: FrozenLakeState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
