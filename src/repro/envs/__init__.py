"""Built-in environments. Importing this module registers the Gym-named ids.

Registered ids mirror Gym's, with Gym's default TimeLimit wrapping, so
`cairl.make("CartPole-v1")` is behaviourally a drop-in (paper Listing 2).
"""
from repro.core.registry import register
from repro.core.wrappers import FrameStack, ObsToPixels, TimeLimit
from repro.envs.arcade import Breakout, Pong
from repro.envs.classic import Acrobot, CartPole, MountainCar, Pendulum
from repro.envs.grid import CliffWalk, FrozenLake, Maze, Snake
from repro.envs.multitask import Multitask
from repro.envs.puzzle import LightsOut

register("CartPole-v1", lambda **kw: TimeLimit(CartPole(**kw), 500))
register("Acrobot-v1", lambda **kw: TimeLimit(Acrobot(**kw), 500))
register("MountainCar-v0", lambda **kw: TimeLimit(MountainCar(**kw), 200))
register("Pendulum-v1", lambda **kw: TimeLimit(Pendulum(**kw), 200))
register("Multitask-v0", lambda **kw: TimeLimit(Multitask(**kw), 1000))
register("LightsOut-v0", lambda **kw: TimeLimit(LightsOut(**kw), 100))

# Arcade pixel games (paper §IV-C): observations are 4 stacked 84×84 frames
# rendered on device by kernels/raster — the raw-pixels mode end to end.
register("Pong-v0",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(Pong(**kw), 1000)), 4))
register("Breakout-v0",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(Breakout(**kw), 1000)),
                                 4))

# Procedural gridworld suite (envs/grid): the level layout is regenerated
# per episode from the AutoReset key chain. `-v0` ids observe the cell-code
# grid (the layout IS the observation, MultiDiscrete); `-px` ids observe 4
# stacked 84×84 on-device renders of the same scene (arcade pixel pipeline).
register("FrozenLake-v0", lambda **kw: TimeLimit(FrozenLake(**kw), 100))
register("CliffWalk-v0", lambda **kw: TimeLimit(CliffWalk(**kw), 100))
register("Snake-v0", lambda **kw: TimeLimit(Snake(**kw), 200))
register("Maze-v0", lambda **kw: TimeLimit(Maze(**kw), 200))
register("FrozenLake-px",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(FrozenLake(**kw), 100)),
                                 4))
register("CliffWalk-px",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(CliffWalk(**kw), 100)),
                                 4))
register("Snake-px",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(Snake(**kw), 200)), 4))
register("Maze-px",
         lambda **kw: FrameStack(ObsToPixels(TimeLimit(Maze(**kw), 200)), 4))

# Raw (unwrapped) variants for custom composition, mirroring CaiRL's
# template-composition style: Flatten<TimeLimit<200, CartPoleEnv>>().
# Arcade `-raw` ids expose the state-vector ("virtual Flash memory") obs.
register("CartPole-raw", CartPole)
register("Acrobot-raw", Acrobot)
register("MountainCar-raw", MountainCar)
register("Pendulum-raw", Pendulum)
register("Multitask-raw", Multitask)
register("LightsOut-raw", LightsOut)
register("Pong-raw", Pong)
register("Breakout-raw", Breakout)
register("FrozenLake-raw", FrozenLake)
register("CliffWalk-raw", CliffWalk)
register("Snake-raw", Snake)
register("Maze-raw", Maze)

__all__ = ["Acrobot", "Breakout", "CartPole", "CliffWalk", "FrozenLake",
           "MountainCar", "Maze", "Pendulum", "Multitask", "LightsOut",
           "Pong", "Snake"]
