"""Built-in environments. Importing this module registers the Gym-named ids.

One `register_family` call per family (core/registry.py): the declarative
`EnvSpec` pipeline derives the `-v<N>` (Gym's default TimeLimit wrapping, so
`cairl.make("CartPole-v1")` is behaviourally a drop-in — paper Listing 2),
`-px` (arcade pixel pipeline) and `-raw` (bare core for custom composition,
CaiRL's `Flatten<TimeLimit<200, CartPoleEnv>>()` template style) variants.
Arcade `-v0` ids *observe* pixels (4 stacked 84×84 on-device renders, paper
§IV-C); their `-raw` twins expose the state vector ("virtual Flash memory").
"""
from repro.core.registry import register_family
from repro.envs.arcade import Breakout, Pong
from repro.envs.classic import Acrobot, CartPole, MountainCar, Pendulum
from repro.envs.grid import CliffWalk, FrozenLake, Maze, Snake
from repro.envs.multitask import Multitask
from repro.envs.puzzle import LightsOut

# Classic control (Gym ids, Gym's default TimeLimits).
register_family("CartPole", CartPole, max_steps=500, version=1,
                tags=("classic",))
register_family("Acrobot", Acrobot, max_steps=500, version=1,
                tags=("classic",))
register_family("MountainCar", MountainCar, max_steps=200, tags=("classic",))
register_family("Pendulum", Pendulum, max_steps=200, version=1,
                tags=("classic",))

# The paper's flagship Flash game (§IV-C) and puzzle runtime (§IV-D).
register_family("Multitask", Multitask, max_steps=1000, tags=("flash",))
register_family("LightsOut", LightsOut, max_steps=100, tags=("puzzle",))

# Arcade pixel games (paper §IV-C): observations are 4 stacked 84×84 frames
# rendered on device by kernels/raster — the raw-pixels mode end to end.
register_family("Pong", Pong, max_steps=1000, obs="pixels", tags=("arcade",))
register_family("Breakout", Breakout, max_steps=1000, obs="pixels",
                tags=("arcade",))

# Procedural gridworld suite (envs/grid): the level layout is regenerated
# per episode from the AutoReset key chain. `-v0` ids observe the cell-code
# grid (the layout IS the observation, MultiDiscrete); `-px` ids observe 4
# stacked 84×84 on-device renders of the same scene (arcade pixel pipeline).
register_family("FrozenLake", FrozenLake, max_steps=100, pixel_variant=True,
                tags=("grid",))
register_family("CliffWalk", CliffWalk, max_steps=100, pixel_variant=True,
                tags=("grid",))
register_family("Snake", Snake, max_steps=200, pixel_variant=True,
                tags=("grid",))
register_family("Maze", Maze, max_steps=200, pixel_variant=True,
                tags=("grid",))

__all__ = ["Acrobot", "Breakout", "CartPole", "CliffWalk", "FrozenLake",
           "MountainCar", "Maze", "Pendulum", "Multitask", "LightsOut",
           "Pong", "Snake"]
