"""MountainCar-v0, Gym-faithful, fully traceable."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

MIN_POS = -1.2
MAX_POS = 0.6
MAX_SPEED = 0.07
GOAL_POS = 0.5
GOAL_VEL = 0.0
FORCE = 0.001
GRAVITY = 0.0025


class MountainCarState(NamedTuple):
    position: jax.Array
    velocity: jax.Array


def _height(x):
    return jnp.sin(3 * x) * 0.45 + 0.55


class MountainCar(Env):
    observation_space = Box(low=(MIN_POS, -MAX_SPEED), high=(MAX_POS, MAX_SPEED), shape=(2,))
    action_space = Discrete(3)
    frame_shape = (84, 84)

    def reset(self, key):
        pos = jax.random.uniform(key, (), minval=-0.6, maxval=-0.4)
        state = MountainCarState(pos, jnp.asarray(0.0))
        return state, self._obs(state)

    @staticmethod
    def _obs(s):
        return jnp.stack([s.position, s.velocity]).astype(jnp.float32)

    def step(self, state: MountainCarState, action, key):
        velocity = state.velocity + (action - 1) * FORCE + jnp.cos(3 * state.position) * (-GRAVITY)
        velocity = jnp.clip(velocity, -MAX_SPEED, MAX_SPEED)
        position = jnp.clip(state.position + velocity, MIN_POS, MAX_POS)
        velocity = jnp.where((position <= MIN_POS) & (velocity < 0), 0.0, velocity)
        ns = MountainCarState(position, velocity)
        done = (position >= GOAL_POS) & (velocity >= GOAL_VEL)
        return Timestep(ns, self._obs(ns), jnp.asarray(-1.0, jnp.float32), done, {})

    def scene(self, state: MountainCarState):
        def to_xy(p):
            x = (p - MIN_POS) / (MAX_POS - MIN_POS) * 0.8 + 0.1
            y = 0.9 - _height(p) * 0.6
            return x, y

        # terrain: 6 chained segments
        ps = jnp.linspace(MIN_POS, MAX_POS, 7)
        xs, ys = to_xy(ps)
        terrain = jnp.stack(
            [jnp.stack([xs[i], ys[i], xs[i + 1], ys[i + 1], jnp.asarray(0.006)]) for i in range(6)]
        )
        cx, cy = to_xy(state.position)
        gx, gy = to_xy(jnp.asarray(GOAL_POS))
        extra = jnp.stack([
            jnp.stack([cx, cy - 0.03, cx, cy - 0.03, jnp.asarray(0.03)]),            # car dot
            jnp.stack([gx, gy - 0.10, gx, gy, jnp.asarray(0.008)]),                  # flag pole
        ])
        segs = jnp.concatenate([terrain, extra])
        intens = jnp.asarray([0.35] * 6 + [1.0, 0.7], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: MountainCarState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
