"""Acrobot-v1, Gym-faithful (book dynamics, RK4), fully traceable."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

DT = 0.2
L1 = 1.0
L2 = 1.0
M1 = 1.0
M2 = 1.0
LC1 = 0.5
LC2 = 0.5
I1 = 1.0
I2 = 1.0
G = 9.8
MAX_VEL_1 = 4 * jnp.pi
MAX_VEL_2 = 9 * jnp.pi
TORQUES = jnp.asarray([-1.0, 0.0, 1.0])


class AcrobotState(NamedTuple):
    theta1: jax.Array
    theta2: jax.Array
    dtheta1: jax.Array
    dtheta2: jax.Array


def _dsdt(s, torque):
    theta1, theta2, dtheta1, dtheta2 = s
    d1 = (
        M1 * LC1**2
        + M2 * (L1**2 + LC2**2 + 2 * L1 * LC2 * jnp.cos(theta2))
        + I1 + I2
    )
    d2 = M2 * (LC2**2 + L1 * LC2 * jnp.cos(theta2)) + I2
    phi2 = M2 * LC2 * G * jnp.cos(theta1 + theta2 - jnp.pi / 2.0)
    phi1 = (
        -M2 * L1 * LC2 * dtheta2**2 * jnp.sin(theta2)
        - 2 * M2 * L1 * LC2 * dtheta2 * dtheta1 * jnp.sin(theta2)
        + (M1 * LC1 + M2 * L1) * G * jnp.cos(theta1 - jnp.pi / 2)
        + phi2
    )
    # "book" dynamics (Gym default).
    ddtheta2 = (
        torque + d2 / d1 * phi1 - M2 * L1 * LC2 * dtheta1**2 * jnp.sin(theta2) - phi2
    ) / (M2 * LC2**2 + I2 - d2**2 / d1)
    ddtheta1 = -(d2 * ddtheta2 + phi1) / d1
    return jnp.stack([dtheta1, dtheta2, ddtheta1, ddtheta2])


def _rk4(s, torque):
    k1 = _dsdt(s, torque)
    k2 = _dsdt(s + DT / 2 * k1, torque)
    k3 = _dsdt(s + DT / 2 * k2, torque)
    k4 = _dsdt(s + DT * k3, torque)
    return s + DT / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)


def _wrap(x, lo, hi):
    return lo + jnp.mod(x - lo, hi - lo)


class Acrobot(Env):
    observation_space = Box(
        low=(-1.0, -1.0, -1.0, -1.0, -float(MAX_VEL_1), -float(MAX_VEL_2)),
        high=(1.0, 1.0, 1.0, 1.0, float(MAX_VEL_1), float(MAX_VEL_2)),
        shape=(6,),
    )
    action_space = Discrete(3)
    frame_shape = (84, 84)

    def reset(self, key):
        vals = jax.random.uniform(key, (4,), minval=-0.1, maxval=0.1)
        state = AcrobotState(vals[0], vals[1], vals[2], vals[3])
        return state, self._obs(state)

    @staticmethod
    def _obs(s: AcrobotState):
        return jnp.stack(
            [jnp.cos(s.theta1), jnp.sin(s.theta1), jnp.cos(s.theta2), jnp.sin(s.theta2), s.dtheta1, s.dtheta2]
        ).astype(jnp.float32)

    def step(self, state: AcrobotState, action, key):
        torque = TORQUES[action]
        vec = jnp.stack([state.theta1, state.theta2, state.dtheta1, state.dtheta2])
        ns = _rk4(vec, torque)
        theta1 = _wrap(ns[0], -jnp.pi, jnp.pi)
        theta2 = _wrap(ns[1], -jnp.pi, jnp.pi)
        dtheta1 = jnp.clip(ns[2], -MAX_VEL_1, MAX_VEL_1)
        dtheta2 = jnp.clip(ns[3], -MAX_VEL_2, MAX_VEL_2)
        new = AcrobotState(theta1, theta2, dtheta1, dtheta2)
        done = (-jnp.cos(theta1) - jnp.cos(theta2 + theta1)) > 1.0
        reward = jnp.where(done, 0.0, -1.0).astype(jnp.float32)
        return Timestep(new, self._obs(new), reward, done, {})

    def scene(self, state: AcrobotState):
        ox, oy = 0.5, 0.45
        x1 = ox + 0.22 * jnp.sin(state.theta1)
        y1 = oy + 0.22 * jnp.cos(state.theta1)
        x2 = x1 + 0.22 * jnp.sin(state.theta1 + state.theta2)
        y2 = y1 + 0.22 * jnp.cos(state.theta1 + state.theta2)
        segs = jnp.stack([
            jnp.stack([jnp.asarray(0.1), jnp.asarray(oy - 0.22), jnp.asarray(0.9), jnp.asarray(oy - 0.22), jnp.asarray(0.004)]),  # goal line
            jnp.stack([jnp.asarray(ox), jnp.asarray(oy), x1, y1, jnp.asarray(0.02)]),
            jnp.stack([x1, y1, x2, y2, jnp.asarray(0.02)]),
        ])
        intens = jnp.asarray([0.3, 0.8, 1.0], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: AcrobotState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
