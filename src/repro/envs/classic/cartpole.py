"""CartPole-v1, Gym-faithful dynamics, fully traceable (paper §V-A benchmark)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

# Gym constants (gym.envs.classic_control.cartpole).
GRAVITY = 9.8
MASSCART = 1.0
MASSPOLE = 0.1
TOTAL_MASS = MASSCART + MASSPOLE
LENGTH = 0.5               # half pole length
POLEMASS_LENGTH = MASSPOLE * LENGTH
FORCE_MAG = 10.0
TAU = 0.02
THETA_THRESHOLD = 12 * 2 * jnp.pi / 360
X_THRESHOLD = 2.4


class CartPoleState(NamedTuple):
    x: jax.Array
    x_dot: jax.Array
    theta: jax.Array
    theta_dot: jax.Array


class CartPole(Env):
    observation_space = Box(
        low=(-4.8, -jnp.inf, -0.418, -jnp.inf),
        high=(4.8, jnp.inf, 0.418, jnp.inf),
        shape=(4,),
    )
    action_space = Discrete(2)
    frame_shape = (84, 84)

    def reset(self, key):
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        state = CartPoleState(vals[0], vals[1], vals[2], vals[3])
        return state, self._obs(state)

    @staticmethod
    def _obs(s: CartPoleState):
        return jnp.stack([s.x, s.x_dot, s.theta, s.theta_dot]).astype(jnp.float32)

    def step(self, state: CartPoleState, action, key):
        force = jnp.where(action == 1, FORCE_MAG, -FORCE_MAG)
        costheta, sintheta = jnp.cos(state.theta), jnp.sin(state.theta)
        temp = (force + POLEMASS_LENGTH * state.theta_dot**2 * sintheta) / TOTAL_MASS
        thetaacc = (GRAVITY * sintheta - costheta * temp) / (
            LENGTH * (4.0 / 3.0 - MASSPOLE * costheta**2 / TOTAL_MASS)
        )
        xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS
        # Euler, kinematics_integrator == "euler"
        x = state.x + TAU * state.x_dot
        x_dot = state.x_dot + TAU * xacc
        theta = state.theta + TAU * state.theta_dot
        theta_dot = state.theta_dot + TAU * thetaacc
        ns = CartPoleState(x, x_dot, theta, theta_dot)
        done = (
            (jnp.abs(x) > X_THRESHOLD) | (jnp.abs(theta) > THETA_THRESHOLD)
        )
        return Timestep(ns, self._obs(ns), jnp.asarray(1.0, jnp.float32), done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: CartPoleState):
        cx = 0.5 + state.x / (2 * X_THRESHOLD) * 0.8       # track [-2.4,2.4] -> [0.1,0.9]
        cy = jnp.asarray(0.75)
        tip_x = cx + jnp.sin(state.theta) * 0.35
        tip_y = cy - jnp.cos(state.theta) * 0.35
        segs = jnp.stack([
            jnp.stack([jnp.asarray(0.05), cy + 0.05, jnp.asarray(0.95), cy + 0.05, jnp.asarray(0.006)]),  # track
            jnp.stack([cx - 0.07, cy, cx + 0.07, cy, jnp.asarray(0.035)]),                                 # cart
            jnp.stack([cx, cy, tip_x, tip_y, jnp.asarray(0.015)]),                                         # pole
        ])
        intens = jnp.asarray([0.35, 0.7, 1.0], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: CartPoleState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
