"""Pendulum-v1, Gym-faithful, fully traceable (continuous control)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box

MAX_SPEED = 8.0
MAX_TORQUE = 2.0
DT = 0.05
G = 10.0
M = 1.0
L = 1.0


def _angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


class PendulumState(NamedTuple):
    theta: jax.Array
    theta_dot: jax.Array


class Pendulum(Env):
    observation_space = Box(low=(-1.0, -1.0, -MAX_SPEED), high=(1.0, 1.0, MAX_SPEED), shape=(3,))
    action_space = Box(low=-MAX_TORQUE, high=MAX_TORQUE, shape=(1,))
    frame_shape = (84, 84)

    def reset(self, key):
        k1, k2 = jax.random.split(key)
        theta = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        theta_dot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        state = PendulumState(theta, theta_dot)
        return state, self._obs(state)

    @staticmethod
    def _obs(s):
        return jnp.stack([jnp.cos(s.theta), jnp.sin(s.theta), s.theta_dot]).astype(jnp.float32)

    def step(self, state: PendulumState, action, key):
        u = jnp.clip(jnp.reshape(action, ()), -MAX_TORQUE, MAX_TORQUE)
        th, thdot = state.theta, state.theta_dot
        costs = _angle_normalize(th) ** 2 + 0.1 * thdot**2 + 0.001 * u**2
        newthdot = thdot + (3 * G / (2 * L) * jnp.sin(th) + 3.0 / (M * L**2) * u) * DT
        newthdot = jnp.clip(newthdot, -MAX_SPEED, MAX_SPEED)
        newth = th + newthdot * DT
        ns = PendulumState(newth, newthdot)
        return Timestep(
            ns, self._obs(ns), (-costs).astype(jnp.float32), jnp.asarray(False), {}
        )

    def scene(self, state: PendulumState):
        ox, oy = 0.5, 0.5
        tx = ox + 0.35 * jnp.sin(state.theta)
        ty = oy - 0.35 * jnp.cos(state.theta)
        segs = jnp.stack([
            jnp.stack([jnp.asarray(ox), jnp.asarray(oy), tx, ty, jnp.asarray(0.025)]),
            jnp.stack([jnp.asarray(ox), jnp.asarray(oy), jnp.asarray(ox), jnp.asarray(oy), jnp.asarray(0.02)]),
        ])
        intens = jnp.asarray([1.0, 0.5], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: PendulumState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
