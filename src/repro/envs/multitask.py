"""Multitask — the paper's flagship Flash environment, re-implemented natively.

Paper §IV-C: "Multitask is an environment that provides minigames that the
agent must control concurrently. If the agent fails one of the tasks, the
game terminates. The reward function is defined as positive rewards while the
game is running and negative rewards when the game engine terminates ...
observations are either raw pixels or the virtual Flash memory, and the
action-space is discrete."

Two concurrent minigames share one Discrete(3) action (left/stay/right):
  (1) CATCH : a ball falls from the top; the paddle must be under it.
  (2) DODGE : an obstacle falls down one of three lanes; the player must not
              be in that lane when it lands.
"Virtual flash memory" observation = the 10-dim game-state vector; raw-pixel
observation = wrap with core.wrappers.ObsToPixels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

BALL_SPEED = 0.05
OBSTACLE_SPEED = 0.04
PADDLE_SPEED = 0.07
CATCH_RADIUS = 0.13
ALIVE_REWARD = 1.0
FAIL_REWARD = -10.0


class MultitaskState(NamedTuple):
    paddle_x: jax.Array     # [0, 1]
    ball_x: jax.Array       # [0, 1]
    ball_y: jax.Array       # [0, 1], 1 = bottom
    lane: jax.Array         # player lane {0,1,2}
    obs_lane: jax.Array     # obstacle lane {0,1,2}
    obs_y: jax.Array        # [0, 1]
    t: jax.Array


class Multitask(Env):
    observation_space = Box(low=0.0, high=1.0, shape=(10,))
    action_space = Discrete(3)
    frame_shape = (84, 84)

    def reset(self, key):
        k1, k2 = jax.random.split(key)
        state = MultitaskState(
            paddle_x=jnp.asarray(0.5),
            ball_x=jax.random.uniform(k1, (), minval=0.1, maxval=0.9),
            ball_y=jnp.asarray(0.0),
            lane=jnp.asarray(1, jnp.int32),
            obs_lane=jax.random.randint(k2, (), 0, 3),
            obs_y=jnp.asarray(0.0),
            t=jnp.asarray(0, jnp.int32),
        )
        return state, self._obs(state)

    @staticmethod
    def _obs(s: MultitaskState):
        lane_oh = jax.nn.one_hot(s.lane, 3)
        obs_oh = jax.nn.one_hot(s.obs_lane, 3)
        return jnp.concatenate(
            [jnp.stack([s.paddle_x, s.ball_x, s.ball_y, s.obs_y]), lane_oh, obs_oh]
        ).astype(jnp.float32)

    def step(self, state: MultitaskState, action, key):
        k_ball, k_lane = jax.random.split(key)
        move = action - 1  # {-1, 0, +1}

        # CATCH minigame.
        paddle_x = jnp.clip(state.paddle_x + move * PADDLE_SPEED, 0.05, 0.95)
        ball_y = state.ball_y + BALL_SPEED
        landing = ball_y >= 1.0
        caught = jnp.abs(state.ball_x - paddle_x) <= CATCH_RADIUS
        catch_fail = landing & ~caught
        ball_x = jnp.where(landing, jax.random.uniform(k_ball, (), minval=0.1, maxval=0.9), state.ball_x)
        ball_y = jnp.where(landing, 0.0, ball_y)

        # DODGE minigame (same action moves the lane).
        lane = jnp.clip(state.lane + move, 0, 2)
        obs_y = state.obs_y + OBSTACLE_SPEED
        obs_landing = obs_y >= 1.0
        dodge_fail = obs_landing & (state.obs_lane == lane)
        obs_lane = jnp.where(obs_landing, jax.random.randint(k_lane, (), 0, 3), state.obs_lane)
        obs_y = jnp.where(obs_landing, 0.0, obs_y)

        done = catch_fail | dodge_fail
        reward = jnp.where(done, FAIL_REWARD, ALIVE_REWARD).astype(jnp.float32)
        ns = MultitaskState(paddle_x, ball_x, ball_y, lane, obs_lane, obs_y, state.t + 1)
        return Timestep(ns, self._obs(ns), reward, done, {})

    def scene(self, state: MultitaskState):
        # Left half: catch. Right half: dodge (3 lanes).
        px = 0.05 + state.paddle_x * 0.40
        bx = 0.05 + state.ball_x * 0.40
        lane_x = 0.55 + (state.lane.astype(jnp.float32) + 0.5) * 0.40 / 3
        obs_x = 0.55 + (state.obs_lane.astype(jnp.float32) + 0.5) * 0.40 / 3
        segs = jnp.stack([
            jnp.stack([jnp.asarray(0.5), jnp.asarray(0.0), jnp.asarray(0.5), jnp.asarray(1.0), jnp.asarray(0.004)]),  # divider
            jnp.stack([px - 0.06, jnp.asarray(0.95), px + 0.06, jnp.asarray(0.95), jnp.asarray(0.02)]),               # paddle
            jnp.stack([bx, state.ball_y, bx, state.ball_y, jnp.asarray(0.025)]),                                       # ball
            jnp.stack([lane_x, jnp.asarray(0.95), lane_x, jnp.asarray(0.95), jnp.asarray(0.03)]),                      # player
            jnp.stack([obs_x, state.obs_y, obs_x, state.obs_y, jnp.asarray(0.03)]),                                    # obstacle
        ])
        intens = jnp.asarray([0.25, 0.8, 1.0, 0.8, 1.0], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: MultitaskState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
