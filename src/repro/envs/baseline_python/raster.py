"""NumPy software rasteriser for the baseline ("AI Gym"-style) envs.

Single-frame, host-side. Mirrors the capsule semantics of
repro.kernels.raster so rendered output is comparable; the point of the
baseline is the *execution model* (one interpreted step at a time, one frame
at a time), which is what the paper benchmarks against.
"""
from __future__ import annotations

import numpy as np

_EPS = 1e-8


def rasterize_np(segs, intens, h: int, w: int) -> np.ndarray:
    """segs: (S, 5) [x0,y0,x1,y1,r]; intens: (S,) -> (H, W) float32."""
    py = (np.arange(h, dtype=np.float32)[:, None] + 0.5) / h
    px = (np.arange(w, dtype=np.float32)[None, :] + 0.5) / w
    softness = 1.0 / h
    fb = np.zeros((h, w), np.float32)
    for (x0, y0, x1, y1, r), inten in zip(segs, intens):
        dx, dy = x1 - x0, y1 - y0
        l2 = max(dx * dx + dy * dy, _EPS)
        t = np.clip(((px - x0) * dx + (py - y0) * dy) / l2, 0.0, 1.0)
        cx, cy = x0 + t * dx, y0 + t * dy
        d = np.sqrt((px - cx) ** 2 + (py - cy) ** 2)
        cov = np.clip((r - d) / softness + 0.5, 0.0, 1.0) * inten
        np.maximum(fb, cov, out=fb)
    return fb
