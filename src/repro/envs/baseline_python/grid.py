"""Pure-Python gridworld baselines — the interpreted comparator twins.

Same per-episode procedural generation (python RNG instead of threefry, so
distributions match but not bit-streams) and the *same dynamics given the
same state*: `set_state` copies a compiled env's state pytree so the
conformance sweep (tests/test_conformance.py) can assert step-for-step
trajectory equality between the interpreted and compiled execution models.

SnakePy computes the food chain in float32 numpy on purpose: the compiled
env places food by minimising frac(prio + k·φ) in f32 (envs/grid/snake.py),
and doing the same math in python f64 could round a near-tie the other way.
"""
from __future__ import annotations

import numpy as np

from repro.envs.baseline_python.classic import _BaselineEnv
from repro.envs.grid.cliff_walk import CLIFF_P, CLIFF_REWARD, STEP_REWARD
from repro.envs.grid.cliff_walk import INTENS as CLIFF_INTENS
from repro.envs.grid.frozen_lake import GOAL_REWARD, HOLE_P
from repro.envs.grid.frozen_lake import INTENS as LAKE_INTENS
from repro.envs.grid.maze import INTENS as MAZE_INTENS
from repro.envs.grid.maze import WALL_P
from repro.envs.grid.snake import DEATH_REWARD, EAT_REWARD, PHI
from repro.envs.grid.snake import INTENS as SNAKE_INTENS

# Gym FrozenLake action order (envs/grid/common.move_deltas): (dr, dc).
_MOVES = {0: (0, -1), 1: (1, 0), 2: (0, 1), 3: (-1, 0)}


def _carve(rng, n_cols, goal_r, goal_c):
    """Python twin of common.carve_path: random monotone path (0,0)->goal."""
    r = c = 0
    cells = {0}
    while r != goal_r or c != goal_c:
        need_r, need_c = goal_r - r, goal_c - c
        if need_r != 0 and (need_c == 0 or rng.random() < 0.5):
            r += 1 if need_r > 0 else -1
        else:
            c += 1 if need_c > 0 else -1
        cells.add(r * n_cols + c)
    return cells


class _GridPy(_BaselineEnv):
    n_actions = 4
    n_rows: int
    n_cols: int
    intens: tuple

    def _codes(self):
        raise NotImplementedError

    def scene(self):
        codes = self._codes()
        segs, intens = [], []
        rad = 0.35 / max(self.n_rows, self.n_cols)
        for i, code in enumerate(codes):
            cx = (i % self.n_cols + 0.5) / self.n_cols
            cy = (i // self.n_cols + 0.5) / self.n_rows
            segs.append([cx, cy, cx, cy, rad])
            intens.append(self.intens[code])
        return segs, intens

    def _move(self, pos, action):
        r, c = divmod(pos, self.n_cols)
        dr, dc = _MOVES[int(action)]
        nr = max(min(r + dr, self.n_rows - 1), 0)
        nc = max(min(c + dc, self.n_cols - 1), 0)
        return nr * self.n_cols + nc


class FrozenLakePy(_GridPy):
    n_rows = n_cols = 4
    intens = LAKE_INTENS
    max_steps = 100

    def reset(self):
        m = self.n_rows * self.n_cols
        path = _carve(self._rng, self.n_cols, self.n_rows - 1, self.n_cols - 1)
        self.holes = [0 if i in path else int(self._rng.random() < HOLE_P)
                      for i in range(m)]
        self.pos = 0
        self.steps = 0
        return self._codes()

    def set_state(self, state):
        self.pos = int(state.pos)
        self.holes = [int(h) for h in np.asarray(state.holes)]
        self.steps = 0

    def _codes(self):
        m = self.n_rows * self.n_cols
        return [3 if i == self.pos else (2 if i == m - 1 else self.holes[i])
                for i in range(m)]

    def step(self, action):
        m = self.n_rows * self.n_cols
        self.pos = self._move(self.pos, action)
        goal = self.pos == m - 1
        terminal = goal or self.holes[self.pos] > 0
        self.steps += 1
        truncated = not terminal and self.steps >= self.max_steps
        reward = GOAL_REWARD if goal else 0.0
        return self._codes(), reward, terminal or truncated, \
            {"truncated": truncated}


class CliffWalkPy(_GridPy):
    n_rows, n_cols = 4, 12
    intens = CLIFF_INTENS
    max_steps = 100

    def reset(self):
        m = self.n_rows * self.n_cols
        safe_row = self._rng.randrange(self.n_rows - 1)
        self.cliff = []
        for i in range(m):
            r, c = divmod(i, self.n_cols)
            safe = c == 0 or c == self.n_cols - 1 or r == safe_row
            bottom = r == self.n_rows - 1 and 0 < c < self.n_cols - 1
            self.cliff.append(
                0 if safe else int(bottom or self._rng.random() < CLIFF_P))
        self.pos = (self.n_rows - 1) * self.n_cols
        self.steps = 0
        return self._codes()

    def set_state(self, state):
        self.pos = int(state.pos)
        self.cliff = [int(x) for x in np.asarray(state.cliff)]
        self.steps = 0

    def _codes(self):
        m = self.n_rows * self.n_cols
        return [3 if i == self.pos else (2 if i == m - 1 else self.cliff[i])
                for i in range(m)]

    def step(self, action):
        m = self.n_rows * self.n_cols
        npos = self._move(self.pos, action)
        fell = self.cliff[npos] > 0
        goal = npos == m - 1
        self.pos = (self.n_rows - 1) * self.n_cols if fell else npos
        self.steps += 1
        truncated = not goal and self.steps >= self.max_steps
        reward = CLIFF_REWARD if fell else STEP_REWARD
        return self._codes(), reward, goal or truncated, \
            {"truncated": truncated}


class MazePy(_GridPy):
    n_rows = n_cols = 8
    intens = MAZE_INTENS
    max_steps = 200

    def reset(self):
        m = self.n_rows * self.n_cols
        self.goal = self._rng.randrange(m // 2, m)
        path = _carve(self._rng, self.n_cols, self.goal // self.n_cols,
                      self.goal % self.n_cols)
        self.walls = [0 if i in path else int(self._rng.random() < WALL_P)
                      for i in range(m)]
        self.pos = 0
        self.steps = 0
        return self._codes()

    def set_state(self, state):
        self.pos = int(state.pos)
        self.goal = int(state.goal)
        self.walls = [int(w) for w in np.asarray(state.walls)]
        self.steps = 0

    def _codes(self):
        m = self.n_rows * self.n_cols
        return [3 if i == self.pos else (2 if i == self.goal else self.walls[i])
                for i in range(m)]

    def step(self, action):
        cand = self._move(self.pos, action)
        if not self.walls[cand]:
            self.pos = cand
        done = self.pos == self.goal
        self.steps += 1
        truncated = not done and self.steps >= self.max_steps
        reward = 1.0 if done else 0.0
        return self._codes(), reward, done or truncated, \
            {"truncated": truncated}


class SnakePy(_GridPy):
    n_rows = n_cols = 6
    intens = SNAKE_INTENS
    max_steps = 200

    def _place_food(self, k):
        # f32 twin of envs/grid/snake.place_food — see module docstring.
        m = self.n_rows * self.n_cols
        vals = self.prio + np.float32(k) * np.float32(PHI)
        vals = vals - np.floor(vals)
        free = (self.ages == 0) & (np.arange(m) != self.head)
        v = np.where(free, vals, np.float32(2.0))
        vmin = v.min()
        return int(np.min(np.where(v == vmin, np.arange(m), m)))

    def reset(self):
        m = self.n_rows * self.n_cols
        self.prio = np.asarray([self._rng.random() for _ in range(m)],
                               np.float32)
        self.head = (self.n_rows // 2) * self.n_cols + self.n_cols // 2
        self.ages = np.zeros((m,), np.int64)
        self.ages[self.head] = 1
        self.length = 1
        self.eaten = 0
        self.food = self._place_food(0)
        self.steps = 0
        return self._codes()

    def set_state(self, state):
        self.prio = np.asarray(state.prio, np.float32)
        self.head = int(state.head)
        self.ages = np.asarray(state.ages, np.int64).copy()
        self.length = int(state.length)
        self.eaten = int(state.eaten)
        self.food = int(state.food)
        self.steps = 0

    def _codes(self):
        m = self.n_rows * self.n_cols
        return [2 if i == self.head else
                (1 if self.ages[i] > 0 else (3 if i == self.food else 0))
                for i in range(m)]

    def step(self, action):
        m = self.n_rows * self.n_cols
        r, c = divmod(self.head, self.n_cols)
        dr, dc = _MOVES[int(action)]
        nr, nc = r + dr, c + dc
        inb = 0 <= nr < self.n_rows and 0 <= nc < self.n_cols
        cand = (max(min(nr, self.n_rows - 1), 0) * self.n_cols
                + max(min(nc, self.n_cols - 1), 0))
        eat = inb and cand == self.food
        if not eat:
            self.ages = np.maximum(self.ages - 1, 0)
        die = not inb or self.ages[cand] > 0
        self.length += int(eat)
        self.ages[cand] = self.length
        self.head = cand
        win = self.length >= m
        done = die or win
        if eat:
            self.eaten += 1
            if not done:
                self.food = self._place_food(self.eaten)
        self.steps += 1
        truncated = not done and self.steps >= self.max_steps
        reward = EAT_REWARD * eat + DEATH_REWARD * die
        return self._codes(), reward, done or truncated, \
            {"truncated": truncated}
