"""Pure-Python arcade baselines — the interpreted comparator for Pong/Breakout.

Same dynamics constants and operation order as the compiled arcade envs
(envs/arcade), one interpreted step per call, software rendering via the
NumPy rasteriser — exactly the execution model Fig. 1 measures against.
A 1000-step time limit matches the registered `-v0` wrapping.
"""
from __future__ import annotations

import math

from repro.envs.arcade.breakout import (
    BALL_VX0, BALL_VY0, BRICK_COLS, BRICK_H, BRICK_ROWS, BRICK_TOP,
    CLEAR_BONUS, MAX_VX)
from repro.envs.arcade.breakout import PADDLE_HALF as BK_PADDLE_HALF
from repro.envs.arcade.breakout import PADDLE_SPEED as BK_PADDLE_SPEED
from repro.envs.arcade.breakout import PADDLE_Y
from repro.envs.arcade.breakout import SPIN as BK_SPIN
from repro.envs.arcade.pong import (
    BALL_SPEED_X, MAX_VY, OPP_SPEED, OPP_X, PADDLE_HALF, PADDLE_SPEED,
    PLAYER_X, SPIN)
from repro.envs.baseline_python.classic import _BaselineEnv

MAX_STEPS = 1000


def _clip(x, lo, hi):
    return lo if x < lo else hi if x > hi else x


class PongPy(_BaselineEnv):
    n_actions = 3

    def reset(self):
        self.ball_x = 0.5
        self.ball_y = self._rng.uniform(0.3, 0.7)
        self.ball_vx = BALL_SPEED_X if self._rng.random() < 0.5 else -BALL_SPEED_X
        self.ball_vy = self._rng.uniform(-0.02, 0.02)
        self.player_y = 0.5
        self.opp_y = 0.5
        self.steps = 0
        return self._obs()

    def _obs(self):
        return [self.ball_x, self.ball_y, self.ball_vx, self.ball_vy,
                self.player_y, self.opp_y]

    def step(self, action):
        move = action - 1
        self.player_y = _clip(self.player_y + move * PADDLE_SPEED,
                              PADDLE_HALF, 1.0 - PADDLE_HALF)
        self.opp_y = _clip(self.opp_y + _clip(self.ball_y - self.opp_y,
                                              -OPP_SPEED, OPP_SPEED),
                           PADDLE_HALF, 1.0 - PADDLE_HALF)
        nx = self.ball_x + self.ball_vx
        ny = self.ball_y + self.ball_vy
        vx, vy = self.ball_vx, self.ball_vy
        if ny < 0.0 or ny > 1.0:
            vy = -vy
            ny = -ny if ny < 0.0 else 2.0 - ny
        if self.ball_x < PLAYER_X <= nx and abs(ny - self.player_y) <= PADDLE_HALF:
            vy = _clip(vy + (ny - self.player_y) * SPIN, -MAX_VY, MAX_VY)
            vx = -vx
            nx = 2.0 * PLAYER_X - nx
        if self.ball_x > OPP_X >= nx and abs(ny - self.opp_y) <= PADDLE_HALF:
            vy = _clip(vy + (ny - self.opp_y) * SPIN, -MAX_VY, MAX_VY)
            vx = -vx
            nx = 2.0 * OPP_X - nx
        self.ball_x, self.ball_y, self.ball_vx, self.ball_vy = nx, ny, vx, vy
        self.steps += 1
        reward = float(nx < 0.0) - float(nx > 1.0)
        terminal = nx < 0.0 or nx > 1.0
        truncated = not terminal and self.steps >= MAX_STEPS
        return self._obs(), reward, terminal or truncated, {"truncated": truncated}

    def scene(self):
        return [
            [0.5, 0.02, 0.5, 0.98, 0.004],
            [OPP_X, self.opp_y - PADDLE_HALF, OPP_X,
             self.opp_y + PADDLE_HALF, 0.02],
            [PLAYER_X, self.player_y - PADDLE_HALF, PLAYER_X,
             self.player_y + PADDLE_HALF, 0.02],
            [self.ball_x, self.ball_y, self.ball_x, self.ball_y, 0.022],
        ], [0.25, 0.7, 1.0, 0.9]


class BreakoutPy(_BaselineEnv):
    n_actions = 3

    def reset(self):
        self.ball_x = self._rng.uniform(0.2, 0.8)
        self.ball_y = 0.55
        self.ball_vx = BALL_VX0 if self._rng.random() < 0.5 else -BALL_VX0
        self.ball_vy = BALL_VY0
        self.paddle_x = 0.5
        self.bricks = [[1] * BRICK_COLS for _ in range(BRICK_ROWS)]
        self.steps = 0
        return self._obs()

    def _obs(self):
        flat = [float(b) for row in self.bricks for b in row]
        return [self.ball_x, self.ball_y, self.ball_vx, self.ball_vy,
                self.paddle_x] + flat

    def step(self, action):
        move = action - 1
        self.paddle_x = _clip(self.paddle_x + move * BK_PADDLE_SPEED,
                              BK_PADDLE_HALF, 1.0 - BK_PADDLE_HALF)
        nx = self.ball_x + self.ball_vx
        ny = self.ball_y + self.ball_vy
        vx, vy = self.ball_vx, self.ball_vy
        if nx < 0.0 or nx > 1.0:
            vx = -vx
            nx = -nx if nx < 0.0 else 2.0 - nx
        if ny < 0.0:
            vy = -vy
            ny = -ny
        if (self.ball_y < PADDLE_Y <= ny
                and abs(nx - self.paddle_x) <= BK_PADDLE_HALF):
            vx = _clip(vx + (nx - self.paddle_x) * BK_SPIN, -MAX_VX, MAX_VX)
            vy = -vy
            ny = 2.0 * PADDLE_Y - ny
        reward = 0.0
        if BRICK_TOP <= ny < BRICK_TOP + BRICK_ROWS * BRICK_H:
            r = int(math.floor((ny - BRICK_TOP) / BRICK_H))
            c = int(math.floor(nx * BRICK_COLS))
            if 0 <= r < BRICK_ROWS and 0 <= c < BRICK_COLS and self.bricks[r][c]:
                self.bricks[r][c] = 0
                vy = -vy
                reward = 1.0
        self.ball_x, self.ball_y, self.ball_vx, self.ball_vy = nx, ny, vx, vy
        self.steps += 1
        cleared = not any(b for row in self.bricks for b in row)
        if cleared:
            reward += CLEAR_BONUS
        terminal = cleared or ny > 1.0
        truncated = not terminal and self.steps >= MAX_STEPS
        return self._obs(), reward, terminal or truncated, {"truncated": truncated}

    def scene(self):
        segs, intens = [], []
        for r in range(BRICK_ROWS):
            for c in range(BRICK_COLS):
                bx = (c + 0.5) / BRICK_COLS
                by = BRICK_TOP + (r + 0.5) * BRICK_H
                segs.append([bx - 0.35 / BRICK_COLS, by,
                             bx + 0.35 / BRICK_COLS, by, 0.016])
                intens.append(self.bricks[r][c] * 0.7)
        segs.append([self.paddle_x - BK_PADDLE_HALF, PADDLE_Y,
                     self.paddle_x + BK_PADDLE_HALF, PADDLE_Y, 0.018])
        intens.append(1.0)
        segs.append([self.ball_x, self.ball_y, self.ball_x, self.ball_y, 0.02])
        intens.append(0.9)
        return segs, intens
