"""Pure-Python classic-control baselines — the "AI Gym" comparator.

Faithful ports of Gym's classic_control envs in interpreted Python (floats +
math, one step per call), exactly the execution model whose overhead the
paper measures (Fig. 1: CaiRL is ~5× faster console, ~80× faster rendering).
These share dynamics constants with the compiled envs so cross-validation
tests can assert trajectory equality.
"""
from __future__ import annotations

import math
import random

import numpy as np

from repro.envs.baseline_python.raster import rasterize_np

FRAME = (84, 84)


class _BaselineEnv:
    """Classic-Gym-style stateful API."""

    n_actions: int | None = None  # discrete envs

    def __init__(self):
        self._rng = random.Random(0)

    def seed(self, seed: int):
        self._rng = random.Random(seed)

    def action_space_sample(self):
        if self.n_actions is None:
            raise NotImplementedError
        return self._rng.randrange(self.n_actions)

    def reset(self):
        raise NotImplementedError

    def step(self, action):
        raise NotImplementedError

    def scene(self):
        raise NotImplementedError

    def render(self):
        segs, intens = self.scene()
        return rasterize_np(np.asarray(segs, np.float32), np.asarray(intens, np.float32), *FRAME)


class CartPolePy(_BaselineEnv):
    n_actions = 2

    def reset(self):
        self.x, self.x_dot, self.theta, self.theta_dot = (
            self._rng.uniform(-0.05, 0.05) for _ in range(4)
        )
        self.steps = 0
        return self._obs()

    def set_state(self, state):
        self.x, self.x_dot = float(state.x), float(state.x_dot)
        self.theta, self.theta_dot = float(state.theta), float(state.theta_dot)
        self.steps = 0

    def _obs(self):
        return [self.x, self.x_dot, self.theta, self.theta_dot]

    def step(self, action):
        force = 10.0 if action == 1 else -10.0
        costheta, sintheta = math.cos(self.theta), math.sin(self.theta)
        temp = (force + 0.05 * self.theta_dot**2 * sintheta) / 1.1
        thetaacc = (9.8 * sintheta - costheta * temp) / (0.5 * (4.0 / 3.0 - 0.1 * costheta**2 / 1.1))
        xacc = temp - 0.05 * thetaacc * costheta / 1.1
        self.x += 0.02 * self.x_dot
        self.x_dot += 0.02 * xacc
        self.theta += 0.02 * self.theta_dot
        self.theta_dot += 0.02 * thetaacc
        self.steps += 1
        terminal = abs(self.x) > 2.4 or abs(self.theta) > 0.2095
        truncated = not terminal and self.steps >= 500
        return self._obs(), 1.0, terminal or truncated, {"truncated": truncated}

    def scene(self):
        cx = 0.5 + self.x / 4.8 * 0.8
        cy = 0.75
        tip_x = cx + math.sin(self.theta) * 0.35
        tip_y = cy - math.cos(self.theta) * 0.35
        segs = [
            [0.05, cy + 0.05, 0.95, cy + 0.05, 0.006],
            [cx - 0.07, cy, cx + 0.07, cy, 0.035],
            [cx, cy, tip_x, tip_y, 0.015],
        ]
        return segs, [0.35, 0.7, 1.0]


class MountainCarPy(_BaselineEnv):
    n_actions = 3

    def reset(self):
        self.position = self._rng.uniform(-0.6, -0.4)
        self.velocity = 0.0
        self.steps = 0
        return [self.position, self.velocity]

    def set_state(self, state):
        self.position = float(state.position)
        self.velocity = float(state.velocity)
        self.steps = 0

    def step(self, action):
        self.velocity += (action - 1) * 0.001 + math.cos(3 * self.position) * (-0.0025)
        self.velocity = max(min(self.velocity, 0.07), -0.07)
        self.position = max(min(self.position + self.velocity, 0.6), -1.2)
        if self.position <= -1.2 and self.velocity < 0:
            self.velocity = 0.0
        self.steps += 1
        terminal = self.position >= 0.5 and self.velocity >= 0.0
        truncated = not terminal and self.steps >= 200
        return [self.position, self.velocity], -1.0, terminal or truncated, \
            {"truncated": truncated}

    def scene(self):
        def to_xy(p):
            return ((p + 1.2) / 1.8 * 0.8 + 0.1, 0.9 - (math.sin(3 * p) * 0.45 + 0.55) * 0.6)

        ps = [(-1.2 + 1.8 * i / 6) for i in range(7)]
        pts = [to_xy(p) for p in ps]
        segs = [[*pts[i], *pts[i + 1], 0.006] for i in range(6)]
        cx, cy = to_xy(self.position)
        gx, gy = to_xy(0.5)
        segs += [[cx, cy - 0.03, cx, cy - 0.03, 0.03], [gx, gy - 0.10, gx, gy, 0.008]]
        return segs, [0.35] * 6 + [1.0, 0.7]


class AcrobotPy(_BaselineEnv):
    n_actions = 3

    def reset(self):
        self.s = [self._rng.uniform(-0.1, 0.1) for _ in range(4)]
        self.steps = 0
        return self._obs()

    def set_state(self, state):
        self.s = [float(state.theta1), float(state.theta2),
                  float(state.dtheta1), float(state.dtheta2)]
        self.steps = 0

    def _obs(self):
        t1, t2, d1, d2 = self.s
        return [math.cos(t1), math.sin(t1), math.cos(t2), math.sin(t2), d1, d2]

    @staticmethod
    def _dsdt(s, torque):
        theta1, theta2, dtheta1, dtheta2 = s
        d1 = 1 * 0.25 + 1 * (1 + 0.25 + 2 * 0.5 * math.cos(theta2)) + 2.0
        d2 = 1 * (0.25 + 0.5 * math.cos(theta2)) + 1.0
        phi2 = 1 * 0.5 * 9.8 * math.cos(theta1 + theta2 - math.pi / 2)
        phi1 = (
            -1 * 0.5 * dtheta2**2 * math.sin(theta2)
            - 2 * 0.5 * dtheta2 * dtheta1 * math.sin(theta2)
            + (0.5 + 1.0) * 9.8 * math.cos(theta1 - math.pi / 2)
            + phi2
        )
        ddtheta2 = (torque + d2 / d1 * phi1 - 0.5 * dtheta1**2 * math.sin(theta2) - phi2) / (
            0.25 + 1.0 - d2**2 / d1
        )
        ddtheta1 = -(d2 * ddtheta2 + phi1) / d1
        return [dtheta1, dtheta2, ddtheta1, ddtheta2]

    def step(self, action):
        torque = [-1.0, 0.0, 1.0][action]
        s = self.s
        dt = 0.2
        k1 = self._dsdt(s, torque)
        k2 = self._dsdt([s[i] + dt / 2 * k1[i] for i in range(4)], torque)
        k3 = self._dsdt([s[i] + dt / 2 * k2[i] for i in range(4)], torque)
        k4 = self._dsdt([s[i] + dt * k3[i] for i in range(4)], torque)
        s = [s[i] + dt / 6 * (k1[i] + 2 * k2[i] + 2 * k3[i] + k4[i]) for i in range(4)]
        s[0] = ((s[0] + math.pi) % (2 * math.pi)) - math.pi
        s[1] = ((s[1] + math.pi) % (2 * math.pi)) - math.pi
        s[2] = max(min(s[2], 4 * math.pi), -4 * math.pi)
        s[3] = max(min(s[3], 9 * math.pi), -9 * math.pi)
        self.s = s
        self.steps += 1
        terminal = -math.cos(s[0]) - math.cos(s[1] + s[0]) > 1.0
        truncated = not terminal and self.steps >= 500
        return self._obs(), (0.0 if terminal else -1.0), terminal or truncated, \
            {"truncated": truncated}

    def scene(self):
        t1, t2 = self.s[0], self.s[1]
        ox, oy = 0.5, 0.45
        x1, y1 = ox + 0.22 * math.sin(t1), oy + 0.22 * math.cos(t1)
        x2, y2 = x1 + 0.22 * math.sin(t1 + t2), y1 + 0.22 * math.cos(t1 + t2)
        segs = [
            [0.1, oy - 0.22, 0.9, oy - 0.22, 0.004],
            [ox, oy, x1, y1, 0.02],
            [x1, y1, x2, y2, 0.02],
        ]
        return segs, [0.3, 0.8, 1.0]


class PendulumPy(_BaselineEnv):
    def reset(self):
        self.theta = self._rng.uniform(-math.pi, math.pi)
        self.theta_dot = self._rng.uniform(-1.0, 1.0)
        self.steps = 0
        return self._obs()

    def set_state(self, state):
        self.theta = float(state.theta)
        self.theta_dot = float(state.theta_dot)
        self.steps = 0

    def _obs(self):
        return [math.cos(self.theta), math.sin(self.theta), self.theta_dot]

    def action_space_sample(self):
        return [self._rng.uniform(-2.0, 2.0)]

    def step(self, action):
        u = max(min(float(action[0]), 2.0), -2.0)
        th, thdot = self.theta, self.theta_dot
        ang = ((th + math.pi) % (2 * math.pi)) - math.pi
        costs = ang**2 + 0.1 * thdot**2 + 0.001 * u**2
        newthdot = thdot + (3 * 10.0 / 2 * math.sin(th) + 3.0 * u) * 0.05
        newthdot = max(min(newthdot, 8.0), -8.0)
        self.theta = th + newthdot * 0.05
        self.theta_dot = newthdot
        self.steps += 1
        truncated = self.steps >= 200  # pendulum never self-terminates
        return self._obs(), -costs, truncated, {"truncated": truncated}

    def scene(self):
        ox, oy = 0.5, 0.5
        tx, ty = ox + 0.35 * math.sin(self.theta), oy - 0.35 * math.cos(self.theta)
        return [[ox, oy, tx, ty, 0.025], [ox, oy, ox, oy, 0.02]], [1.0, 0.5]
