"""Pure-Python Multitask baseline (interpreted execution model)."""
from __future__ import annotations

from repro.envs.baseline_python.classic import _BaselineEnv


class MultitaskPy(_BaselineEnv):
    n_actions = 3

    def reset(self):
        self.paddle_x = 0.5
        self.ball_x = self._rng.uniform(0.1, 0.9)
        self.ball_y = 0.0
        self.lane = 1
        self.obs_lane = self._rng.randrange(3)
        self.obs_y = 0.0
        self.steps = 0
        return self._obs()

    def set_state(self, state):
        self.paddle_x = float(state.paddle_x)
        self.ball_x, self.ball_y = float(state.ball_x), float(state.ball_y)
        self.lane, self.obs_lane = int(state.lane), int(state.obs_lane)
        self.obs_y = float(state.obs_y)
        self.steps = 0

    def _obs(self):
        lane_oh = [1.0 if self.lane == i else 0.0 for i in range(3)]
        obs_oh = [1.0 if self.obs_lane == i else 0.0 for i in range(3)]
        return [self.paddle_x, self.ball_x, self.ball_y, self.obs_y] + lane_oh + obs_oh

    def step(self, action):
        move = action - 1
        self.paddle_x = max(min(self.paddle_x + move * 0.07, 0.95), 0.05)
        self.ball_y += 0.05
        catch_fail = False
        if self.ball_y >= 1.0:
            catch_fail = abs(self.ball_x - self.paddle_x) > 0.13
            self.ball_x = self._rng.uniform(0.1, 0.9)
            self.ball_y = 0.0
        self.lane = max(min(self.lane + move, 2), 0)
        self.obs_y += 0.04
        dodge_fail = False
        if self.obs_y >= 1.0:
            dodge_fail = self.obs_lane == self.lane
            self.obs_lane = self._rng.randrange(3)
            self.obs_y = 0.0
        self.steps += 1
        terminal = catch_fail or dodge_fail
        truncated = not terminal and self.steps >= 1000
        reward = -10.0 if terminal else 1.0
        return self._obs(), reward, terminal or truncated, {"truncated": truncated}

    def scene(self):
        px = 0.05 + self.paddle_x * 0.40
        bx = 0.05 + self.ball_x * 0.40
        lane_x = 0.55 + (self.lane + 0.5) * 0.40 / 3
        obs_x = 0.55 + (self.obs_lane + 0.5) * 0.40 / 3
        segs = [
            [0.5, 0.0, 0.5, 1.0, 0.004],
            [px - 0.06, 0.95, px + 0.06, 0.95, 0.02],
            [bx, self.ball_y, bx, self.ball_y, 0.025],
            [lane_x, 0.95, lane_x, 0.95, 0.03],
            [obs_x, self.obs_y, obs_x, self.obs_y, 0.03],
        ]
        return segs, [0.25, 0.8, 1.0, 0.8, 1.0]
