"""Interpreted-Python baselines ("AI Gym" comparator in the paper's Fig. 1/2)."""
from repro.envs.baseline_python.arcade import BreakoutPy, PongPy
from repro.envs.baseline_python.classic import AcrobotPy, CartPolePy, MountainCarPy, PendulumPy
from repro.envs.baseline_python.grid import CliffWalkPy, FrozenLakePy, MazePy, SnakePy
from repro.envs.baseline_python.multitask import MultitaskPy

BASELINES = {
    "CartPole-v1": CartPolePy,
    "Acrobot-v1": AcrobotPy,
    "MountainCar-v0": MountainCarPy,
    "Pendulum-v1": PendulumPy,
    "Multitask-v0": MultitaskPy,
    "Pong-v0": PongPy,
    "Breakout-v0": BreakoutPy,
    "FrozenLake-v0": FrozenLakePy,
    "CliffWalk-v0": CliffWalkPy,
    "Snake-v0": SnakePy,
    "Maze-v0": MazePy,
}

__all__ = ["CartPolePy", "AcrobotPy", "MountainCarPy", "PendulumPy",
           "MultitaskPy", "PongPy", "BreakoutPy", "FrozenLakePy",
           "CliffWalkPy", "SnakePy", "MazePy", "BASELINES"]
