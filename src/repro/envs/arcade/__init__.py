"""Arcade pixel-game suite — Flash-era games on the Pallas rasteriser.

The paper's headline workload class (§II-B, §IV-C): simple 2D games whose
observations are software-rendered frames living where the learner reads
them. Both games are pure-JAX functional envs with elementwise dynamics, so
they run on every execution engine in the repo — vmap pools, the fused
Pallas megastep kernel (with per-chunk on-device pixel rendering), sharded
pools — and ship interpreted baselines for the Fig. 1 comparison.
"""
from repro.envs.arcade.breakout import Breakout
from repro.envs.arcade.pong import Pong

__all__ = ["Breakout", "Pong"]
