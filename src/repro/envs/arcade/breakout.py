"""Breakout — Flash-era brick-breaker on the on-toolkit rasteriser (§IV-C).

The agent drives a paddle (Discrete(3): left/stay/right) returning a ball
into a 4×6 brick grid; each broken brick pays +1, clearing the board pays a
+5 bonus and ends the episode, dropping the ball past the paddle ends it
with no reward. Coordinates are the rasteriser's normalised [0, 1]²
(x rightward, y downward), bricks spanning y ∈ [BRICK_TOP, BRICK_TOP+R·H).

Dynamics are elementwise (`jnp.where` + iota comparisons over the brick
grid — the LightsOut bitboard idiom), so the identical arithmetic runs in
the env step here, the row-major Pallas megastep spec
(kernels/envstep/specs.py), and the interpreted baseline
(envs/baseline_python/arcade.py). The observation is the flattened state
(ball + paddle + brick bitboard); the registered `Breakout-v0` id wraps it
with `ObsToPixels`/`FrameStack` for on-device raw-pixel observations.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

BRICK_ROWS = 4
BRICK_COLS = 6
BRICK_TOP = 0.12       # top of the brick region
BRICK_H = 0.05         # brick row height
PADDLE_Y = 0.92        # paddle plane
PADDLE_HALF = 0.14     # paddle half-width
PADDLE_SPEED = 0.06    # paddle speed per step
BALL_VX0 = 0.022       # serve horizontal speed
BALL_VY0 = 0.03        # serve vertical speed (downward)
SPIN = 0.15            # horizontal deflection per unit of paddle offset
MAX_VX = 0.04          # horizontal ball speed cap
CLEAR_BONUS = 5.0      # board-clear bonus reward


class BreakoutState(NamedTuple):
    ball_x: jax.Array
    ball_y: jax.Array
    ball_vx: jax.Array
    ball_vy: jax.Array
    paddle_x: jax.Array
    bricks: jax.Array   # (BRICK_ROWS, BRICK_COLS) int32 in {0, 1}


class Breakout(Env):
    observation_space = Box(low=-1.0, high=1.0,
                            shape=(5 + BRICK_ROWS * BRICK_COLS,))
    action_space = Discrete(3)
    frame_shape = (84, 84)

    def reset(self, key):
        kx, kd = jax.random.split(key)
        serve = jnp.where(jax.random.bernoulli(kd), 1.0, -1.0)
        state = BreakoutState(
            ball_x=jax.random.uniform(kx, (), minval=0.2, maxval=0.8),
            ball_y=jnp.asarray(0.55, jnp.float32),
            ball_vx=(BALL_VX0 * serve).astype(jnp.float32),
            ball_vy=jnp.asarray(BALL_VY0, jnp.float32),
            paddle_x=jnp.asarray(0.5, jnp.float32),
            bricks=jnp.ones((BRICK_ROWS, BRICK_COLS), jnp.int32),
        )
        return state, self._obs(state)

    @staticmethod
    def _obs(s: BreakoutState):
        # obs == flattened state, in flatten-row order (fused-spec contract).
        return jnp.concatenate([
            jnp.stack([s.ball_x, s.ball_y, s.ball_vx, s.ball_vy, s.paddle_x]),
            s.bricks.reshape(-1).astype(jnp.float32),
        ]).astype(jnp.float32)

    def step(self, state: BreakoutState, action, key):
        move = (jnp.asarray(action) - 1).astype(jnp.float32)  # {-1, 0, +1}
        paddle_x = jnp.clip(state.paddle_x + move * PADDLE_SPEED,
                            PADDLE_HALF, 1.0 - PADDLE_HALF)

        nx = state.ball_x + state.ball_vx
        ny = state.ball_y + state.ball_vy
        vx, vy = state.ball_vx, state.ball_vy
        # side walls
        vx = jnp.where((nx < 0.0) | (nx > 1.0), -vx, vx)
        nx = jnp.where(nx < 0.0, -nx, nx)
        nx = jnp.where(nx > 1.0, 2.0 - nx, nx)
        # ceiling
        vy = jnp.where(ny < 0.0, -vy, vy)
        ny = jnp.where(ny < 0.0, -ny, ny)
        # paddle bounce (crossing the paddle plane within reach)
        hit_pad = ((state.ball_y < PADDLE_Y) & (ny >= PADDLE_Y)
                   & (jnp.abs(nx - paddle_x) <= PADDLE_HALF))
        vx = jnp.where(hit_pad, jnp.clip(vx + (nx - paddle_x) * SPIN,
                                         -MAX_VX, MAX_VX), vx)
        vy = jnp.where(hit_pad, -vy, vy)
        ny = jnp.where(hit_pad, 2.0 * PADDLE_Y - ny, ny)
        # brick collision: the cell under the ball, via iota comparisons
        # (float planes so the megastep row spec is bit-identical)
        board = state.bricks.astype(jnp.float32)
        rr = jax.lax.broadcasted_iota(jnp.float32, (BRICK_ROWS, BRICK_COLS), 0)
        cc = jax.lax.broadcasted_iota(jnp.float32, (BRICK_ROWS, BRICK_COLS), 1)
        cell_r = jnp.floor((ny - BRICK_TOP) / BRICK_H)
        cell_c = jnp.floor(nx * BRICK_COLS)
        in_region = ((ny >= BRICK_TOP)
                     & (ny < BRICK_TOP + BRICK_ROWS * BRICK_H))
        mask = ((rr == cell_r) & (cc == cell_c)).astype(jnp.float32) \
            * in_region.astype(jnp.float32) * board
        broke = jnp.sum(mask)            # 0.0 or 1.0: at most one cell matches
        new_board = board - mask
        vy = jnp.where(broke > 0.0, -vy, vy)

        cleared = jnp.sum(new_board) == 0.0
        lost = ny > 1.0
        done = cleared | lost
        reward = broke + jnp.where(cleared, CLEAR_BONUS, 0.0)
        ns = BreakoutState(nx, ny, vx, vy, paddle_x,
                           new_board.astype(jnp.int32))
        return Timestep(ns, self._obs(ns), reward.astype(jnp.float32), done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: BreakoutState):
        r, c = BRICK_ROWS, BRICK_COLS
        bx = jnp.tile((jnp.arange(c, dtype=jnp.float32) + 0.5) / c, r)
        by = jnp.repeat(BRICK_TOP + (jnp.arange(r, dtype=jnp.float32) + 0.5)
                        * BRICK_H, c)
        half_w = jnp.full((r * c,), 0.35 / c, jnp.float32)
        brick_segs = jnp.stack([bx - half_w, by, bx + half_w, by,
                                jnp.full((r * c,), 0.016, jnp.float32)],
                               axis=-1)
        brick_int = state.bricks.reshape(-1).astype(jnp.float32) * 0.7
        dyn = jnp.stack([
            jnp.stack([state.paddle_x - PADDLE_HALF, jnp.asarray(PADDLE_Y),
                       state.paddle_x + PADDLE_HALF, jnp.asarray(PADDLE_Y),
                       jnp.asarray(0.018)]),                          # paddle
            jnp.stack([state.ball_x, state.ball_y, state.ball_x,
                       state.ball_y, jnp.asarray(0.02)]),             # ball
        ])
        segs = jnp.concatenate([brick_segs, dyn], axis=0)
        intens = jnp.concatenate(
            [brick_int, jnp.asarray([1.0, 0.9], jnp.float32)])
        return segs.astype(jnp.float32), intens

    def render(self, state: BreakoutState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
