"""Pong — Flash-era arcade rally game on the on-toolkit rasteriser (§IV-C).

Single-player Pong against a scripted tracking opponent: the agent drives the
right paddle (Discrete(3): up/stay/down), the opponent tracks the ball with a
capped speed, and the episode is one rally — +1 when the ball passes the
opponent, -1 when it passes the agent. Coordinates are the rasteriser's
normalised [0, 1]² (x rightward, y downward).

Everything is elementwise `jnp.where` arithmetic, so the same dynamics run
three ways: here (functional pytree step), as row-major VPU ops inside the
Pallas megastep kernel (kernels/envstep/specs.py — mirrored
operation-for-operation), and as the interpreted baseline
(envs/baseline_python/arcade.py, shared constants). The observation is
exactly the flattened state vector (the paper's "virtual Flash memory"
mode); wrap with `ObsToPixels`/`FrameStack` — the registered `Pong-v0` id —
for the raw-pixel mode rendered on device by kernels/raster.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Discrete

PADDLE_HALF = 0.12     # paddle half-height
PADDLE_SPEED = 0.05    # agent paddle speed per step
OPP_SPEED = 0.03       # opponent tracking speed cap (slower => beatable)
BALL_SPEED_X = 0.035   # horizontal ball speed (constant magnitude)
SPIN = 0.25            # vertical deflection per unit of paddle-centre offset
MAX_VY = 0.05          # vertical ball speed cap
PLAYER_X = 0.92        # agent paddle plane (right)
OPP_X = 0.08           # opponent paddle plane (left)


class PongState(NamedTuple):
    ball_x: jax.Array
    ball_y: jax.Array
    ball_vx: jax.Array
    ball_vy: jax.Array
    player_y: jax.Array
    opp_y: jax.Array


class Pong(Env):
    observation_space = Box(low=(0.0, 0.0, -1.0, -1.0, 0.0, 0.0),
                            high=(1.0, 1.0, 1.0, 1.0, 1.0, 1.0), shape=(6,))
    action_space = Discrete(3)
    frame_shape = (84, 84)

    def reset(self, key):
        ky, kd, kv = jax.random.split(key, 3)
        serve = jnp.where(jax.random.bernoulli(kd), 1.0, -1.0)
        state = PongState(
            ball_x=jnp.asarray(0.5, jnp.float32),
            ball_y=jax.random.uniform(ky, (), minval=0.3, maxval=0.7),
            ball_vx=(BALL_SPEED_X * serve).astype(jnp.float32),
            ball_vy=jax.random.uniform(kv, (), minval=-0.02, maxval=0.02),
            player_y=jnp.asarray(0.5, jnp.float32),
            opp_y=jnp.asarray(0.5, jnp.float32),
        )
        return state, self._obs(state)

    @staticmethod
    def _obs(s: PongState):
        # obs == flattened state, in flatten-row order (fused-spec contract).
        return jnp.stack([s.ball_x, s.ball_y, s.ball_vx, s.ball_vy,
                          s.player_y, s.opp_y]).astype(jnp.float32)

    def step(self, state: PongState, action, key):
        move = (jnp.asarray(action) - 1).astype(jnp.float32)  # {-1, 0, +1}
        player_y = jnp.clip(state.player_y + move * PADDLE_SPEED,
                            PADDLE_HALF, 1.0 - PADDLE_HALF)
        opp_y = state.opp_y + jnp.clip(state.ball_y - state.opp_y,
                                       -OPP_SPEED, OPP_SPEED)
        opp_y = jnp.clip(opp_y, PADDLE_HALF, 1.0 - PADDLE_HALF)

        nx = state.ball_x + state.ball_vx
        ny = state.ball_y + state.ball_vy
        vx, vy = state.ball_vx, state.ball_vy
        # top/bottom wall bounce (reflect position and velocity)
        vy = jnp.where((ny < 0.0) | (ny > 1.0), -vy, vy)
        ny = jnp.where(ny < 0.0, -ny, ny)
        ny = jnp.where(ny > 1.0, 2.0 - ny, ny)
        # agent paddle (right plane): reflect on crossing within paddle reach
        hit_p = ((state.ball_x < PLAYER_X) & (nx >= PLAYER_X)
                 & (jnp.abs(ny - player_y) <= PADDLE_HALF))
        vy = jnp.where(hit_p, jnp.clip(vy + (ny - player_y) * SPIN,
                                       -MAX_VY, MAX_VY), vy)
        vx = jnp.where(hit_p, -vx, vx)
        nx = jnp.where(hit_p, 2.0 * PLAYER_X - nx, nx)
        # opponent paddle (left plane)
        hit_o = ((state.ball_x > OPP_X) & (nx <= OPP_X)
                 & (jnp.abs(ny - opp_y) <= PADDLE_HALF))
        vy = jnp.where(hit_o, jnp.clip(vy + (ny - opp_y) * SPIN,
                                       -MAX_VY, MAX_VY), vy)
        vx = jnp.where(hit_o, -vx, vx)
        nx = jnp.where(hit_o, 2.0 * OPP_X - nx, nx)

        score_p = nx < 0.0   # past the opponent: agent point
        score_o = nx > 1.0   # past the agent: opponent point
        done = score_p | score_o
        reward = score_p.astype(jnp.float32) - score_o.astype(jnp.float32)
        ns = PongState(nx, ny, vx, vy, player_y, opp_y)
        return Timestep(ns, self._obs(ns), reward, done, {})

    # -- rendering (capsule scene; see kernels/raster) -----------------------
    def scene(self, state: PongState):
        segs = jnp.stack([
            jnp.stack([jnp.asarray(0.5), jnp.asarray(0.02), jnp.asarray(0.5),
                       jnp.asarray(0.98), jnp.asarray(0.004)]),       # net
            jnp.stack([jnp.asarray(OPP_X), state.opp_y - PADDLE_HALF,
                       jnp.asarray(OPP_X), state.opp_y + PADDLE_HALF,
                       jnp.asarray(0.02)]),                           # opponent
            jnp.stack([jnp.asarray(PLAYER_X), state.player_y - PADDLE_HALF,
                       jnp.asarray(PLAYER_X), state.player_y + PADDLE_HALF,
                       jnp.asarray(0.02)]),                           # agent
            jnp.stack([state.ball_x, state.ball_y, state.ball_x,
                       state.ball_y, jnp.asarray(0.022)]),            # ball
        ])
        intens = jnp.asarray([0.25, 0.7, 1.0, 0.9], jnp.float32)
        return segs.astype(jnp.float32), intens

    def render(self, state: PongState):
        from repro.kernels.raster import rasterize_single

        segs, intens = self.scene(state)
        return rasterize_single(segs, intens, *self.frame_shape)
