"""repro.kernels.envstep — fused multi-step environment kernels (megastep).

K environment steps per `pallas_call`: physics, reward/done, time-limit
truncation, auto-reset re-entry and the observation write, fused over the
batch-lane dimension. `EnvPool(..., backend="pallas", unroll=K)` is the
consumer (docs/pool.md); `fused_step` is the `Env.fused_step` protocol
implementation for the registered classic-control + puzzle envs.

Structure mirrors kernels/raster and kernels/attention: megastep.py
(pl.pallas_call + BlockSpec), ref.py (pure-jnp oracle), ops.py (dispatching
wrapper with an interpret=True CPU mode), specs.py (per-env row dynamics;
the row *layout* is auto-derived from a traced reset — `derive_layout`).
"""
from repro.kernels.envstep.megastep import fused_transition, megastep_pallas
from repro.kernels.envstep.ops import env_megastep, fused_step, supports
from repro.kernels.envstep.ref import megastep_ref
from repro.kernels.envstep.specs import (FusedSpec, derive_layout, lookup,
                                         spec_for)

__all__ = [
    "FusedSpec", "derive_layout", "env_megastep", "fused_step",
    "fused_transition", "lookup", "megastep_pallas", "megastep_ref",
    "spec_for", "supports",
]
