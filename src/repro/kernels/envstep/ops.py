"""Public megastep API: backend dispatch + the wrapper-stack adapter.

`env_megastep` is the raw row-level op (pallas | pallas_interpret | jnp, with
"auto" picking Pallas on TPU and the jnp reference elsewhere — the same
dispatch idiom as kernels/raster and kernels/attention).

`fused_step` is the high-level entry the pool and `Env.fused_step` use: it
takes the *batched autoreset state* exactly as `Vec(AutoReset(env))` carries
it, precomputes the auto-reset key chain and fresh reset states with the
identical `jax.random` call sequence `AutoReset.step` makes per step (so the
threefry stream is bit-exact against the vmap path), flattens the state to
rows, launches the kernel, and rebuilds the state pytree. Which parts of
the stack fuse how is read off the *declared* pipeline (core/pipeline.py):
every wrapper is a reconstructible transform carrying its fusion role, so
the planner (`_plan`) walks data instead of reverse-engineering wrapper
stacks with isinstance heuristics.

Pixel stacks (`FrameStack(ObsToPixels(core))` / `ObsToPixels(core)`, arcade
suite) fuse too, when the core spec's obs rows are its state rows
(`FusedSpec.obs_is_state`): the kernel advances the row-major game logic for
the whole K-step chunk, then the per-step frames are rasterised *outside*
the fused body — one batched `kernels.raster` call over all K·B scenes per
chunk — and the frame-stack ring is rebuilt with a cheap select scan.
Everything stays on device; rendering work matches the vmap path exactly
(one stepped + one fresh frame per env per step).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.envstep.megastep import megastep_pallas
from repro.kernels.envstep.ref import megastep_ref
from repro.kernels.envstep.specs import lookup


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover  # repro: allow[silent-except] backend probe: failure = "not TPU", the safe dispatch default
        return False


def env_megastep(step_rows, state, actions, fresh, fresh_obs, *,
                 max_steps: Optional[int] = None, backend: str = "auto",
                 batch_block: int = 128):
    """Row-level K-step fused op with backend dispatch.

    backend: "auto" (pallas on TPU, jnp elsewhere) | "pallas" |
    "pallas_interpret" | "jnp".
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return megastep_pallas(step_rows, state, actions, fresh, fresh_obs,
                               max_steps=max_steps, batch_block=batch_block)
    if backend == "pallas_interpret":
        return megastep_pallas(step_rows, state, actions, fresh, fresh_obs,
                               max_steps=max_steps, batch_block=batch_block,
                               interpret=True)
    if backend == "jnp":
        return megastep_ref(step_rows, state, actions, fresh, fresh_obs,
                            max_steps=max_steps)
    raise ValueError(f"unknown backend {backend!r}")


def _plan(env):
    """Read the fusion plan off the stack's *declared* pipeline.

    Walks `pipeline.declared_pipeline(env)` — wrappers are reconstructible
    transforms carrying their fusion role (`Transform.fusion`) — and accepts
    the one shape the kernel models: `[TimeLimit] [ObsToPixels [FrameStack]]`
    over a base env. Returns (core_env_stack, num_stack, pixels) where
    `core_env_stack` is the TimeLimit(base)/bare-base sub-stack `lookup()`
    resolves, or (None, None, False) for anything the plan can't express
    (opaque wrappers, FrameStack without pixels, reordered transforms).
    """
    from repro.core import pipeline as P

    core, transforms = P.declared_pipeline(env)
    if core is None:
        return None, None, False
    stack = list(transforms)  # innermost-first; env is the outermost wrapper
    core_stack, num_stack, pixels = env, None, False
    if stack and stack[-1].fusion == P.FUSION_FRAME_STACK:
        num_stack = stack.pop().num_frames
        core_stack = core_stack.env
    if stack and stack[-1].fusion == P.FUSION_PIXELS:
        pixels = True
        stack.pop()
        core_stack = core_stack.env
    elif num_stack is not None:  # FrameStack over non-pixel obs: not modelled
        return None, None, False
    if stack and not (len(stack) == 1
                      and stack[0].fusion == P.FUSION_TIME_LIMIT):
        return None, None, False  # anything besides an inner TimeLimit
    return core_stack, num_stack, pixels


def _pixel_fusable(spec, core) -> bool:
    return bool(spec.obs_is_state) and hasattr(core.unwrapped, "scene")


def supports(env) -> bool:
    """True if `env` (base, TimeLimit(base), or a pixel wrapper stack over
    them) has a fused megastep execution path."""
    core, _, pixels = _plan(env)
    if core is None:
        return False
    found = lookup(core)
    if found is None:
        return False
    return _pixel_fusable(found[0], core) if pixels else True


def _render_obs_rows(core, spec, obs_rows, backend):
    """(K, O, B) obs rows -> (K, B, H, W) frames, one batched raster call.

    Valid because `spec.obs_is_state`: obs rows ARE state rows, so the
    capsule scene of every step is reconstructable on device from the
    kernel's per-step obs output — no per-step render inside the fused body.
    """
    from repro.kernels.raster import rasterize

    base = core.unwrapped
    k, _, b = obs_rows.shape
    states = jax.vmap(spec.unflatten)(obs_rows)
    segs, intens = jax.vmap(jax.vmap(base.scene))(states)
    h, w = base.frame_shape
    frames = rasterize(segs.reshape((k * b,) + segs.shape[2:]),
                       intens.reshape(k * b, -1), h, w, backend=backend)
    return frames.reshape(k, b, h, w)


def _mask_inactive(old_state, new_state, ts, active):
    """Masked-active lane gating (the serving/engine.py decode-slot pattern
    applied to env lanes): rows where `active` is False keep their pre-chunk
    state — including their AutoReset key chain, which must not advance for
    a lane that did not step — and report zero reward / obs and done=False.
    The kernel still computes every lane (SIMD lanes are paid for either
    way); the select is what makes slot recycling in the async pool unable
    to perturb neighbouring sessions."""
    from repro.core.env import Timestep

    act = jnp.asarray(active, bool)

    def lane(n, o):  # state leaves: (B, ...)
        return jnp.where(act.reshape(act.shape + (1,) * (n.ndim - 1)), n, o)

    def out(n):      # per-step output leaves: (K, B, ...)
        m = act.reshape((1,) + act.shape + (1,) * (n.ndim - 2))
        return jnp.where(m, n, jnp.zeros_like(n))

    sel_state = jax.tree.map(lane, new_state, old_state)
    info = {k: out(v) for k, v in ts.info.items()}
    return sel_state, Timestep(state=sel_state, obs=out(ts.obs),
                               reward=out(ts.reward), done=out(ts.done),
                               info=info)


def fused_step(env, state, actions, keys=None, num_steps: Optional[int] = None,
               *, backend: str = "auto", batch_block: int = 128, active=None):
    """Advance a batched `AutoReset(env)` state by `num_steps` fused steps.

    env     : the single-env stack the pool holds — `TimeLimit(base)` / base,
              optionally under `ObsToPixels` / `FrameStack(ObsToPixels(...))`
              (the arcade pixel pipeline).
    state   : `AutoResetState` with batched (B, ...) leaves — exactly the
              env_state `Vec(AutoReset(env))` carries.
    actions : (K, B) (discrete) or (K, B, 1) (continuous) action block.
    keys    : optional per-step key array; accepted for protocol symmetry
              with `Vec.step` and ignored — every fused env's dynamics are
              action-deterministic, and auto-reset randomness comes from the
              state's own key chain (like the vmap path).
    active  : optional (B,) bool lane mask (the async pool's masked chunk
              step): lanes where it is False keep their pre-chunk state and
              key chain and report zero reward / done=False. Default None
              steps every lane (lock-step).

    Returns `(new_state, ts)` where `ts` is a `Timestep` whose obs/reward/
    done/info leaves carry a leading (K, ...) step axis — the same stack
    `lax.scan` of `Vec(AutoReset(env)).step` would produce. `info` carries
    `terminal_obs` (pre-reset obs) and, when the stack has a TimeLimit,
    `truncated` (time-limit cut of a non-terminal state).
    """
    from repro.core.env import Timestep
    from repro.core.wrappers import (AutoResetState, FrameStackState,
                                     TimeLimitState)

    core, num_stack, pixels = _plan(env)
    found = lookup(core) if core is not None else None
    if found is None or (pixels and not _pixel_fusable(found[0], core)):
        raise NotImplementedError(
            f"no fused megastep spec for {type(env.unwrapped).__name__}; "
            "supported: CartPole, MountainCar, Pendulum, Acrobot, LightsOut, "
            "Pong, Breakout, FrozenLake, CliffWalk, Snake, Maze (bare or "
            "under a single TimeLimit, arcade also under ObsToPixels / "
            "FrameStack(ObsToPixels))")
    spec, max_steps = found

    acts = jnp.asarray(actions)
    if acts.ndim == 3 and acts.shape[-1] == 1:
        acts = acts[..., 0]
    if acts.ndim != 2:
        raise ValueError(f"actions must be (K, B[, 1]); got {actions.shape}")
    k, b = acts.shape
    if num_steps is not None and num_steps != k:
        raise ValueError(f"num_steps={num_steps} != actions.shape[0]={k}")

    # Auto-reset key chain + fresh reset states, OUTSIDE the kernel: the same
    # per-step `split(state.key)` + `env.reset(reset_key)` AutoReset.step
    # performs, so the threefry stream matches the vmap path bit-for-bit.
    # Pixel wrappers pass the reset key through to the core untouched, so
    # resetting `core` here sees the exact stream the full-stack reset would;
    # the fresh *frames* are re-rendered from the fresh core obs rows below
    # instead of being materialised per stack slot.
    def reset_body(ks, _):
        pair = jax.vmap(jax.random.split)(ks)          # (B, 2, 2)
        fs, fo = jax.vmap(core.reset)(pair[:, 1])
        return pair[:, 0], (fs, fo)

    final_keys, (fresh_states, fresh_obs) = jax.lax.scan(
        reset_body, state.key, None, length=k)

    def to_rows(wrapped):
        if max_steps is None:
            return spec.flatten(wrapped)
        return jnp.concatenate(
            [spec.flatten(wrapped.inner),
             wrapped.t.astype(jnp.float32)[..., None, :]], axis=-2)

    core_state = state.inner
    frames0 = None
    if num_stack is not None:
        frames0 = core_state.frames                    # (B, N, H, W)
        core_state = core_state.inner

    rows = to_rows(core_state)                         # (S', B)
    fresh_rows = to_rows(fresh_states)                 # (K, S', B)
    fobs_rows = jnp.swapaxes(fresh_obs, -1, -2)        # (K, O, B)

    new_rows, obs, tobs, reward, done, trunc = env_megastep(
        spec.step_rows, rows, acts.astype(jnp.float32), fresh_rows, fobs_rows,
        max_steps=max_steps, backend=backend, batch_block=batch_block)

    inner = spec.unflatten(new_rows if max_steps is None
                           else new_rows[:spec.state_size])
    if max_steps is not None:
        inner = TimeLimitState(inner, new_rows[spec.state_size].astype(jnp.int32))
    done_b = done.astype(bool)
    info = {}
    if max_steps is not None:
        info["truncated"] = trunc.astype(bool)

    if not pixels:
        new_state = AutoResetState(inner, final_keys)
        # The kernel computes in f32 rows; integer observation spaces (the
        # grid suite's MultiDiscrete cell codes) get their dtype back here —
        # values are small ints, exact through the f32 round-trip.
        odt = core.observation_space.dtype
        info["terminal_obs"] = jnp.swapaxes(tobs, -1, -2).astype(odt)
        out = new_state, Timestep(
            state=new_state, obs=jnp.swapaxes(obs, -1, -2).astype(odt),
            reward=reward, done=done_b, info=info)
        return out if active is None else _mask_inactive(state, *out,
                                                         active=active)

    # Pixel pipeline: rasterise the chunk's stepped (pre-reset) and fresh
    # frames in two batched on-device calls, then apply the frame-stack ring
    # and auto-reset selection — the same per-step render count as the vmap
    # path, minus all its per-step dispatch.
    pre = _render_obs_rows(core, spec, tobs, backend)        # (K, B, H, W)
    fresh_px = _render_obs_rows(core, spec, fobs_rows, backend)
    if num_stack is None:
        obs_px = jnp.where(done_b[..., None, None], fresh_px, pre)
        tobs_px = pre
        new_inner = inner
    else:
        def stack_body(frames, xs):
            pre_f, fresh_f, d = xs
            pre_stack = jnp.concatenate([frames[:, 1:], pre_f[:, None]],
                                        axis=1)
            post = jnp.where(d[:, None, None, None],
                             jnp.broadcast_to(fresh_f[:, None],
                                              pre_stack.shape), pre_stack)
            return post, (post, pre_stack)

        frames_t, (obs_px, tobs_px) = jax.lax.scan(
            stack_body, frames0, (pre, fresh_px, done_b))
        new_inner = FrameStackState(inner, frames_t)
    new_state = AutoResetState(new_inner, final_keys)
    info["terminal_obs"] = tobs_px
    out = new_state, Timestep(state=new_state, obs=obs_px, reward=reward,
                              done=done_b, info=info)
    return out if active is None else _mask_inactive(state, *out,
                                                     active=active)
