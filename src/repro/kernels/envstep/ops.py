"""Public megastep API: backend dispatch + the wrapper-stack adapter.

`env_megastep` is the raw row-level op (pallas | pallas_interpret | jnp, with
"auto" picking Pallas on TPU and the jnp reference elsewhere — the same
dispatch idiom as kernels/raster and kernels/attention).

`fused_step` is the high-level entry the pool and `Env.fused_step` use: it
takes the *batched autoreset state* exactly as `Vec(AutoReset(env))` carries
it, precomputes the auto-reset key chain and fresh reset states with the
identical `jax.random` call sequence `AutoReset.step` makes per step (so the
threefry stream is bit-exact against the vmap path), flattens the state to
rows, launches the kernel, and rebuilds the state pytree.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.envstep.megastep import megastep_pallas
from repro.kernels.envstep.ref import megastep_ref
from repro.kernels.envstep.specs import lookup


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def env_megastep(step_rows, state, actions, fresh, fresh_obs, *,
                 max_steps: Optional[int] = None, backend: str = "auto",
                 batch_block: int = 128):
    """Row-level K-step fused op with backend dispatch.

    backend: "auto" (pallas on TPU, jnp elsewhere) | "pallas" |
    "pallas_interpret" | "jnp".
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return megastep_pallas(step_rows, state, actions, fresh, fresh_obs,
                               max_steps=max_steps, batch_block=batch_block)
    if backend == "pallas_interpret":
        return megastep_pallas(step_rows, state, actions, fresh, fresh_obs,
                               max_steps=max_steps, batch_block=batch_block,
                               interpret=True)
    if backend == "jnp":
        return megastep_ref(step_rows, state, actions, fresh, fresh_obs,
                            max_steps=max_steps)
    raise ValueError(f"unknown backend {backend!r}")


def supports(env) -> bool:
    """True if `env` (base or TimeLimit(base)) has a fused megastep spec."""
    return lookup(env) is not None


def fused_step(env, state, actions, keys=None, num_steps: Optional[int] = None,
               *, backend: str = "auto", batch_block: int = 128):
    """Advance a batched `AutoReset(env)` state by `num_steps` fused steps.

    env     : the single-env stack the pool holds (`TimeLimit(base)` or base).
    state   : `AutoResetState` with batched (B, ...) leaves — exactly the
              env_state `Vec(AutoReset(env))` carries.
    actions : (K, B) (discrete) or (K, B, 1) (continuous) action block.
    keys    : optional per-step key array; accepted for protocol symmetry
              with `Vec.step` and ignored — every fused env's dynamics are
              action-deterministic, and auto-reset randomness comes from the
              state's own key chain (like the vmap path).

    Returns `(new_state, ts)` where `ts` is a `Timestep` whose obs/reward/
    done/info leaves carry a leading (K, ...) step axis — the same stack
    `lax.scan` of `Vec(AutoReset(env)).step` would produce.
    """
    from repro.core.env import Timestep
    from repro.core.wrappers import AutoResetState, TimeLimitState

    found = lookup(env)
    if found is None:
        raise NotImplementedError(
            f"no fused megastep spec for {type(env.unwrapped).__name__}; "
            "supported: CartPole, MountainCar, Pendulum, Acrobot, LightsOut "
            "(bare or under a single TimeLimit)")
    spec, max_steps = found

    acts = jnp.asarray(actions)
    if acts.ndim == 3 and acts.shape[-1] == 1:
        acts = acts[..., 0]
    if acts.ndim != 2:
        raise ValueError(f"actions must be (K, B[, 1]); got {actions.shape}")
    k, b = acts.shape
    if num_steps is not None and num_steps != k:
        raise ValueError(f"num_steps={num_steps} != actions.shape[0]={k}")

    # Auto-reset key chain + fresh reset states, OUTSIDE the kernel: the same
    # per-step `split(state.key)` + `env.reset(reset_key)` AutoReset.step
    # performs, so the threefry stream matches the vmap path bit-for-bit.
    def reset_body(ks, _):
        pair = jax.vmap(jax.random.split)(ks)          # (B, 2, 2)
        fs, fo = jax.vmap(env.reset)(pair[:, 1])
        return pair[:, 0], (fs, fo)

    final_keys, (fresh_states, fresh_obs) = jax.lax.scan(
        reset_body, state.key, None, length=k)

    def to_rows(wrapped):
        if max_steps is None:
            return spec.flatten(wrapped)
        return jnp.concatenate(
            [spec.flatten(wrapped.inner),
             wrapped.t.astype(jnp.float32)[..., None, :]], axis=-2)

    rows = to_rows(state.inner)                        # (S', B)
    fresh_rows = to_rows(fresh_states)                 # (K, S', B)
    fobs_rows = jnp.swapaxes(fresh_obs, -1, -2)        # (K, O, B)

    new_rows, obs, tobs, reward, done = env_megastep(
        spec.step_rows, rows, acts.astype(jnp.float32), fresh_rows, fobs_rows,
        max_steps=max_steps, backend=backend, batch_block=batch_block)

    inner = spec.unflatten(new_rows if max_steps is None
                           else new_rows[:spec.state_size])
    if max_steps is not None:
        inner = TimeLimitState(inner, new_rows[spec.state_size].astype(jnp.int32))
    new_state = AutoResetState(inner, final_keys)
    obs = jnp.swapaxes(obs, -1, -2)                    # (K, B, O)
    return new_state, Timestep(
        state=new_state, obs=obs, reward=reward,
        done=done.astype(bool),
        info={"terminal_obs": jnp.swapaxes(tobs, -1, -2)})
