"""Per-env fused-step specs: row-major dynamics for the megastep kernel.

A `FusedSpec` describes one base environment's dynamics in *row-major* form:
the batched state is a single `(S, B)` float32 array (one row per state
component, batch along the 128-wide lane dimension) and `step_rows` advances
all B lanes with pure element-wise VPU ops. The same `step_rows` body runs
inside the Pallas megastep kernel (megastep.py) and the pure-jnp reference
(ref.py), so kernel and oracle share one dynamics implementation.

Only the *dynamics* (`step_rows`) is written by hand — every formula mirrors
the canonical env module (envs/classic/*, envs/grid/*, envs/arcade/*,
envs/puzzle.py) operation-for-operation; parity with the vmap path is a test
contract (tests/test_conformance.py), not an aspiration. The *layout*
(state/obs row counts, flatten/unflatten between the state pytree and the
row matrix) is derived automatically by `derive_layout` from a traced
`reset` of the env: field order, shapes and dtypes come from the state
NamedTuple itself, so a new env needs only its `step_rows` math, not a
hand-maintained field table. Integer state (boards, counters, cell indices)
rides in float32 rows; the values are small integers, so the round-trip
through f32 is exact. An env whose dynamics index rows in a different order
than its state fields declares a `field_order` override (Snake: the age
grid is field 0 but the dynamics put the scalars first).

Registry: `spec_for(core_env)` derives the spec for a supported base env;
`lookup(env)` additionally accepts a single declared `TimeLimit` over it
and returns `(spec, max_steps)`, else None.
"""
from __future__ import annotations

import functools
import weakref
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class FusedSpec(NamedTuple):
    """Row-major dynamics of one base env (state components × batch lanes)."""

    name: str
    state_size: int     # S: rows in the flattened base state
    obs_size: int       # O: rows in the observation
    # flatten: batched state pytree with (..., B) leaves -> (..., S, B) f32
    flatten: Callable[[Any], jax.Array]
    # unflatten: (S, B) f32 -> batched state pytree (inverse of flatten)
    unflatten: Callable[[jax.Array], Any]
    # step_rows: (rows (S, B), action (1, B) f32)
    #   -> (new_rows (S, B), obs (O, B), reward (1, B), done (1, B) f32)
    step_rows: Callable[[jax.Array, jax.Array], Tuple[jax.Array, ...]]
    # obs rows == state rows (obs = flattened base state). When True and the
    # base env has a capsule `scene()`, pixel wrapper stacks
    # (ObsToPixels / FrameStack) can run fused too: the kernel steps the
    # row-major game logic, and frames are rasterised per-chunk outside the
    # fused body (ops.fused_step).
    obs_is_state: bool = False


class FusedDynamics(NamedTuple):
    """What a fused env must declare by hand: the row math, and nothing else.

    `step_rows_factory(env)` closes over static config (board size etc.) and
    returns the `step_rows` body. Layout is derived; `field_order` overrides
    the row order only when the dynamics index rows in a different order
    than the state NamedTuple declares its fields.
    """

    step_rows_factory: Callable[[Any], Callable]
    obs_is_state: bool = False
    field_order: Optional[Tuple[str, ...]] = None


# -- derived layout ----------------------------------------------------------

def derive_layout(env, field_order: Optional[Tuple[str, ...]] = None):
    """Introspect a traced `reset`: (state_size, obs_size, flatten, unflatten).

    The state NamedTuple's fields — in declaration order, or `field_order` —
    become consecutive row blocks of `prod(field_shape)` rows each; the
    batch dimension stays on the trailing (lane) axis. `flatten` accepts any
    leading dims before the batch axis (the (K, B, ...) fresh-reset stacks
    `ops.fused_step` scans out), `unflatten` is its exact inverse on `(S, B)`
    rows, restoring per-field shapes and dtypes.
    """
    state_s, obs_s = jax.eval_shape(env.reset, jax.random.PRNGKey(0))
    cls = type(state_s)
    fields = tuple(state_s._fields)
    order = tuple(field_order) if field_order is not None else fields
    if sorted(order) != sorted(fields):
        raise ValueError(f"field_order {order} != state fields {fields}")
    shapes = {f: tuple(getattr(state_s, f).shape) for f in fields}
    dtypes = {f: getattr(state_s, f).dtype for f in fields}
    sizes = {f: int(np.prod(shapes[f], dtype=int)) for f in fields}
    state_size = sum(sizes.values())
    obs_size = int(np.prod(obs_s.shape, dtype=int))

    def flatten(state) -> jax.Array:
        rows = []
        for f in order:
            leaf = getattr(state, f)
            lead = leaf.shape[: leaf.ndim - len(shapes[f])]
            rows.append(jnp.swapaxes(
                jnp.reshape(leaf, lead + (sizes[f],)), -1, -2))
        return jnp.concatenate(rows, axis=-2).astype(jnp.float32)

    def unflatten(rows: jax.Array):
        parts, offset = {}, 0
        for f in order:
            block = jnp.swapaxes(rows[offset:offset + sizes[f]], -1, -2)
            offset += sizes[f]
            parts[f] = jnp.reshape(
                block, block.shape[:-1] + shapes[f]).astype(dtypes[f])
        return cls(**parts)

    return state_size, obs_size, flatten, unflatten


# -- CartPole ----------------------------------------------------------------

def _cartpole_rows(env) -> Callable:
    from repro.envs.classic.cartpole import (
        FORCE_MAG, GRAVITY, LENGTH, MASSPOLE, POLEMASS_LENGTH, TAU,
        THETA_THRESHOLD, TOTAL_MASS, X_THRESHOLD)

    def step_rows(rows, act):
        x, x_dot = rows[0:1], rows[1:2]
        theta, theta_dot = rows[2:3], rows[3:4]
        force = jnp.where(act == 1.0, FORCE_MAG, -FORCE_MAG)
        costheta, sintheta = jnp.cos(theta), jnp.sin(theta)
        temp = (force + POLEMASS_LENGTH * theta_dot**2 * sintheta) / TOTAL_MASS
        thetaacc = (GRAVITY * sintheta - costheta * temp) / (
            LENGTH * (4.0 / 3.0 - MASSPOLE * costheta**2 / TOTAL_MASS)
        )
        xacc = temp - POLEMASS_LENGTH * thetaacc * costheta / TOTAL_MASS
        nx = x + TAU * x_dot
        nxd = x_dot + TAU * xacc
        nth = theta + TAU * theta_dot
        nthd = theta_dot + TAU * thetaacc
        new = jnp.concatenate([nx, nxd, nth, nthd], axis=0)
        done = ((jnp.abs(nx) > X_THRESHOLD)
                | (jnp.abs(nth) > THETA_THRESHOLD)).astype(jnp.float32)
        return new, new, jnp.ones_like(done), done

    return step_rows


# -- MountainCar -------------------------------------------------------------

def _mountain_car_rows(env) -> Callable:
    from repro.envs.classic.mountain_car import (
        FORCE, GOAL_POS, GOAL_VEL, GRAVITY, MAX_POS, MAX_SPEED, MIN_POS)

    def step_rows(rows, act):
        pos, vel = rows[0:1], rows[1:2]
        nv = vel + (act - 1.0) * FORCE + jnp.cos(3 * pos) * (-GRAVITY)
        nv = jnp.clip(nv, -MAX_SPEED, MAX_SPEED)
        npos = jnp.clip(pos + nv, MIN_POS, MAX_POS)
        nv = jnp.where((npos <= MIN_POS) & (nv < 0), 0.0, nv)
        new = jnp.concatenate([npos, nv], axis=0)
        done = ((npos >= GOAL_POS) & (nv >= GOAL_VEL)).astype(jnp.float32)
        return new, new, -jnp.ones_like(done), done

    return step_rows


# -- Pendulum ----------------------------------------------------------------

def _pendulum_rows(env) -> Callable:
    from repro.envs.classic.pendulum import (
        DT, G, L, M, MAX_SPEED, MAX_TORQUE, _angle_normalize)

    def step_rows(rows, act):
        th, thdot = rows[0:1], rows[1:2]
        u = jnp.clip(act, -MAX_TORQUE, MAX_TORQUE)
        costs = _angle_normalize(th) ** 2 + 0.1 * thdot**2 + 0.001 * u**2
        nthdot = thdot + (3 * G / (2 * L) * jnp.sin(th) + 3.0 / (M * L**2) * u) * DT
        nthdot = jnp.clip(nthdot, -MAX_SPEED, MAX_SPEED)
        nth = th + nthdot * DT
        new = jnp.concatenate([nth, nthdot], axis=0)
        obs = jnp.concatenate([jnp.cos(nth), jnp.sin(nth), nthdot], axis=0)
        done = jnp.zeros_like(u)
        return new, obs, -costs, done

    return step_rows


# -- Acrobot -----------------------------------------------------------------

def _acrobot_rows(env) -> Callable:
    from repro.envs.classic.acrobot import (
        DT, G, I1, I2, L1, LC1, LC2, M1, M2, MAX_VEL_1, MAX_VEL_2)

    def dsdt(s, torque):
        theta1, theta2 = s[0:1], s[1:2]
        dtheta1, dtheta2 = s[2:3], s[3:4]
        d1 = (M1 * LC1**2
              + M2 * (L1**2 + LC2**2 + 2 * L1 * LC2 * jnp.cos(theta2))
              + I1 + I2)
        d2 = M2 * (LC2**2 + L1 * LC2 * jnp.cos(theta2)) + I2
        phi2 = M2 * LC2 * G * jnp.cos(theta1 + theta2 - jnp.pi / 2.0)
        phi1 = (-M2 * L1 * LC2 * dtheta2**2 * jnp.sin(theta2)
                - 2 * M2 * L1 * LC2 * dtheta2 * dtheta1 * jnp.sin(theta2)
                + (M1 * LC1 + M2 * L1) * G * jnp.cos(theta1 - jnp.pi / 2)
                + phi2)
        ddtheta2 = (torque + d2 / d1 * phi1
                    - M2 * L1 * LC2 * dtheta1**2 * jnp.sin(theta2) - phi2
                    ) / (M2 * LC2**2 + I2 - d2**2 / d1)
        ddtheta1 = -(d2 * ddtheta2 + phi1) / d1
        return jnp.concatenate([dtheta1, dtheta2, ddtheta1, ddtheta2], axis=0)

    def wrap(x, lo, hi):
        return lo + jnp.mod(x - lo, hi - lo)

    def step_rows(rows, act):
        torque = act - 1.0  # TORQUES = [-1, 0, 1]
        k1 = dsdt(rows, torque)
        k2 = dsdt(rows + DT / 2 * k1, torque)
        k3 = dsdt(rows + DT / 2 * k2, torque)
        k4 = dsdt(rows + DT * k3, torque)
        ns = rows + DT / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
        th1 = wrap(ns[0:1], -jnp.pi, jnp.pi)
        th2 = wrap(ns[1:2], -jnp.pi, jnp.pi)
        dth1 = jnp.clip(ns[2:3], -MAX_VEL_1, MAX_VEL_1)
        dth2 = jnp.clip(ns[3:4], -MAX_VEL_2, MAX_VEL_2)
        new = jnp.concatenate([th1, th2, dth1, dth2], axis=0)
        done = ((-jnp.cos(th1) - jnp.cos(th2 + th1)) > 1.0).astype(jnp.float32)
        reward = jnp.where(done > 0.0, 0.0, -1.0)
        obs = jnp.concatenate(
            [jnp.cos(th1), jnp.sin(th1), jnp.cos(th2), jnp.sin(th2),
             dth1, dth2], axis=0)
        return new, obs, reward, done

    return step_rows


# -- LightsOut ---------------------------------------------------------------

def _lightsout_rows(env) -> Callable:
    n = env.n
    m = n * n

    def step_rows(rows, act):
        board, t = rows[:m], rows[m:m + 1]
        # Per-cell (row, col) indices as (m, 1) planes; 2-D iota is TPU-native.
        idx = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
        ri = (idx // n).astype(jnp.float32)
        ci = (idx % n).astype(jnp.float32)
        r = jnp.floor(act / n)
        c = act - r * n
        cross = (((ri == r) & (jnp.abs(ci - c) <= 1))
                 | ((ci == c) & (jnp.abs(ri - r) <= 1))).astype(jnp.float32)
        nb = board + cross - 2.0 * board * cross  # XOR on {0, 1} rows
        done = (jnp.sum(nb, axis=0, keepdims=True) == 0).astype(jnp.float32)
        reward = jnp.where(done > 0.0, 10.0, -1.0)
        new = jnp.concatenate([nb, t + 1.0], axis=0)
        return new, nb, reward, done

    return step_rows


# -- Grid suite (envs/grid) --------------------------------------------------
#
# The level layout (holes/cliff/walls, goal, food priorities) rides in the
# state rows, so the precomputed AutoReset fresh states regenerate it per
# episode *inside* the kernel's lane-select — on-device procedural
# generation on the same key chain that gives vmap/fused bit-parity.

def _grid_moves(act):
    """(1, B) f32 action -> (dr, dc) in the Gym FrozenLake order."""
    dr = jnp.where(act == 1.0, 1.0, 0.0) - jnp.where(act == 3.0, 1.0, 0.0)
    dc = jnp.where(act == 2.0, 1.0, 0.0) - jnp.where(act == 0.0, 1.0, 0.0)
    return dr, dc


def _grid_move(pos, act, n_rows, n_cols):
    """(1, B) f32 cell index + action -> edge-clipped new cell index.

    The f32 twin of `clip(r+dr) * n_cols + clip(c+dc)` in the env `step`s —
    exact for any board whose cell count fits f32 integers."""
    dr, dc = _grid_moves(act)
    r = jnp.floor(pos / n_cols)
    c = pos - r * n_cols
    nr = jnp.clip(r + dr, 0.0, n_rows - 1.0)
    nc = jnp.clip(c + dc, 0.0, n_cols - 1.0)
    return nr * n_cols + nc


def _cell_iota(m):
    """(m, 1) f32 per-cell index plane; 2-D iota is TPU-native."""
    return jax.lax.broadcasted_iota(jnp.float32, (m, 1), 0)


def _frozen_lake_rows(env) -> Callable:
    from repro.envs.grid.frozen_lake import GOAL_REWARD

    n, m = env.n, env.m

    def step_rows(rows, act):
        pos, holes = rows[0:1], rows[1:1 + m]
        npos = _grid_move(pos, act, n, n)
        idx = _cell_iota(m)
        at = (idx == npos).astype(jnp.float32)
        hole = jnp.sum(at * holes, axis=0, keepdims=True)
        goal = (npos == m - 1.0).astype(jnp.float32)
        done = jnp.maximum(hole, goal)
        reward = goal * GOAL_REWARD
        codes = jnp.where(at > 0.0, 3.0,
                          jnp.where(idx == m - 1.0, 2.0, holes))
        new = jnp.concatenate([npos, holes], axis=0)
        return new, codes, reward, done

    return step_rows


def _cliff_walk_rows(env) -> Callable:
    from repro.envs.grid.cliff_walk import CLIFF_REWARD, STEP_REWARD

    n_rows, n_cols, m = env.n_rows, env.n_cols, env.m
    start = float(env.start)

    def step_rows(rows, act):
        pos, cliff = rows[0:1], rows[1:1 + m]
        npos = _grid_move(pos, act, n_rows, n_cols)
        idx = _cell_iota(m)
        at = (idx == npos).astype(jnp.float32)
        fell = jnp.sum(at * cliff, axis=0, keepdims=True)
        goal = (npos == m - 1.0).astype(jnp.float32)
        new_pos = jnp.where(fell > 0.0, start, npos)
        reward = jnp.where(fell > 0.0, CLIFF_REWARD, STEP_REWARD)
        at2 = (idx == new_pos).astype(jnp.float32)
        codes = jnp.where(at2 > 0.0, 3.0,
                          jnp.where(idx == m - 1.0, 2.0, cliff))
        new = jnp.concatenate([new_pos, cliff], axis=0)
        return new, codes, reward, goal

    return step_rows


def _maze_rows(env) -> Callable:
    from repro.envs.grid.maze import GOAL_REWARD

    n, m = env.n, env.m

    def step_rows(rows, act):
        pos, goal, walls = rows[0:1], rows[1:2], rows[2:2 + m]
        cand = _grid_move(pos, act, n, n)
        idx = _cell_iota(m)
        at = (idx == cand).astype(jnp.float32)
        blocked = jnp.sum(at * walls, axis=0, keepdims=True)
        npos = jnp.where(blocked > 0.0, pos, cand)
        done = (npos == goal).astype(jnp.float32)
        reward = done * GOAL_REWARD
        at2 = (idx == npos).astype(jnp.float32)
        codes = jnp.where(at2 > 0.0, 3.0, jnp.where(idx == goal, 2.0, walls))
        new = jnp.concatenate([npos, goal, walls], axis=0)
        return new, codes, reward, done

    return step_rows


def _snake_rows(env) -> Callable:
    from repro.envs.grid.snake import DEATH_REWARD, EAT_REWARD, PHI

    n, m = env.n, env.m

    def step_rows(rows, act):
        head, food = rows[0:1], rows[1:2]
        length, eaten = rows[2:3], rows[3:4]
        ages, prio = rows[4:4 + m], rows[4 + m:4 + 2 * m]
        dr, dc = _grid_moves(act)
        r = jnp.floor(head / n)
        c = head - r * n
        nr, nc = r + dr, c + dc
        inb = ((nr >= 0.0) & (nr <= n - 1.0)
               & (nc >= 0.0) & (nc <= n - 1.0)).astype(jnp.float32)
        cand = (jnp.clip(nr, 0.0, n - 1.0) * n + jnp.clip(nc, 0.0, n - 1.0))
        eat = inb * (cand == food).astype(jnp.float32)
        ages2 = jnp.maximum(ages - jnp.where(eat > 0.0, 0.0, 1.0), 0.0)
        idx = _cell_iota(m)
        at = (idx == cand).astype(jnp.float32)
        hit = jnp.sum(at * (ages2 > 0.0).astype(jnp.float32), axis=0,
                      keepdims=True)
        die = jnp.maximum(1.0 - inb, hit)
        new_len = length + eat
        ages3 = jnp.where(at > 0.0, new_len, ages2)
        win = (new_len >= m).astype(jnp.float32)
        done = jnp.maximum(die, win)
        new_eaten = eaten + eat
        # Deterministic food chain (snake.place_food, same min-reductions):
        # k-th food = free cell minimising frac(prio + k·φ).
        vals = prio + new_eaten * PHI
        vals = vals - jnp.floor(vals)
        free = ((ages3 == 0.0) & (idx != cand)).astype(jnp.float32)
        v = jnp.where(free > 0.0, vals, 2.0)
        vmin = jnp.min(v, axis=0, keepdims=True)
        placed = jnp.min(jnp.where(v == vmin, idx, float(m)), axis=0,
                         keepdims=True)
        new_food = jnp.where(eat * (1.0 - done) > 0.0, placed, food)
        reward = eat * EAT_REWARD + die * DEATH_REWARD
        codes = jnp.where(at > 0.0, 2.0,
                          jnp.where(ages3 > 0.0, 1.0,
                                    jnp.where(idx == new_food, 3.0, 0.0)))
        new = jnp.concatenate([cand, new_food, new_len, new_eaten, ages3,
                               prio], axis=0)
        return new, codes, reward, done

    return step_rows


# -- Pong --------------------------------------------------------------------

def _pong_rows(env) -> Callable:
    from repro.envs.arcade.pong import (
        MAX_VY, OPP_SPEED, OPP_X, PADDLE_HALF, PADDLE_SPEED, PLAYER_X, SPIN)

    def step_rows(rows, act):
        x, y = rows[0:1], rows[1:2]
        vx, vy = rows[2:3], rows[3:4]
        py, oy = rows[4:5], rows[5:6]
        move = act - 1.0
        py = jnp.clip(py + move * PADDLE_SPEED, PADDLE_HALF, 1.0 - PADDLE_HALF)
        oy = oy + jnp.clip(y - oy, -OPP_SPEED, OPP_SPEED)
        oy = jnp.clip(oy, PADDLE_HALF, 1.0 - PADDLE_HALF)
        nx = x + vx
        ny = y + vy
        vy = jnp.where((ny < 0.0) | (ny > 1.0), -vy, vy)
        ny = jnp.where(ny < 0.0, -ny, ny)
        ny = jnp.where(ny > 1.0, 2.0 - ny, ny)
        hit_p = ((x < PLAYER_X) & (nx >= PLAYER_X)
                 & (jnp.abs(ny - py) <= PADDLE_HALF))
        vy = jnp.where(hit_p, jnp.clip(vy + (ny - py) * SPIN,
                                       -MAX_VY, MAX_VY), vy)
        vx = jnp.where(hit_p, -vx, vx)
        nx = jnp.where(hit_p, 2.0 * PLAYER_X - nx, nx)
        hit_o = ((x > OPP_X) & (nx <= OPP_X)
                 & (jnp.abs(ny - oy) <= PADDLE_HALF))
        vy = jnp.where(hit_o, jnp.clip(vy + (ny - oy) * SPIN,
                                       -MAX_VY, MAX_VY), vy)
        vx = jnp.where(hit_o, -vx, vx)
        nx = jnp.where(hit_o, 2.0 * OPP_X - nx, nx)
        new = jnp.concatenate([nx, ny, vx, vy, py, oy], axis=0)
        reward = (nx < 0.0).astype(jnp.float32) - (nx > 1.0).astype(jnp.float32)
        done = ((nx < 0.0) | (nx > 1.0)).astype(jnp.float32)
        return new, new, reward, done

    return step_rows


# -- Breakout ----------------------------------------------------------------

def _breakout_rows(env) -> Callable:
    from repro.envs.arcade.breakout import (
        BRICK_COLS, BRICK_H, BRICK_ROWS, BRICK_TOP, CLEAR_BONUS, MAX_VX,
        PADDLE_HALF, PADDLE_SPEED, PADDLE_Y, SPIN)

    m = BRICK_ROWS * BRICK_COLS

    def step_rows(rows, act):
        x, y = rows[0:1], rows[1:2]
        vx, vy = rows[2:3], rows[3:4]
        px = rows[4:5]
        board = rows[5:5 + m]
        move = act - 1.0
        px = jnp.clip(px + move * PADDLE_SPEED, PADDLE_HALF, 1.0 - PADDLE_HALF)
        nx = x + vx
        ny = y + vy
        vx = jnp.where((nx < 0.0) | (nx > 1.0), -vx, vx)
        nx = jnp.where(nx < 0.0, -nx, nx)
        nx = jnp.where(nx > 1.0, 2.0 - nx, nx)
        vy = jnp.where(ny < 0.0, -vy, vy)
        ny = jnp.where(ny < 0.0, -ny, ny)
        hit_pad = ((y < PADDLE_Y) & (ny >= PADDLE_Y)
                   & (jnp.abs(nx - px) <= PADDLE_HALF))
        vx = jnp.where(hit_pad, jnp.clip(vx + (nx - px) * SPIN,
                                         -MAX_VX, MAX_VX), vx)
        vy = jnp.where(hit_pad, -vy, vy)
        ny = jnp.where(hit_pad, 2.0 * PADDLE_Y - ny, ny)
        # Per-cell (row, col) planes; 2-D iota is TPU-native (LightsOut idiom).
        idx = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
        rr = (idx // BRICK_COLS).astype(jnp.float32)
        cc = (idx % BRICK_COLS).astype(jnp.float32)
        cell_r = jnp.floor((ny - BRICK_TOP) / BRICK_H)
        cell_c = jnp.floor(nx * BRICK_COLS)
        in_region = ((ny >= BRICK_TOP)
                     & (ny < BRICK_TOP + BRICK_ROWS * BRICK_H))
        mask = ((rr == cell_r) & (cc == cell_c)).astype(jnp.float32) \
            * in_region.astype(jnp.float32) * board
        broke = jnp.sum(mask, axis=0, keepdims=True)
        new_board = board - mask
        vy = jnp.where(broke > 0.0, -vy, vy)
        cleared = jnp.sum(new_board, axis=0, keepdims=True) == 0.0
        lost = ny > 1.0
        done = (cleared | lost).astype(jnp.float32)
        reward = broke + jnp.where(cleared, CLEAR_BONUS, 0.0)
        new = jnp.concatenate([nx, ny, vx, vy, px, new_board], axis=0)
        return new, new, reward, done

    return step_rows


# -- registry ----------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dynamics():
    from repro.envs.arcade import Breakout, Pong
    from repro.envs.classic import Acrobot, CartPole, MountainCar, Pendulum
    from repro.envs.grid import CliffWalk, FrozenLake, Maze, Snake
    from repro.envs.puzzle import LightsOut

    return {
        CartPole: FusedDynamics(_cartpole_rows, obs_is_state=True),
        MountainCar: FusedDynamics(_mountain_car_rows, obs_is_state=True),
        Pendulum: FusedDynamics(_pendulum_rows),
        Acrobot: FusedDynamics(_acrobot_rows),
        LightsOut: FusedDynamics(_lightsout_rows),
        Pong: FusedDynamics(_pong_rows, obs_is_state=True),
        Breakout: FusedDynamics(_breakout_rows, obs_is_state=True),
        FrozenLake: FusedDynamics(_frozen_lake_rows),
        CliffWalk: FusedDynamics(_cliff_walk_rows),
        Maze: FusedDynamics(_maze_rows),
        # The dynamics put the scalar rows (head, food, length, eaten)
        # before the grids; the state NamedTuple declares `ages` first.
        Snake: FusedDynamics(_snake_rows, field_order=(
            "head", "food", "length", "eaten", "ages", "prio")),
    }


#: per-instance memo of derived specs: one env instance is probed/looked-up
#: repeatedly (pool construction, then every fused_step trace), and the
#: `jax.eval_shape` reset trace behind `derive_layout` is not free. Weak
#: keys so cached entries die with their env.
_SPEC_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def spec_for(env) -> Optional[FusedSpec]:
    """Derive the `FusedSpec` for a supported *base* env, else None."""
    try:
        return _SPEC_CACHE[env]
    except (KeyError, TypeError):  # miss, or an unhashable/unweakref env
        pass
    dyn = _dynamics().get(type(env))
    if dyn is None:
        spec = None
    else:
        state_size, obs_size, flatten, unflatten = derive_layout(
            env, dyn.field_order)
        spec = FusedSpec(type(env).__name__, state_size, obs_size, flatten,
                         unflatten, dyn.step_rows_factory(env),
                         dyn.obs_is_state)
    try:
        _SPEC_CACHE[env] = spec
    except TypeError:
        pass
    return spec


def lookup(env) -> Optional[Tuple[FusedSpec, Optional[int]]]:
    """(spec, max_steps) for `env` = base or TimeLimit(base), else None.

    The stack is read through its declared pipeline (core/pipeline.py) —
    only a bare base (the `-raw` ids) or a single TimeLimit over it (the
    `-v*` ids) is row-fusable; any other transform changes step semantics
    the kernel doesn't model (pixel stacks are planned one level up, in
    ops.fused_step).
    """
    from repro.core.pipeline import TimeLimit, declared_pipeline

    core, transforms = declared_pipeline(env)
    if core is None:
        return None
    max_steps = None
    if transforms:
        if len(transforms) != 1 or not isinstance(transforms[0], TimeLimit):
            return None
        max_steps = transforms[0].max_steps
    spec = spec_for(core)
    if spec is None:
        return None
    return spec, max_steps
