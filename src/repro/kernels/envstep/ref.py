"""Pure-jnp oracle for the megastep kernel: a scan of the shared transition.

Same row-major layout and the exact `fused_transition` body the Pallas
kernel runs (megastep.py), but expressed as `lax.scan` over the K steps —
the CPU execution path and the parity oracle for
tests/test_envstep_fused.py.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.envstep.megastep import fused_transition


def megastep_ref(step_rows: Callable, state: jax.Array, actions: jax.Array,
                 fresh: jax.Array, fresh_obs: jax.Array, *,
                 max_steps: Optional[int] = None):
    """Same contract as megastep_pallas: returns
    (new_state (S', B), obs (K, O, B), terminal_obs (K, O, B),
    reward (K, B), done (K, B), truncated (K, B)), all f32."""
    s_env = state.shape[0] - (1 if max_steps is not None else 0)

    def body(rows, xs):
        act, fresh_t, fobs_t = xs
        new_rows, obs_out, tobs, reward, done, trunc = fused_transition(
            step_rows, rows, act[None], fresh_t, fobs_t, s_env, max_steps)
        return new_rows, (obs_out, tobs, reward[0], done[0], trunc[0])

    new_state, (obs, tobs, rew, done, trunc) = jax.lax.scan(
        body, state.astype(jnp.float32),
        (actions.astype(jnp.float32), fresh.astype(jnp.float32),
         fresh_obs.astype(jnp.float32)))
    return new_state, obs, tobs, rew, done, trunc
