"""Pallas TPU megastep — K fused environment steps per kernel launch.

The vmap execution path lowers each env step as a chain of many small XLA
ops, so a T-step rollout pays T× op dispatch and T× HBM round-trips for
state vectors of a few floats. This kernel keeps the whole batched state
resident in VMEM and advances it K steps per launch: physics update,
reward/done computation, time-limit truncation, auto-reset re-entry and the
observation write all happen inside one `pallas_call`.

Layout (see specs.py): state components are sublane rows, the env batch is
the 128-wide lane dimension. Per grid step one program instance owns a
(S', BB) state tile plus the (K, ·, BB) action/reset/output tiles for its
batch slice; the K-loop is a `fori_loop` carrying the state tile in
registers/VMEM, so HBM traffic per launch is O(K·(obs+reward+done)) writes
instead of O(K·everything) round-trips.

Randomness never enters the kernel: classic-control dynamics are
action-deterministic, and the auto-reset re-entry states (the only RNG
consumer) are precomputed outside with the exact `jax.random` call sequence
the vmap path makes (ops.py), then selected per lane with `jnp.where`. That
is what makes vmap/fused bit-parity a testable contract.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fused_transition(step_rows: Callable, rows: jax.Array, act: jax.Array,
                     fresh: jax.Array, fresh_obs: jax.Array,
                     s_env: int, max_steps: Optional[int]):
    """One fused step on row-major state: dynamics + TimeLimit + AutoReset.

    All operands are 2-D `(rows, B)` float32. Mirrors, in order,
    `AutoReset(TimeLimit(env)).step` with the fresh reset state/obs already
    materialised. Shared by the Pallas kernel and the jnp reference (ref.py).

    Returns (new_rows, obs, terminal_obs, reward, done, truncated) —
    `terminal_obs` is the pre-reset observation AutoReset surfaces in
    `info["terminal_obs"]`; `truncated` is TimeLimit's distinct cut signal
    (1.0 only on a time-limit cut of a non-terminal state, all-zero when
    there is no time limit) surfaced in `info["truncated"]`.
    """
    stepped, obs, reward, done = step_rows(rows[:s_env], act)
    trunc = jnp.zeros_like(done)
    if max_steps is not None:
        tcnt = rows[s_env:s_env + 1] + 1.0
        trunc = (tcnt >= float(max_steps)).astype(jnp.float32) * (1.0 - done)
        done = jnp.maximum(done, (tcnt >= float(max_steps)).astype(jnp.float32))
        stepped = jnp.concatenate([stepped, tcnt], axis=0)
    new_rows = jnp.where(done > 0.0, fresh, stepped)
    obs_out = jnp.where(done > 0.0, fresh_obs, obs)
    return new_rows, obs_out, obs, reward, done, trunc


def _megastep_kernel(state_ref, act_ref, fresh_ref, fobs_ref,
                     out_state_ref, obs_ref, tobs_ref, rew_ref, done_ref,
                     trunc_ref, *, step_rows: Callable, k: int, s_env: int,
                     max_steps: Optional[int]):
    def body(t, rows):
        act = act_ref[pl.ds(t, 1), :]                    # (1, BB)
        fresh = fresh_ref[pl.ds(t, 1), :, :][0]          # (S', BB)
        fobs = fobs_ref[pl.ds(t, 1), :, :][0]            # (O, BB)
        new_rows, obs_out, tobs, reward, done, trunc = fused_transition(
            step_rows, rows, act, fresh, fobs, s_env, max_steps)
        obs_ref[pl.ds(t, 1), :, :] = obs_out[None]
        tobs_ref[pl.ds(t, 1), :, :] = tobs[None]
        rew_ref[pl.ds(t, 1), :] = reward
        done_ref[pl.ds(t, 1), :] = done
        trunc_ref[pl.ds(t, 1), :] = trunc
        return new_rows

    out_state_ref[...] = jax.lax.fori_loop(0, k, body, state_ref[...])


def megastep_pallas(step_rows: Callable, state: jax.Array, actions: jax.Array,
                    fresh: jax.Array, fresh_obs: jax.Array, *,
                    max_steps: Optional[int] = None, batch_block: int = 128,
                    interpret: bool = False):
    """Run K fused env steps over the batch as one `pallas_call`.

    state (S', B) f32; actions (K, B) f32; fresh (K, S', B) f32 precomputed
    auto-reset states; fresh_obs (K, O, B) f32. The batch is padded to the
    `batch_block` lane boundary (zero lanes compute inert garbage that is
    sliced off). Returns (new_state (S', B), obs (K, O, B),
    terminal_obs (K, O, B), reward (K, B), done (K, B),
    truncated (K, B)) — all f32.
    """
    sp, b = state.shape
    k = actions.shape[0]
    o = fresh_obs.shape[1]
    s_env = sp - (1 if max_steps is not None else 0)

    bb = batch_block
    bp = pl.cdiv(b, bb) * bb
    if bp != b:
        pad = lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, bp - b)])
        state, actions, fresh, fresh_obs = map(pad, (state, actions, fresh,
                                                     fresh_obs))

    outs = pl.pallas_call(
        functools.partial(_megastep_kernel, step_rows=step_rows, k=k,
                          s_env=s_env, max_steps=max_steps),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((sp, bb), lambda i: (0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
            pl.BlockSpec((k, sp, bb), lambda i: (0, 0, i)),
            pl.BlockSpec((k, o, bb), lambda i: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((sp, bb), lambda i: (0, i)),
            pl.BlockSpec((k, o, bb), lambda i: (0, 0, i)),
            pl.BlockSpec((k, o, bb), lambda i: (0, 0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
            pl.BlockSpec((k, bb), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sp, bp), jnp.float32),
            jax.ShapeDtypeStruct((k, o, bp), jnp.float32),
            jax.ShapeDtypeStruct((k, o, bp), jnp.float32),
            jax.ShapeDtypeStruct((k, bp), jnp.float32),
            jax.ShapeDtypeStruct((k, bp), jnp.float32),
            jax.ShapeDtypeStruct((k, bp), jnp.float32),
        ],
        interpret=interpret,
    )(state.astype(jnp.float32), actions.astype(jnp.float32),
      fresh.astype(jnp.float32), fresh_obs.astype(jnp.float32))

    return tuple(x[..., :b] for x in outs)
