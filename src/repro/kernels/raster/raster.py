"""Pallas TPU rasteriser — the paper's SIMD software renderer, TPU-native.

Paper §II-B: for simple 2D scenes, *software* rendering into a framebuffer
that lives where the consumer reads it beats hardware rendering + readback by
~80×. On TPU the analogue is rasterising directly in VMEM with VPU vector
ops: the (H, W) framebuffer tile is VMEM-resident, each segment's coverage is
evaluated across all 8×128 lanes at once, and the frame lands in the same HBM
the learner's conv stack reads — no host or PCIe round-trip anywhere.

Tiling: grid over (batch-tile,); each program instance rasterises BB frames.
The framebuffer block (BB, H, Wp) with W padded to the 128-lane boundary and
the (BB, S, 8) segment table both sit in VMEM; S is looped with fori_loop so
VMEM stays O(H·W) regardless of scene complexity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-8


def _raster_kernel(segs_ref, inten_ref, out_ref, *, h: int, w: int, s: int, bb: int):
    softness = 1.0 / h
    # Pixel-centre coordinate planes for the padded (h, wp) tile. TPU needs
    # >=2D iota; broadcasted_iota is the native VPU form.
    wp = out_ref.shape[-1]
    py = (jax.lax.broadcasted_iota(jnp.float32, (h, wp), 0) + 0.5) / h
    px = (jax.lax.broadcasted_iota(jnp.float32, (h, wp), 1) + 0.5) / w

    def one_frame(b, _):
        def body(i, fb):
            x0 = segs_ref[b, i, 0]
            y0 = segs_ref[b, i, 1]
            x1 = segs_ref[b, i, 2]
            y1 = segs_ref[b, i, 3]
            r = segs_ref[b, i, 4]
            inten = inten_ref[b, i]
            dx, dy = x1 - x0, y1 - y0
            l2 = jnp.maximum(dx * dx + dy * dy, _EPS)
            t = jnp.clip(((px - x0) * dx + (py - y0) * dy) / l2, 0.0, 1.0)
            cx, cy = x0 + t * dx, y0 + t * dy
            d = jnp.sqrt((px - cx) ** 2 + (py - cy) ** 2)
            cov = jnp.clip((r - d) / softness + 0.5, 0.0, 1.0) * inten
            return jnp.maximum(fb, cov)

        fb = jax.lax.fori_loop(0, s, body, jnp.zeros((h, wp), jnp.float32))
        out_ref[b, :, :] = fb
        return 0

    jax.lax.fori_loop(0, bb, one_frame, 0)


def rasterize_pallas(
    segs: jax.Array,
    intens: jax.Array,
    h: int,
    w: int,
    *,
    batch_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """(B, S, 5) segments + (B, S) intensities -> (B, H, W) framebuffers."""
    b, s, _ = segs.shape
    bb = min(batch_block, b)
    bp = (b + bb - 1) // bb * bb  # pad the batch to the block boundary
    if bp != b:
        # Zero-radius/zero-intensity pad scenes are inert; sliced off below.
        segs = jnp.pad(segs, ((0, bp - b), (0, 0), (0, 0)))
        intens = jnp.pad(intens, ((0, bp - b), (0, 0)))
    wp = (w + 127) // 128 * 128  # lane-align the minor dim

    # Pad the segment feature dim to 8 so the VMEM tile is sublane-friendly.
    segs8 = jnp.concatenate([segs, jnp.zeros((bp, s, 3), segs.dtype)], axis=-1)

    out = pl.pallas_call(
        functools.partial(_raster_kernel, h=h, w=w, s=s, bb=bb),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, s, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, h, wp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, h, wp), jnp.float32),
        interpret=interpret,
    )(segs8.astype(jnp.float32), intens.astype(jnp.float32))
    return out[:b, :, :w]
