"""jit'd public wrapper for the rasteriser: picks Pallas on TPU, oracle on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.raster.raster import rasterize_pallas
from repro.kernels.raster.ref import rasterize_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover  # repro: allow[silent-except] backend probe: failure = "not TPU", the safe dispatch default
        return False


@functools.partial(jax.jit, static_argnames=("h", "w", "backend"))
def rasterize(segs: jax.Array, intens: jax.Array, h: int, w: int, backend: str = "auto") -> jax.Array:
    """Render (B, S, 5) capsule scenes to (B, H, W) float32 framebuffers.

    backend: "auto" (pallas on TPU, jnp elsewhere) | "pallas" | "pallas_interpret" | "jnp".
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return rasterize_pallas(segs, intens, h, w)
    if backend == "pallas_interpret":
        return rasterize_pallas(segs, intens, h, w, interpret=True)
    if backend == "jnp":
        return rasterize_ref(segs, intens, h, w)
    raise ValueError(f"unknown backend {backend!r}")


def rasterize_single(segs: jax.Array, intens: jax.Array, h: int, w: int) -> jax.Array:
    """Unbatched convenience: (S, 5), (S,) -> (H, W)."""
    return rasterize(segs[None], intens[None], h, w)[0]
