"""Pure-jnp oracle for the software rasteriser.

Scene model: each environment frame is a set of S "capsules" (line segments
with radius — rectangles, rods and dots are all capsules). Coordinates are
normalised to [0, 1]² with x rightward, y downward. Coverage uses a soft edge
one pixel wide so rendering is smooth (and differentiable, a bonus the
paper's integer framebuffers don't have).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-8


def _pixel_grid(h: int, w: int):
    py = (jnp.arange(h, dtype=jnp.float32)[:, None] + 0.5) / h
    px = (jnp.arange(w, dtype=jnp.float32)[None, :] + 0.5) / w
    return px, py


def _segment_coverage(seg: jax.Array, inten: jax.Array, px, py, softness: float):
    x0, y0, x1, y1, r = seg[0], seg[1], seg[2], seg[3], seg[4]
    dx, dy = x1 - x0, y1 - y0
    l2 = jnp.maximum(dx * dx + dy * dy, _EPS)
    t = jnp.clip(((px - x0) * dx + (py - y0) * dy) / l2, 0.0, 1.0)
    cx, cy = x0 + t * dx, y0 + t * dy
    d = jnp.sqrt((px - cx) ** 2 + (py - cy) ** 2)
    cov = jnp.clip((r - d) / softness + 0.5, 0.0, 1.0)
    return cov * inten


def rasterize_ref(segs: jax.Array, intens: jax.Array, h: int, w: int) -> jax.Array:
    """segs: (B, S, 5) [x0,y0,x1,y1,radius]; intens: (B, S). Returns (B, H, W).

    Pixel value = max over segments of soft coverage × intensity (painter's
    max-composite; zero-radius segments with zero intensity are inert padding).
    """
    px, py = _pixel_grid(h, w)
    softness = 1.0 / h

    def per_env(segs_e, int_e):
        covs = jax.vmap(lambda s, i: _segment_coverage(s, i, px, py, softness))(segs_e, int_e)
        return jnp.max(covs, axis=0)

    return jax.vmap(per_env)(segs, intens)
