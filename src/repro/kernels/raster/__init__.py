from repro.kernels.raster.ops import rasterize, rasterize_single
from repro.kernels.raster.raster import rasterize_pallas
from repro.kernels.raster.ref import rasterize_ref

__all__ = ["rasterize", "rasterize_single", "rasterize_pallas", "rasterize_ref"]
