"""Pallas TPU kernels for the perf-critical hot spots.

- raster/    : the paper's SIMD software renderer, TPU-native (VMEM framebuffers)
- attention/ : flash GQA attention for the learner plane (train/prefill)
- envstep/   : fused multi-step environment kernels (megastep) behind the pool

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with backend dispatch) and ref.py (pure-jnp oracle used by tests).
"""
