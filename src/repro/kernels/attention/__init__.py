from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ops import attention
from repro.kernels.attention.ref import attention_ref

__all__ = ["flash_attention", "attention", "attention_ref"]
