"""jit'd public wrapper for flash attention with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ref import attention_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover  # repro: allow[silent-except] backend probe: failure = "not TPU", the safe dispatch default
        return False


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "backend", "block_q", "block_k")
)
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    backend: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Multi-head GQA attention (B, Hq, Lq, D) × (B, Hkv, Lk, D) -> (B, Hq, Lq, D)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k)
    if backend == "pallas_interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k, interpret=True)
    if backend == "jnp":
        return attention_ref(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown backend {backend!r}")
