"""Pure-jnp oracle for flash GQA attention (causal / sliding-window)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Lq, D)
    k: jnp.ndarray,  # (B, Hkv, Lk, D)
    v: jnp.ndarray,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unbounded; else keys in (qpos-window, qpos]
    q_offset: int = 0,        # absolute position of q[0] (decode/prefill chunking)
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale

    qpos = jnp.arange(lq) + q_offset
    kpos = jnp.arange(lk)
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.nan_to_num(jnp.exp(s - jnp.max(s, axis=-1, keepdims=True)))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)
