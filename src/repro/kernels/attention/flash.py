"""Flash GQA attention — Pallas TPU kernel (MXU-tiled, VMEM-streaming).

Online-softmax attention in the FlashAttention-2 style, adapted to TPU:
  - grid = (B, Hq, nQ, nK); the last (nK) axis is sequential ("arbitrary")
    so the running (m, l, acc) state lives in VMEM scratch across K blocks.
  - Q/K/V blocks are MXU-aligned (block_q × d and block_k × d with d a
    multiple of 128 on real hardware); s = q·kᵀ and p·v both hit the MXU.
  - GQA: K/V index maps divide the query-head index by the group size, so
    kv blocks are fetched once per group position without materialising the
    head-repeat (the repeat the jnp oracle pays in HBM is free here).
  - causal + sliding-window masking is positional; fully-masked K blocks are
    skipped with pl.when (on TPU this skips the DMA+MXU work entirely).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q: int, block_k: int, nk: int, lk: int,
    causal: bool, window: int, scale: float,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = iq * block_q
    k0 = ik * block_k

    # Block-level skip: the whole K block is out of the causal/window range.
    live = True
    if causal:
        live = k0 <= q0 + block_q - 1
    if window > 0:
        live = live & (k0 + block_k - 1 > q0 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (bq, bk)

        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < lk
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    if lq % block_q or lk % block_k:
        raise ValueError(f"seq lens ({lq},{lk}) must tile by blocks ({block_q},{block_k})")
    nq, nk = lq // block_q, lk // block_k

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q, block_k=block_k, nk=nk, lk=lk,
        causal=causal, window=window, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
