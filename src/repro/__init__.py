"""CaiRL on JAX/TPU — compiled RL environment toolkit + multi-pod learner.

Drop-in entry point (paper Listing 2): `from repro import cairl`.
"""
