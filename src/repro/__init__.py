"""CaiRL on JAX/TPU — compiled RL environment toolkit + multi-pod learner.

Drop-in entry point (paper Listing 2): `from repro import cairl`.
Vectorised entry point: `repro.make_vec(id, num_envs)` — one constructor
over every pool backend (repro.pool).

Exports resolve lazily (PEP 562) so `import repro` stays cheap and
submodules keep importing in any order.
"""

#: public surface of the bare `repro` package (tests/test_api_surface.py)
__all__ = ["cairl", "make", "make_compat", "make_vec", "registered", "spec"]

_LAZY = {
    "make_vec": ("repro.pool", "make_vec"),
    "make": ("repro.core.registry", "make"),
    "make_compat": ("repro.core.registry", "make_compat"),
    "spec": ("repro.core.registry", "spec"),
    "registered": ("repro.core.registry", "registered"),
}


def __getattr__(name):
    if name == "cairl":
        import importlib

        return importlib.import_module("repro.cairl")
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
