"""Serving driver: batched requests against any assigned arch.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 16

On a real cluster the same engine runs the full config on the production
mesh; prefill/decode are the exact step functions the dry-run compiles for
the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import lm
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.is_encoder_decoder:
        raise SystemExit("use a decoder-only arch for the text-serving driver")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 32))),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run(max_ticks=args.requests * (args.max_new + 4))
    dt = time.perf_counter() - t0
    tokens = sum(len(r.output or []) for r in reqs)
    print(f"{args.arch}: served {len(reqs)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:,.1f} tok/s, {args.slots}-slot continuous batching)")


if __name__ == "__main__":
    main()
