"""launch subsystem."""
