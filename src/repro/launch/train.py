"""Training driver: any assigned arch, any host, fault-tolerant.

On this CPU container it trains reduced configs end-to-end (the quickstart
path); on a real cluster the same driver runs the full configs on the
production mesh — mesh construction, sharding, checkpointing and the data
stream are all host-count-agnostic.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig, batch_at_step
from repro.runtime.straggler import StragglerTracker
from repro.sharding import rules
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    tc = TrainConfig(lr=args.lr, warmup=max(args.steps // 20, 1),
                     total_steps=args.steps, remat=args.remat, accum_steps=args.accum)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, kind="markov")

    params, opt = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        state = mgr.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start_step = mgr.latest_step()
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    tracker = StragglerTracker(num_hosts=1)
    t_start = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at_step(dc, step).items()}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, cfg.encoder_len, cfg.d_model))
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        tracker.record(0, time.perf_counter() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = args.batch * args.seq / max(time.perf_counter() - t0, 1e-9)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} tok/s {tps:,.0f}")
        if mgr and step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt}, blocking=False)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt})
        mgr.wait()
    print(f"done in {time.perf_counter() - t_start:.1f}s; final loss "
          f"{float(metrics['loss']):.4f} (uniform = {np.log(cfg.vocab_size):.3f})")


if __name__ == "__main__":
    main()
