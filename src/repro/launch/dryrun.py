import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this AOT-compiles the real step function (train_step with
optimizer, prefill, or decode_step with caches) against ShapeDtypeStruct
stand-ins on the production mesh — no arrays are ever materialised. The
compiled artifact yields:
  - memory_analysis()  : per-device bytes (proves the cell fits)
  - cost_analysis()    : per-device HLO FLOPs / bytes accessed
  - HLO text           : per-device collective-operand bytes (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)
which benchmarks/roofline.py turns into the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun   # every cell
"""
import argparse
import gc
import json
import re
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_by_name
from repro.configs.registry import ARCH_IDS, cell_supported, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.sharding import rules
from repro.train.trainer import TrainConfig, make_train_step, make_optimizer

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shaped(tree, shardings):
    """Pytree of ShapeDtypeStructs carrying NamedShardings."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-operand bytes of every collective in the per-device HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"(^|\)\s|\}}\s|\s){re.escape(c)}(-start|-done)?\(", rhs) or \
               rhs.startswith(c + "(") or re.match(rf"^[\w\[\],\s()]*\)\s*{re.escape(c)}\(", rhs):
                op = c
                break
        if op is None:
            # robust fallback: opcode appears as " <op>(" anywhere on the rhs
            for c in _COLLECTIVES:
                if f" {c}(" in rhs or rhs.startswith(f"{c}("):
                    op = c
                    break
        if op is None:
            continue
        if f"{op}-done(" in rhs:
            continue  # counted at -start
        # sum all result shapes on the lhs type annotation (may be a tuple)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(rhs.split(op)[0]):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            key = dt if dt in _DTYPE_BYTES else dt[:2]
            nbytes += n * _DTYPE_BYTES.get(key, 4)
        out[op] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _memory_analysis(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except (AttributeError, NotImplementedError):
        return {}  # backend exposes no memory stats; anything else raises
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out


def build_cell(arch: str, shape: ShapeConfig, mesh):
    """Returns (fn, args_shaped, donate) ready for jit(...).lower(...)."""
    cfg = get_config(arch)
    key = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(lambda k: lm.init_params(cfg, k), key)
    psh = rules.to_shardings(rules.param_specs(params_shape, mesh), mesh)
    params_in = _shaped(params_shape, psh)

    if shape.kind == "train":
        tc = TrainConfig(remat="full", accum_steps=1)
        opt_shape = jax.eval_shape(lambda p: make_optimizer(tc).init(p), params_shape)
        osh = rules.to_shardings(rules.opt_specs(opt_shape, params_shape, mesh), mesh)
        opt_in = _shaped(opt_shape, osh)
        batch = input_specs(cfg, shape)
        bsh = rules.to_shardings(rules.batch_specs(mesh, batch), mesh)
        batch_in = _shaped(batch, bsh)
        fn = make_train_step(cfg, tc)
        jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None), donate_argnums=(0, 1))
        return jitted, (params_in, opt_in, batch_in)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bsh = rules.to_shardings(rules.batch_specs(mesh, batch), mesh)
        batch_in = _shaped(batch, bsh)

        def prefill_fn(params, batch):
            return lm.prefill(cfg, params, batch, max_seq=shape.seq_len)

        jitted = jax.jit(prefill_fn, in_shardings=(psh, bsh))
        return jitted, (params_in, batch_in)

    # decode: one token against a seq_len cache
    b = shape.global_batch
    seq_sharded = b == 1  # long_500k: shard the cache sequence dim instead
    cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, b, shape.seq_len))
    csh = rules.to_shardings(
        rules.cache_specs(mesh, cache_shape, b, seq_sharded=seq_sharded), mesh)
    cache_in = _shaped(cache_shape, csh)
    tok = input_specs(cfg, shape)["tokens"]
    tsh = rules.to_shardings(rules.batch_specs(mesh, {"tokens": tok}), mesh)["tokens"]
    tok_in = jax.ShapeDtypeStruct(tok.shape, tok.dtype, sharding=tsh)
    pos_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

    def decode_fn(params, caches, tokens, pos):
        return lm.decode_step(cfg, params, caches, tokens, pos)

    jitted = jax.jit(decode_fn, in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
                     out_shardings=(None, csh), donate_argnums=(1,))
    return jitted, (params_in, cache_in, tok_in, pos_in)


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> Dict[str, Any]:
    shape = shape_by_name(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    skip = cell_supported(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    jitted, args = build_cell(arch, shape, mesh)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0]
    cost = dict(cost)
    mem = _memory_analysis(compiled)
    from repro.launch.hlo_analysis import analyze_hlo

    hlo = analyze_hlo(compiled.as_text())     # trip-count-aware (see module doc)
    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
        "kind": shape.kind, "chips": int(n_chips),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": float(hlo["flops"]),
        "bytes_per_device": float(hlo["bytes"]),
        "collective_bytes_per_device": hlo["collectives"],
        "cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "memory_analysis": mem,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] compile ok "
              f"({t_compile:.1f}s); flops/dev={result['flops_per_device']:.3e} "
              f"bytes/dev={result['bytes_per_device']:.3e} "
              f"coll/dev={hlo['collectives']['total']:.3e}B "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
        print("  memory_analysis:", {k: f"{v/2**30:.2f}GiB" for k, v in mem.items()})
    del jitted, lowered, compiled
    gc.collect()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for s in SHAPES:
                cells.append((arch, s.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape_name in cells:
        for multi_pod in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[{tag}] cached, skipping")
                continue
            try:
                res = run_cell(arch, shape_name, multi_pod)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                res = {"arch": arch, "shape": shape_name,
                       "mesh": "pod2x16x16" if multi_pod else "pod16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                print(f"[{tag}] FAILED: {res['error']}")
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
    print(f"done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
