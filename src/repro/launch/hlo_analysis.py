"""Trip-count-aware static analysis of compiled HLO.

`compiled.cost_analysis()` counts every computation ONCE — a `lax.scan` over
62 layers contributes one body's FLOPs, a 62× undercount. Since the whole
model stack is scan-based (deliberately, for compile time), roofline terms
must come from a call-graph walk that multiplies `while` bodies by their
trip counts. This module parses the post-optimization HLO text and computes,
per device:

  flops            : 2 · |out| · K for every `dot` (contraction K from the
                     operand shape + contracting dims), × trip counts
  bytes            : Σ (operand + output bytes) per instruction — the same
                     definition cost_analysis uses ("bytes accessed";
                     intra-fusion traffic is free, fusions count their
                     boundary I/O), × trip counts
  collective bytes : output bytes of all-gather / all-reduce / reduce-scatter
                     / all-to-all / collective-permute, × trip counts

Validated against cost_analysis on scan-free programs (exact match for dot
flops) and against analytic 6·N·D for the scanned LM stacks.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    """Dims of the first *array* shape in `type_str`.

    Full-module texts put tuple types and `token[]` in instruction type
    positions (`(f32[4,2], token[])` on while/infeed roots); tokens and
    other non-array entries carry no bytes and must not masquerade as a
    scalar shape, so entries whose dtype is unknown are skipped rather
    than returned as `[]`.
    """
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        return [int(d) for d in dims.split(",") if d] if dims else []
    return None


# elementwise ops cost 1 flop per output element (HloCostAnalysis semantics);
# reduce costs its input element count.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "sine", "cosine", "negate", "abs",
    "floor", "ceil", "round-nearest-afz", "sign", "atan2", "remainder",
    "erf", "expm1", "log1p",
}


class Instr:
    __slots__ = ("name", "type_str", "opcode", "rhs", "operands")

    def __init__(self, name, type_str, opcode, rhs, operands):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.rhs = rhs
        self.operands = operands


def _balanced(s: str, start: int = 0) -> int:
    """Index just past the paren group opening at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2).strip()
    # rhs = "TYPE opcode(operands), attrs...". TYPE may be a tuple containing
    # parens and /*index=N*/ comments — scan balanced parens, no regex.
    if rhs.startswith("("):
        end = _balanced(rhs, 0)
        type_str, rest = rhs[:end], rhs[end:].lstrip()
    else:
        tm = re.match(r"^([^\s(]+)\s+", rhs)
        if not tm:
            return None
        type_str, rest = tm.group(1), rhs[tm.end():]
    om = re.match(r"^([\w\-]+)\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    arg_end = _balanced(rest, om.end() - 1)
    arglist = rest[om.end():arg_end - 1]
    operands = re.findall(r"%([\w.\-]+)", arglist)
    return Instr(name, type_str, opcode, rhs, operands)


def parse_computations(hlo_text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    comps_entry: List[str] = []
    current = None
    for line in hlo_text.splitlines():
        is_instr = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S", line)
        header = re.match(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", line)
        if header and not is_instr:
            current = header.group(2)
            comps[current] = []
            if header.group(1):
                comps_entry.append(current)
            continue
        if current is None:
            continue
        if line.strip().startswith("}"):
            current = None
            continue
        ins = _parse_instr(line)
        if ins:
            comps[current].append(ins)
    return comps


def _dot_flops(ins: Instr, symtab: Dict[str, Instr]) -> float:
    """2·|out|·K for a dot; K = product of the contracting dims.

    |out| already includes the batch dims of a batched dot
    (`lhs_batch_dims={0}` style), so only the contraction K must come from
    an operand shape. The lhs operand is preferred; when it is not in this
    computation's symbol table (full-module texts can reference values the
    per-computation parse did not capture) the rhs operand with
    `rhs_contracting_dims` answers instead.
    """
    out_dims = _shape_dims(ins.type_str) or []
    out_elems = math.prod(out_dims) if out_dims else 1

    def _k(operand_idx: int, side: str) -> Optional[float]:
        if operand_idx >= len(ins.operands):
            return None
        src = symtab.get(ins.operands[operand_idx])
        if src is None:
            return None
        m = re.search(rf"{side}_contracting_dims=\{{([0-9,]*)\}}", ins.rhs)
        if not m:
            return None
        dims = _shape_dims(src.type_str) or []
        k = 1.0
        for c in (int(x) for x in m.group(1).split(",") if x):
            if c < len(dims):
                k *= dims[c]
        return k

    k = _k(0, "lhs")
    if k is None:
        k = _k(1, "rhs")
    return 2.0 * out_elems * (k if k is not None else 1.0)


class Analysis(dict):
    @property
    def flops(self):
        return self["flops"]

    @property
    def bytes(self):
        return self["bytes"]

    @property
    def collective_bytes(self):
        return self["collectives"]["total"]


_HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "send-done", "recv",
                      "recv-done")


def host_transfer_ops(hlo_text: str) -> List[str]:
    """Instructions that move data across the host boundary.

    Used to certify device-residency claims (fig4, test_pool): a compiled
    rollout whose step loop round-trips to the host shows up here as
    infeed/outfeed/send/recv, or as a custom-call into a Python callback
    (io_callback / pure_callback lower to `*_callback` custom-call targets).
    Returns "computation/instruction:opcode" strings; empty = fully resident.
    """
    found = []
    for comp, instrs in parse_computations(hlo_text).items():
        for ins in instrs:
            if ins.opcode in _HOST_TRANSFER_OPS:
                found.append(f"{comp}/{ins.name}:{ins.opcode}")
            elif ins.opcode == "custom-call" and "callback" in ins.rhs:
                found.append(f"{comp}/{ins.name}:custom-call(callback)")
    return found


def _entry_computation(comps: Dict[str, List[Instr]], hlo_text: str,
                       entry: Optional[str] = None) -> str:
    """The ENTRY computation's name, else the one never called."""
    if entry is not None:
        return entry
    em = re.search(r"^\s*ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if em and em.group(1) in comps:
        return em.group(1)
    called = set()
    for instrs in comps.values():
        for ins in instrs:
            called.update(_CALLED_RE.findall(ins.rhs))
    entries = [c for c in comps if c not in called]
    return entries[0] if entries else next(iter(comps))


# ops whose "output" aliases or annotates existing buffers — zero-cost views
# for liveness purposes (counting them would double-count tuple elements)
_VIEW_OPS = ("get-tuple-element", "tuple", "bitcast", "parameter")


def peak_live_bytes(hlo_text: str, entry: Optional[str] = None) -> float:
    """Static peak of simultaneously-live buffer bytes in the entry frame.

    A linear liveness scan over the entry computation in program order:
    each non-view instruction's output becomes live at its definition and
    dies after its last use; parameters are live from the start; the root
    lives to the end. Called computations (while bodies, fusions) are
    treated as atomic — their internal temporaries are not modeled — so
    this is an *entry-frame* estimate: deterministic, platform-independent,
    and exactly the kind of monotonic signal a regression gate needs
    (a step program that starts double-buffering its carry moves this
    number, timing noise never does). Donation/aliasing is ignored, making
    it a conservative upper bound.
    """
    comps = parse_computations(hlo_text)
    if not comps:
        return 0.0
    instrs = comps.get(_entry_computation(comps, hlo_text, entry), [])
    if not instrs:
        return 0.0
    sizes = {ins.name: (0.0 if ins.opcode in _VIEW_OPS and ins.opcode != "parameter"
                        else float(_shape_bytes(ins.type_str)))
             for ins in instrs}
    last_use = {ins.name: i for i, ins in enumerate(instrs)}  # def-only: die at def
    for i, ins in enumerate(instrs):
        for op in ins.operands:
            if op in last_use:
                last_use[op] = max(last_use[op], i)
    last_use[instrs[-1].name] = len(instrs)  # the root survives the program
    live = peak = 0.0
    # parameters are input buffers: live before the first instruction runs
    for ins in instrs:
        if ins.opcode == "parameter":
            live += sizes[ins.name]
    peak = live
    for i, ins in enumerate(instrs):
        if ins.opcode != "parameter":
            live += sizes[ins.name]
        peak = max(peak, live)
        for op in set(ins.operands) | {ins.name}:
            if last_use.get(op) == i:
                live -= sizes.get(op, 0.0)
    return peak


def analyze_hlo(hlo_text: str, entry: Optional[str] = None) -> Analysis:
    comps = parse_computations(hlo_text)
    if not comps:
        return Analysis(flops=0.0, bytes=0.0,
                        collectives={c: 0.0 for c in _COLLECTIVES} | {"total": 0.0})
    entry = _entry_computation(comps, hlo_text, entry)

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def trip_count(cond_comp: str) -> int:
        consts = [int(x) for x in _CONST_RE.findall(
            "\n".join(i.rhs for i in comps.get(cond_comp, [])))]
        return max(consts) if consts else 1

    def visit(name: str, stack=()) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0.0, 0.0, {c: 0.0 for c in _COLLECTIVES}
        flops = 0.0
        nbytes = 0.0
        coll = {c: 0.0 for c in _COLLECTIVES}
        symtab = {i.name: i for i in comps[name]}
        for ins in comps[name]:
            out_b = _shape_bytes(ins.type_str)
            if ins.opcode == "dot":
                flops += _dot_flops(ins, symtab)
            elif ins.opcode in _ELEMENTWISE:
                dims = _shape_dims(ins.type_str)
                flops += float(math.prod(dims)) if dims else 1.0
            elif ins.opcode == "reduce" and ins.operands:
                src = symtab.get(ins.operands[0])
                if src is not None:
                    dims = _shape_dims(src.type_str)
                    flops += float(math.prod(dims)) if dims else 1.0
            base = ins.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                coll[base] += out_b
            # bytes accessed: outputs + operand reads (skip pure metadata ops)
            if ins.opcode not in ("parameter", "constant", "get-tuple-element",
                                  "tuple", "bitcast"):
                nbytes += out_b
                for op in ins.operands:
                    src = symtab.get(op)
                    if src is not None:
                        nbytes += _shape_bytes(src.type_str)
            # recurse into called computations
            if ins.opcode == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cond_m = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if body_m:
                    trips = trip_count(cond_m.group(1)) if cond_m else 1
                    bf, bb, bc = visit(body_m.group(1), stack + (name,))
                    flops += bf * trips
                    nbytes += bb * trips
                    for c in _COLLECTIVES:
                        coll[c] += bc[c] * trips
                    if cond_m:
                        cf, cb, cc = visit(cond_m.group(1), stack + (name,))
                        flops += cf * trips
                        nbytes += cb * trips
            elif ins.opcode == "fusion":
                # fusion: I/O already counted above; dots inside fused comps
                # still cost flops (rare on CPU, common on TPU backends)
                for callee in _CALLED_RE.findall(ins.rhs):
                    cf, _, cc = visit(callee, stack + (name,))
                    flops += cf
                    for c in _COLLECTIVES:
                        coll[c] += cc[c]
            elif ins.opcode in ("call", "conditional", "custom-call", "reduce",
                                "sort", "scatter", "map", "reduce-window",
                                "select-and-scatter", "all-reduce"):
                for callee in _CALLED_RE.findall(ins.rhs):
                    if callee in ("region",):
                        continue
                    cf, cb, cc = visit(callee, stack + (name,))
                    # reduce/sort/scatter regions are per-element lambdas —
                    # their I/O is not boundary traffic; count flops only.
                    flops += cf
                    for c in _COLLECTIVES:
                        coll[c] += cc[c]
        memo[name] = (flops, nbytes, coll)
        return memo[name]

    f, b, c = visit(entry)
    c = dict(c)
    c["total"] = sum(c.values())
    return Analysis(flops=f, bytes=b, collectives=c, entry=entry)


_MAIN_SIG_RE = re.compile(r"@main\s*\(")
_ARG_SPLIT_RE = re.compile(r"%arg(\d+)\s*:")


def donated_params(lowered_text: str) -> List[int]:
    """Parameter indices with `tf.aliasing_output` in a lowered StableHLO text.

    `jax.jit(..., donate_argnums=...)` stamps every donated parameter of the
    lowered module's `@main` signature with a `tf.aliasing_output = N` attr —
    on every platform, even where the runtime later drops the actual aliasing
    (CPU). That makes the *lowered* text, not the compiled binary, the right
    place to audit donation intent. Parsing note: the attr dict can nest
    braces (`mhlo.sharding = "{replicated}"`), so the signature is split on
    `%argN:` boundaries and each chunk is substring-checked rather than
    brace-matched.
    """
    m = _MAIN_SIG_RE.search(lowered_text)
    if not m:
        return []
    # balance parens from the signature's open paren; quoted attr strings in
    # practice never contain parens, so a plain depth count suffices
    depth, i = 1, m.end()
    while i < len(lowered_text) and depth:
        depth += {"(": 1, ")": -1}.get(lowered_text[i], 0)
        i += 1
    sig = lowered_text[m.end():i - 1]
    chunks = _ARG_SPLIT_RE.split(sig)
    # chunks = [prefix, idx0, body0, idx1, body1, ...]
    out = []
    for idx, body in zip(chunks[1::2], chunks[2::2]):
        if "tf.aliasing_output" in body:
            out.append(int(idx))
    return sorted(out)
