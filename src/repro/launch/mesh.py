"""Production meshes. IMPORTANT: functions only — importing this module must
never touch jax device state (the dry-run sets XLA_FLAGS before any init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single pod = 256 chips; (2, 16, 16) = 2 pods / 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))
