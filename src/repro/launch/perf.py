import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration harness (§Perf): compile a cell under a named VARIANT of
the tunable knobs and report the roofline-term deltas vs. baseline.

Knobs exposed (each one maps to a hypothesis in EXPERIMENTS.md §Perf):
  remat            : none | dots | full          (compute <-> memory trade)
  ce_chunk         : loss-chunk length           (CE temp memory)
  q_chunk          : attention query-chunk       (attention temp memory)
  accum            : gradient-accumulation steps (collective amortisation)
  seq_shard_decode : shard decode cache seq over model axis when heads can't
                     be TP-sharded (collective <-> memory trade)
  dtype            : activation dtype

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch olmoe-1b-7b \
      --shape train_4k --variant remat=dots,accum=4
"""
import argparse
import dataclasses
import json
import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import shape_by_name
from repro.configs.registry import ARCH_IDS, get_config, input_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.sharding import rules
from repro.train.trainer import TrainConfig, make_optimizer, make_train_step

_KNOB_DEFAULTS = {
    "remat": "full",
    "ce_chunk": 512,
    "q_chunk": 512,
    "accum": 1,
    "seq_shard_decode": 0,
    "dtype": "bfloat16",
    "mla_absorb": 0,        # weight-absorbed latent attention
    "moe_ep_only": 0,       # experts: EP over model only (no FSDP gathers)
    "moe_groups": 0,        # shard-local grouped MoE dispatch
    "cache_bf16": 1,        # decode caches in bf16 (0 = match param dtype)
}


def parse_variant(s: str) -> Dict:
    knobs = dict(_KNOB_DEFAULTS)
    if s:
        for kv in s.split(","):
            k, v = kv.split("=")
            knobs[k] = v if k in ("remat", "dtype") else int(v)
    return knobs


def compile_cell(arch: str, shape_name: str, knobs: Dict, multi_pod: bool = False):
    import repro.models.layers as layers_mod
    import repro.models.attention as attn_mod

    # knob injection: chunk sizes are module-level defaults threaded through
    # static args; patch them for this compile only.
    shape = shape_by_name(shape_name)
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, dtype=knobs["dtype"],
                              mla_absorb=bool(knobs["mla_absorb"]),
                              moe_groups=int(knobs["moe_groups"]))
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    if knobs["moe_ep_only"]:
        rules.set_moe_ep_only(True)

    old_ce = layers_mod.chunked_cross_entropy.__defaults__
    layers_mod.chunked_cross_entropy.__defaults__ = (
        None, knobs["ce_chunk"], True)
    old_q = attn_mod.gqa_apply.__kwdefaults__["q_chunk"]
    attn_mod.gqa_apply.__kwdefaults__["q_chunk"] = knobs["q_chunk"]
    attn_mod.mla_apply.__kwdefaults__["q_chunk"] = knobs["q_chunk"]

    try:
        params_shape = jax.eval_shape(lambda k: lm.init_params(cfg, k), key)
        psh = rules.to_shardings(rules.param_specs(params_shape, mesh), mesh)

        if shape.kind == "train":
            tc = TrainConfig(remat=knobs["remat"], accum_steps=knobs["accum"])
            opt_shape = jax.eval_shape(lambda p: make_optimizer(tc).init(p), params_shape)
            osh = rules.to_shardings(rules.opt_specs(opt_shape, params_shape, mesh), mesh)
            batch = input_specs(cfg, shape)
            bsh = rules.to_shardings(rules.batch_specs(mesh, batch), mesh)
            fn = make_train_step(cfg, tc)
            jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None), donate_argnums=(0, 1))
            args = (_shaped(params_shape, psh), _shaped(opt_shape, osh), _shaped(batch, bsh))
        elif shape.kind == "prefill":
            batch = input_specs(cfg, shape)
            bsh = rules.to_shardings(rules.batch_specs(mesh, batch), mesh)
            jitted = jax.jit(lambda p, b: lm.prefill(cfg, p, b, max_seq=shape.seq_len),
                             in_shardings=(psh, bsh))
            args = (_shaped(params_shape, psh), _shaped(batch, bsh))
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            b = shape.global_batch
            cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, b, shape.seq_len))
            seq_sharded = b == 1
            cspec = rules.cache_specs(mesh, cache_shape, b, seq_sharded=seq_sharded)
            if knobs["seq_shard_decode"]:
                cspec = _seq_shard_over_model(cspec, cache_shape, mesh)
            csh = rules.to_shardings(cspec, mesh)
            tok = input_specs(cfg, shape)["tokens"]
            tsh = rules.to_shardings(rules.batch_specs(mesh, {"tokens": tok}), mesh)["tokens"]
            jitted = jax.jit(lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos),
                             in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
                             out_shardings=(None, csh), donate_argnums=(1,))
            args = (_shaped(params_shape, psh), _shaped(cache_shape, csh),
                    jax.ShapeDtypeStruct(tok.shape, tok.dtype, sharding=tsh),
                    jax.ShapeDtypeStruct((), jnp.int32))

        t0 = time.perf_counter()
        with mesh:
            compiled = jitted.lower(*args).compile()
        dt = time.perf_counter() - t0
    finally:
        layers_mod.chunked_cross_entropy.__defaults__ = old_ce
        attn_mod.gqa_apply.__kwdefaults__["q_chunk"] = old_q
        attn_mod.mla_apply.__kwdefaults__["q_chunk"] = old_q
    return compiled, dt, mesh, cfg


def _shaped(tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), tree, shardings)


def _seq_shard_over_model(cspec, cache_shape, mesh):
    """Shard decode KV-cache SEQ dim over 'model' when heads can't TP-shard."""
    from jax.sharding import PartitionSpec as P

    def fix(spec, leaf):
        if leaf.ndim >= 5 and spec[2] is None and leaf.shape[3] % mesh.shape["model"] == 0 \
                and leaf.shape[3] > 1024:
            lst = list(spec) + [None] * (leaf.ndim - len(spec))
            lst[3] = "model" if lst[3] is None else lst[3]
            return P(*lst)
        return spec

    return jax.tree.map(fix, cspec, cache_shape,
                        is_leaf=lambda x: isinstance(x, P))


def score_traffic_bytes(hlo_text: str, kv_len: int) -> float:
    """Bytes moved through attention-score-shaped tensors (f32, minor dim =
    kv length, rank ≥ 4). The Pallas flash kernel (kernels/attention) keeps
    these in VMEM on TPU, so `memory_s - score_traffic/HBM_BW` is the
    projected TPU memory term with the kernel engaged."""
    import re as _re

    from repro.launch import hlo_analysis as ha

    comps = ha.parse_computations(hlo_text)
    em = _re.search(r"^\s*ENTRY\s+%?([\w.\-]+)", hlo_text, _re.MULTILINE)
    if not em:
        return 0.0
    total = [0.0]

    def trip(cond):
        consts = [int(x) for x in ha._CONST_RE.findall(
            "\n".join(i.rhs for i in comps.get(cond, [])))]
        return max(consts) if consts else 1

    def is_score(type_str):
        m = ha._SHAPE_RE.search(type_str)
        if not m or m.group(1) != "f32":
            return False
        dims = [int(d) for d in m.group(2).split(",") if d]
        return len(dims) >= 4 and dims[-1] == kv_len

    def visit(name, mult, stack=()):
        if name in stack or name not in comps:
            return
        symtab = {i.name: i for i in comps[name]}
        for ins in comps[name]:
            if ins.opcode not in ("parameter", "constant", "get-tuple-element",
                                  "tuple", "bitcast"):
                if is_score(ins.type_str):
                    total[0] += ha._shape_bytes(ins.type_str) * mult
                for op in ins.operands:
                    src = symtab.get(op)
                    if src is not None and is_score(src.type_str):
                        total[0] += ha._shape_bytes(src.type_str) * mult
            if ins.opcode == "while":
                bm = _re.search(r"body=%?([\w.\-]+)", ins.rhs)
                cm = _re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                if bm:
                    visit(bm.group(1), mult * (trip(cm.group(1)) if cm else 1),
                          stack + (name,))

    visit(em.group(1), 1.0)
    return total[0]


def measure(arch: str, shape_name: str, variant: str, multi_pod: bool = False) -> Dict:
    knobs = parse_variant(variant)
    compiled, dt, mesh, cfg = compile_cell(arch, shape_name, knobs, multi_pod)
    hlo = analyze_hlo(compiled.as_text())
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {"temp_gib": ma.temp_size_in_bytes / 2**30,
               "args_gib": ma.argument_size_in_bytes / 2**30}
    except (AttributeError, NotImplementedError):
        pass  # backend exposes no memory stats; anything else should raise
    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    from repro.configs.base import shape_by_name as _sbn

    kv_len = _sbn(shape_name).seq_len
    score_b = score_traffic_bytes(compiled.as_text(), kv_len)
    res = {
        "arch": arch, "shape": shape_name, "variant": variant or "baseline",
        "knobs": knobs, "compile_s": round(dt, 1),
        "flops_per_device": hlo["flops"],
        "bytes_per_device": hlo["bytes"],
        "collective_bytes_per_device": hlo["collectives"],
        "compute_s": hlo["flops"] / PEAK_FLOPS,
        "memory_s": hlo["bytes"] / HBM_BW,
        "collective_s": hlo["collectives"]["total"] / ICI_BW,
        "score_traffic_s": score_b / HBM_BW,
        "memory_s_flash": (hlo["bytes"] - score_b) / HBM_BW,
        **mem,
    }
    res["bound_s"] = max(res["compute_s"], res["memory_s"], res["collective_s"])
    res["bound_s_flash"] = max(res["compute_s"], res["memory_s_flash"], res["collective_s"])
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    res = measure(args.arch, args.shape, args.variant, args.multi_pod)
    print(json.dumps(res, indent=2))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
