"""checkpoint subsystem."""
