"""Mesh-agnostic checkpointing: atomic, keep-k, restorable onto any mesh.

Format: one directory per step containing
  - `tree.json`   : flattened key-paths, shapes, dtypes (the pytree schema)
  - `arrays.npz`  : one entry per leaf, keyed by its path string
  - `meta.json`   : optional host-side metadata (scheduler tables, session
                    bookkeeping — anything JSON, written atomically with the
                    arrays; serving/env_service.py's restart path uses it)

Arrays are stored UNSHARDED (gathered), so a checkpoint written from a
(16, 16) mesh restores onto (2, 16, 16), (8, 8) or a single CPU device —
this is the elastic-scaling contract (runtime/elastic.py). On a real
multi-host cluster the same layout is written per-shard with a process-0
manifest; the single-host gather form keeps semantics identical.

Writes are atomic (tmp dir + os.replace) so a preemption mid-save never
corrupts the latest checkpoint; `save(..., blocking=False)` runs the write
off the rollout loop's critical path. The gather (device -> host, with a
copy so donated buffers cannot be reused under the snapshot) always happens
on the caller thread — only the file I/O is deferred.

Concurrency contract (tests/test_checkpoint.py):
  - writes are SERIALIZED: a save (blocking or not) never starts until the
    previous write — and its keep-k GC — has finished, so GC can never
    collect around an in-flight tmp dir;
  - the writer thread is non-daemon, so an interpreter exit joins it instead
    of silently dropping the newest checkpoint mid-write;
  - `wait()` joins the in-flight write and re-raises its error, `close()` is
    wait + refuse further saves (also usable as a context manager).

Fault injection: `_pre_replace_hook`, when set, runs after the tmp dir is
fully written and immediately before the atomic rename — the exact window a
preemption mid-save lands in. The fault harness (runtime/failures.py
FaultInjector "preempt_save") raises from it to prove atomicity.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        # copy: on CPU backends device_get can alias the device buffer, and
        # donated carries reuse those buffers on the next step — an aliased
        # snapshot would silently mutate under the writer thread
        out[key] = np.array(jax.device_get(leaf), copy=True)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._write_lock = threading.Lock()  # serializes write + keep-k GC
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._closed = False
        #: test seam — called with the tmp path between the fully-written tmp
        #: dir and the atomic os.replace (the mid-save preemption window)
        self._pre_replace_hook: Optional[Callable[[str], None]] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree, blocking: bool = True,
             meta: Optional[Dict] = None) -> str:
        if self._closed:
            raise RuntimeError(f"CheckpointManager({self.directory}) is closed")
        self.wait()  # serialize: one write in flight, errors surface here
        flat = _flatten(tree)  # gather on the caller thread (device -> host)
        treedef = jax.tree_util.tree_structure(tree)
        schema = {
            "step": step,
            "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }

        def write():
            with self._write_lock:
                final = os.path.join(self.directory, f"step_{step:010d}")
                tmp = final + ".tmp"
                shutil.rmtree(tmp, ignore_errors=True)  # stale preempted write
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "tree.json"), "w") as f:
                    json.dump(schema, f)
                if meta is not None:
                    with open(os.path.join(tmp, "meta.json"), "w") as f:
                        json.dump(meta, f)
                if self._pre_replace_hook is not None:
                    self._pre_replace_hook(tmp)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()

        if blocking:
            write()
        else:
            # non-daemon: interpreter exit joins the write instead of
            # dropping it mid-file
            # repro: allow[unguarded-mutation] single-writer contract: save()/wait()/close() run on one owner thread; _write_lock only serializes the directory writes
            self._thread = threading.Thread(
                target=self._run_write, args=(write,),
                name=f"ckpt-save-{step}", daemon=False)
            self._thread.start()
        return os.path.join(self.directory, f"step_{step:010d}")

    def _run_write(self, write) -> None:
        try:
            write()
        except BaseException as e:  # repro: allow[silent-except,unguarded-mutation] not swallowed: stored and re-raised by wait(); the store is ordered before the owner's join()
            self._error = e

    def wait(self) -> None:
        """Join the in-flight write; re-raise its error, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None  # repro: allow[unguarded-mutation] owner-thread bookkeeping; join() above is the happens-before for _error
        if self._error is not None:
            # repro: allow[unguarded-mutation] owner thread only, after join()
            err, self._error = self._error, None
            raise err

    def close(self) -> None:
        """Join pending writes and refuse further saves."""
        self._closed = True  # repro: allow[unguarded-mutation] owner-thread latch; save() checks it on the same thread
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _step_path(self, step: Optional[int]) -> str:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return os.path.join(self.directory, f"step_{step:010d}")

    def read_meta(self, step: Optional[int] = None) -> Optional[Dict]:
        """The `meta=` dict written with the checkpoint (None if absent)."""
        path = os.path.join(self._step_path(step), "meta.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def restore(self, template: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Pytree:
        """Restore into `template`'s structure; `shardings` may target ANY mesh."""
        path = self._step_path(step)
        data = np.load(os.path.join(path, "arrays.npz"))
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(paths_and_leaves)
        )
        out = []
        for (p, leaf), sh in zip(paths_and_leaves, shard_leaves):
            key = jax.tree_util.keystr(p)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs template {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
