"""Mesh-agnostic checkpointing: atomic, keep-k, restorable onto any mesh.

Format: one directory per step containing
  - `tree.json`   : flattened key-paths, shapes, dtypes (the pytree schema)
  - `arrays.npz`  : one entry per leaf, keyed by its path string

Arrays are stored UNSHARDED (gathered), so a checkpoint written from a
(16, 16) mesh restores onto (2, 16, 16), (8, 8) or a single CPU device —
this is the elastic-scaling contract (runtime/elastic.py). On a real
multi-host cluster the same layout is written per-shard with a process-0
manifest; the single-host gather form keeps semantics identical.

Writes are atomic (tmp dir + os.replace) so a preemption mid-save never
corrupts the latest checkpoint; `save(..., blocking=False)` runs the write
in a daemon thread off the training loop's critical path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree, blocking: bool = True) -> str:
        flat = _flatten(tree)  # gather on the caller thread (device -> host)
        treedef = jax.tree_util.tree_structure(tree)
        schema = {
            "step": step,
            "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        }

        def write():
            final = os.path.join(self.directory, f"step_{step:010d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "tree.json"), "w") as f:
                json.dump(schema, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return os.path.join(self.directory, f"step_{step:010d}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Pytree:
        """Restore into `template`'s structure; `shardings` may target ANY mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(paths_and_leaves)
        )
        out = []
        for (p, leaf), sh in zip(paths_and_leaves, shard_leaves):
            key = jax.tree_util.keystr(p)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs template {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
