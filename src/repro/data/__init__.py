"""data subsystem."""
