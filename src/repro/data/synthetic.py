"""Deterministic synthetic data pipeline (host-sharded, restart-exact).

Every batch is a pure function of (seed, step, host_id), so:
  - restarts replay the exact stream from the checkpointed step (no data
    loss / duplication across failures — the fault-tolerance contract);
  - each host materialises only its slice of the global batch;
  - elastic re-scaling re-slices the same global stream.

Two generators:
  - `random_stream`  : uniform tokens (throughput benchmarking)
  - `markov_stream`  : an order-1 Markov chain with a banded transition
    matrix — has real, learnable structure so example training losses visibly
    drop below log(V) (used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"     # markov | random
    num_hosts: int = 1
    host_id: int = 0


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # Philox keyed on (seed, step, host) — O(1) seek to any step.
    key = (np.uint64(cfg.seed) << np.uint64(32)) ^ np.uint64(step)
    return np.random.Generator(np.random.Philox(key=[key, np.uint64(cfg.host_id)]))


def _markov_matrix(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.Generator(np.random.Philox(key=[np.uint64(seed), np.uint64(0xBEEF)]))
    base = rng.random((vocab, 8))  # 8 plausible successors per token
    succ = (np.arange(vocab)[:, None] * 7 + np.arange(8)[None] * 13 + 1) % vocab
    probs = base / base.sum(-1, keepdims=True)
    return succ, probs


def batch_at_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    local_batch = cfg.global_batch // cfg.num_hosts
    rng = _rng(cfg, step)
    if cfg.kind == "random":
        tokens = rng.integers(0, cfg.vocab_size, (local_batch, cfg.seq_len + 1), dtype=np.int32)
    else:
        succ, probs = _markov_matrix(cfg.vocab_size, cfg.seed)
        tokens = np.empty((local_batch, cfg.seq_len + 1), np.int32)
        tokens[:, 0] = rng.integers(0, cfg.vocab_size, local_batch)
        # vectorised chain: pick one of 8 successors per position
        choices = rng.random((local_batch, cfg.seq_len))
        for t in range(cfg.seq_len):
            p = probs[tokens[:, t]]                      # (B, 8)
            cum = np.cumsum(p, axis=-1)
            pick = (choices[:, t : t + 1] < cum).argmax(-1)
            tokens[:, t + 1] = succ[tokens[:, t], pick]
    return {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:].astype(np.int32),
    }


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at_step(cfg, step)
        step += 1
