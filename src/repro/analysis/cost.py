"""Static compiled-cost model + perf/carbon regression gate.

`python -m repro.analysis.cost --smoke --check BENCH_cost_baseline.json`

Runtime benchmarks are too noisy to gate in CI, but the compiled artifact
is deterministic: the same source always lowers to the same HLO, and the
HLO's FLOPs / bytes-moved / live-buffer footprint are exact static
quantities. This module extends the PR-8 audit sweep (`analysis/audit.py`)
from *invariant* gating (residency/donation/retraces) to *cost* gating:
for every id × backend cell (plus the fused-train cells) it lowers the
donated step program and emits a per-cell cost record:

  flops_per_step / bytes_per_step : trip-count-aware HLO totals from
      `launch/hlo_analysis.py`, normalised by env steps per program;
  peak_live_bytes  : static liveness-scan peak of the entry frame;
  collective bytes : per-step inter-chip traffic (sharded cells);
  arithmetic intensity + roofline : where the cell sits against the
      `benchmarks/roofline.py` machine ceilings (compute- vs memory- vs
      collective-bound, and the static time bound per step);
  xla_cost_analysis / xla_memory_analysis : XLA's own numbers alongside
      ours, for cross-checking (informational, not gated);
  static_impact : the CaiRL Table II analogue derived from the roofline
      bound — joules and gCO₂ per million env steps, at compile time
      (`sustainability.impact.StaticImpact`).

The regression gate: `check(report, baseline)` diffs the gated metrics
(GATED_METRICS) against a committed `BENCH_cost_baseline.json` with
per-family relative thresholds (DEFAULT_THRESHOLDS) and returns
`(problems, notes)` — problems name the cell, metric, and delta, and make
the CLI exit nonzero; improvements beyond threshold and new cells are
notes suggesting a reviewed `--regen-baseline`. `make cost-check` runs
this inside `make test-fast`, so a PR that inflates a fused env's compiled
cost >threshold fails loudly with zero timing noise.

Smoke mode sweeps the dispatch-distinct backends only (vmap + pallas: the
async/sharded step programs wrap the same cores, and the full matrix is
already residency-audited by `analysis.audit`); full mode covers all four.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.audit import (BACKENDS, EXPECTED_REFUSALS, TRAIN_BACKEND,
                                  _build_pool, _lower_step)
from repro.core.registry import registered, spec
from repro.launch.hlo_analysis import analyze_hlo, peak_live_bytes
from repro.sustainability.impact import ACCELERATOR_TDP_WATTS, StaticImpact

try:  # benchmarks/ is a repo-root package; importable from make targets,
    # but src-only contexts fall back to the same documented constants
    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
except ImportError:  # pragma: no cover - mirrors benchmarks/roofline.py
    PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip (TPU v5e)
    HBM_BW = 819e9       # B/s per chip
    ICI_BW = 50e9        # B/s per link

#: backends swept in smoke mode (the two distinct step-kernel paths; async/
#: sharded wrap the same cores and stay in the full sweep + audit matrix)
SMOKE_BACKENDS = ("vmap", "pallas")

#: metrics the regression gate diffs against the baseline (all exact static
#: quantities from our own parsers — XLA's numbers are informational)
GATED_METRICS = ("flops_per_step", "bytes_per_step", "peak_live_bytes")

#: per-family relative regression thresholds. Arcade carries the pixel
#: rasteriser (layout-sensitive fusion decisions) and train programs fold
#: whole learners in — both get more headroom than the small cores.
DEFAULT_THRESHOLDS: Dict[str, float] = {
    "classic": 0.10, "grid": 0.10, "puzzle": 0.10, "flash": 0.10,
    "arcade": 0.15, "train": 0.15,
}
FALLBACK_THRESHOLD = 0.10

_FAMILIES = ("classic", "grid", "arcade", "puzzle", "flash")


def family_of(env_id: str, backend: str = "vmap") -> str:
    """Env family (threshold bucket) of a cell: the registry spec tag for
    pool cells, the fixed "train" family for fused-train cells."""
    if backend == TRAIN_BACKEND:
        return "train"
    tags = spec(env_id).tags
    for fam in _FAMILIES:
        if fam in tags:
            return fam
    return "other"


def threshold_for(family: str,
                  thresholds: Optional[Dict[str, float]] = None) -> float:
    return (thresholds or DEFAULT_THRESHOLDS).get(family, FALLBACK_THRESHOLD)


def _xla_cost_analysis(compiled) -> Dict[str, float]:
    """XLA's own cost numbers, normalised (newer jax returns a dict, older
    a one-element list) and trimmed to the cross-checkable keys."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # repro: allow[silent-except] informational cross-check only; absent on some platforms
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = ("flops", "bytes accessed", "optimal_seconds")
    return {k: float(ca[k]) for k in keep
            if isinstance(ca.get(k), (int, float))}


def _xla_memory_analysis(compiled) -> Dict[str, float]:
    """XLA's buffer-assignment sizes (unavailable on CPU backends)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:  # repro: allow[silent-except] informational cross-check only; raises NotImplementedError on CPU
        return {}
    out = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def _roofline(flops_ps: float, bytes_ps: float,
              coll_ps: float) -> Dict[str, Any]:
    """Static roofline position of one env step against the per-chip
    ceilings: per-term time bounds, the binding term, and where the cell's
    arithmetic intensity sits relative to the machine balance point."""
    compute_s = flops_ps / PEAK_FLOPS
    memory_s = bytes_ps / HBM_BW
    collective_s = coll_ps / ICI_BW
    terms = (("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s))
    dominant, bound_s = max(terms, key=lambda kv: kv[1])
    balance = PEAK_FLOPS / HBM_BW  # FLOP/byte where compute == memory time
    intensity = flops_ps / bytes_ps if bytes_ps else 0.0
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bound_s": bound_s, "dominant": dominant,
        "balance_intensity": balance,
        "intensity_vs_balance": intensity / balance if balance else 0.0,
    }


def _cost_record(row: Dict[str, Any], lowered, steps_per_program: int
                 ) -> Dict[str, Any]:
    """Fill `row` with the static cost of a lowered step program whose one
    execution advances `steps_per_program` env steps."""
    compiled = lowered.compile()
    hlo = compiled.as_text()
    analysis = analyze_hlo(hlo)
    n = max(steps_per_program, 1)
    flops_ps = analysis.flops / n
    bytes_ps = analysis.bytes / n
    coll_ps = analysis.collective_bytes / n
    roofline = _roofline(flops_ps, bytes_ps, coll_ps)
    impact = StaticImpact(seconds_per_step=roofline["bound_s"],
                          watts=ACCELERATOR_TDP_WATTS)
    row.update(
        status="ok",
        env_steps_per_program=steps_per_program,
        flops=analysis.flops,
        bytes=analysis.bytes,
        collective_bytes=analysis.collective_bytes,
        peak_live_bytes=peak_live_bytes(hlo),
        flops_per_step=flops_ps,
        bytes_per_step=bytes_ps,
        collective_bytes_per_step=coll_ps,
        arithmetic_intensity=flops_ps / bytes_ps if bytes_ps else 0.0,
        roofline=roofline,
        static_impact=impact.report(),
        xla_cost_analysis=_xla_cost_analysis(compiled),
        xla_memory_analysis=_xla_memory_analysis(compiled),
    )
    return row


def cost_cell(env_id: str, backend: str, batch: int) -> Dict[str, Any]:
    """Cost one (id, backend) pool cell; refusals are recorded rows, same
    named-refusal protocol as the audit."""
    row: Dict[str, Any] = {"id": env_id, "backend": backend, "batch": batch,
                           "family": family_of(env_id, backend)}
    try:
        pool = _build_pool(env_id, backend, batch)
        lowered, _ = _lower_step(pool, backend)
    except Exception as e:  # repro: allow[silent-except] named-refusal protocol: class+message recorded, judged against EXPECTED_REFUSALS
        row.update(status="refused", refusal=type(e).__name__,
                   refusal_msg=str(e).splitlines()[0][:200])
        return row
    # one program execution steps every env in the batch once
    return _cost_record(row, lowered, batch)


def cost_train_cell(gid: str, chunk: int = 8) -> Dict[str, Any]:
    """Cost one fused-train program (a GOLDEN_TRAIN_IDS "<algo>/<env>" id).

    Env steps per program: each of the `chunk` scanned train steps advances
    `num_envs` envs once (DQN) or through a full rollout (PPO).
    """
    row: Dict[str, Any] = {"id": gid, "backend": TRAIN_BACKEND,
                           "chunk": chunk, "family": "train"}
    try:
        from repro.train.fused import golden_train_setup, lower_train_chunk

        algo, env_id, cfg, _ = golden_train_setup(gid)
        row["batch"] = cfg.num_envs
        lowered, _ = lower_train_chunk(algo, env_id, cfg, chunk=chunk)
        steps = chunk * cfg.num_envs * getattr(cfg, "rollout_len", 1)
    except Exception as e:  # repro: allow[silent-except] named-refusal protocol (see cost_cell)
        row.update(status="refused", refusal=type(e).__name__,
                   refusal_msg=str(e).splitlines()[0][:200])
        return row
    return _cost_record(row, lowered, steps)


def plan(ids: Optional[Sequence[str]] = None,
         backends: Sequence[str] = BACKENDS) -> List[Tuple[str, str]]:
    """The cost matrix: every registry id × every requested backend (the
    audit matrix restricted to `backends`)."""
    ids = list(ids) if ids else sorted(registered())
    return [(i, b) for i in ids for b in backends]


def run(ids: Optional[Sequence[str]] = None,
        backends: Optional[Sequence[str]] = None, batch: int = 4,
        smoke: bool = True, train: Optional[bool] = None,
        chunk: int = 8, progress=None) -> Dict[str, Any]:
    """Run the cost sweep; returns the report dict.

    `train=None` means auto: on for full-registry sweeps, off with an
    explicit `ids` subset (same convention as the audit)."""
    if backends is None:
        backends = SMOKE_BACKENDS if smoke else BACKENDS
    cells = plan(ids, backends)
    train = (ids is None) if train is None else train
    rows: List[Dict[str, Any]] = []
    for env_id, backend in cells:
        row = cost_cell(env_id, backend, batch)
        rows.append(row)
        if progress:
            progress(row)
    train_ids: Tuple[str, ...] = ()
    if train:
        from repro.train.fused import GOLDEN_TRAIN_IDS

        train_ids = GOLDEN_TRAIN_IDS
        for gid in train_ids:
            row = cost_train_cell(gid, chunk=chunk)
            rows.append(row)
            if progress:
                progress(row)
    hosted = [r for r in rows if r["status"] == "ok"]
    unexpected = [r for r in rows if r["status"] == "refused"
                  and r["refusal"] not in EXPECTED_REFUSALS]
    return {
        "meta": {
            "smoke": smoke,
            "batch": batch,
            "chunk": chunk,
            "jax": jax.__version__,
            "platform": jax.default_backend(),
            "backends": list(backends),
            "ids": sorted({c[0] for c in cells}),
            "train_cells": list(train_ids),
            "thresholds": dict(DEFAULT_THRESHOLDS),
            "gated_metrics": list(GATED_METRICS),
            "ceilings": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                         "ici_bw": ICI_BW,
                         "accelerator_watts": ACCELERATOR_TDP_WATTS},
        },
        "rows": rows,
        "summary": {
            "cells": len(rows),
            "hosted": len(hosted),
            "refused": len(rows) - len(hosted),
            "unexpected_refusals": [f"{r['id']}×{r['backend']}: "
                                    f"{r['refusal']}" for r in unexpected],
        },
    }


def _key(row: Dict[str, Any]) -> Tuple[str, str]:
    return (row["id"], row["backend"])


def check(report: Dict[str, Any], baseline: Dict[str, Any],
          thresholds: Optional[Dict[str, float]] = None
          ) -> Tuple[List[str], List[str]]:
    """Diff a fresh cost report against the committed baseline.

    Returns `(problems, notes)`. Problems (gate failures, nonzero exit):
      - a gated metric regressed beyond the cell family's threshold
        (named cell + metric + relative delta);
      - a baseline-hosted cell is missing from or refused by the report;
      - a cell's batch/steps-per-program changed (costs not comparable).
    Notes (printed, never failing): improvements beyond threshold and new
    cells — both suggest a reviewed `--regen-baseline`.
    """
    problems: List[str] = []
    notes: List[str] = []
    new_rows = {_key(r): r for r in report["rows"]}
    base_rows = {_key(r): r for r in baseline["rows"]}
    base_platform = baseline.get("meta", {}).get("platform")
    platform = report.get("meta", {}).get("platform")
    if base_platform and platform and base_platform != platform:
        notes.append(f"platform changed {base_platform} -> {platform}; "
                     "compiled costs may legitimately differ")
    for key, base in sorted(base_rows.items()):
        tag = f"{key[0]}×{key[1]}"
        new = new_rows.get(key)
        if new is None:
            problems.append(f"{tag}: cell missing from the new report "
                            "(id or backend dropped?)")
            continue
        if base["status"] == "refused":
            if new["status"] == "ok":
                notes.append(f"{tag}: newly hosted (was refused: "
                             f"{base['refusal']}) — regen the baseline to "
                             "start gating it")
            continue
        if new["status"] == "refused":
            problems.append(f"{tag}: was hosted in the baseline, now "
                            f"refused ({new['refusal']}: "
                            f"{new.get('refusal_msg', '')})")
            continue
        for dim in ("batch", "env_steps_per_program"):
            if base.get(dim) != new.get(dim):
                problems.append(f"{tag}: {dim} changed "
                                f"{base.get(dim)} -> {new.get(dim)}; "
                                "costs not comparable — regen the baseline")
                break
        else:
            fam = new.get("family") or base.get("family", "other")
            thr = threshold_for(fam, thresholds)
            for metric in GATED_METRICS:
                b, n = base.get(metric, 0.0), new.get(metric, 0.0)
                if not b:
                    continue
                rel = (n - b) / b
                if rel > thr:
                    problems.append(
                        f"{tag}: {metric} regressed {rel:+.1%} "
                        f"({b:.4g} -> {n:.4g}; {fam} threshold "
                        f"{thr:.0%})")
                elif rel < -thr:
                    notes.append(
                        f"{tag}: {metric} improved {rel:+.1%} "
                        f"({b:.4g} -> {n:.4g}) — regen the baseline to "
                        "lock it in")
    for key in sorted(set(new_rows) - set(base_rows)):
        notes.append(f"{key[0]}×{key[1]}: new cell not in the baseline — "
                     "regen to start gating it")
    return problems, notes


def summary_table(report: Dict[str, Any]) -> str:
    """Per-family cost summary (the `make analyze` console table)."""
    by_fam: Dict[str, List[Dict[str, Any]]] = {}
    for r in report["rows"]:
        if r["status"] == "ok":
            by_fam.setdefault(r.get("family", "other"), []).append(r)
    lines = [f"  {'family':<8} {'cells':>5} {'flops/step':>12} "
             f"{'bytes/step':>12} {'peak live B':>12} {'dominant':>10} "
             f"{'J/Mstep':>10}"]
    for fam in sorted(by_fam):
        rows = by_fam[fam]
        med = sorted(r["flops_per_step"] for r in rows)[len(rows) // 2]
        medb = sorted(r["bytes_per_step"] for r in rows)[len(rows) // 2]
        peak = max(r["peak_live_bytes"] for r in rows)
        doms = [r["roofline"]["dominant"] for r in rows]
        dom = max(set(doms), key=doms.count)
        joules = max(r["static_impact"]["joules_per_mstep"] for r in rows)
        lines.append(f"  {fam:<8} {len(rows):>5} {med:>12.4g} {medb:>12.4g} "
                     f"{peak:>12.4g} {dom:>10} {joules:>10.4g}")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.cost",
        description="static compiled-cost model + perf/carbon regression "
                    "gate (see docs/analysis.md)")
    ap.add_argument("--smoke", action="store_true",
                    help="small batch, vmap+pallas backends only (the "
                         "make-cost-check / test-fast mode)")
    ap.add_argument("--ids", default="",
                    help="comma-separated id subset (default: full registry)")
    ap.add_argument("--backends", default="",
                    help=f"comma-separated backend subset of {BACKENDS} "
                         "(default: vmap,pallas in smoke, all four full)")
    ap.add_argument("--batch", type=int, default=0,
                    help="envs per pool (default: 4 smoke, 16 full)")
    ap.add_argument("--train", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="cost the fused-train programs too (default: auto "
                         "— on for full-registry sweeps, off with --ids)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the cost report as JSON")
    ap.add_argument("--check", default="", metavar="BASELINE",
                    help="diff against a committed baseline; exit nonzero "
                         "on any above-threshold regression")
    ap.add_argument("--regen-baseline", default="", metavar="BASELINE",
                    help="write the report as the new committed baseline "
                         "(review the diff!)")
    ap.add_argument("--table", action="store_true",
                    help="print the per-family cost summary table")
    args = ap.parse_args(argv)
    ids = [i.strip() for i in args.ids.split(",") if i.strip()] or None
    backends: Optional[Tuple[str, ...]] = tuple(
        b.strip() for b in args.backends.split(",") if b.strip()) or None
    if backends and (unknown := set(backends) - set(BACKENDS)):
        ap.error(f"unknown backends {sorted(unknown)}; expected {BACKENDS}")
    batch = args.batch or (4 if args.smoke else 16)

    def progress(row):
        if row["status"] == "ok":
            rl = row["roofline"]
            detail = (f"{row['flops_per_step']:.4g} flop/step, "
                      f"{row['bytes_per_step']:.4g} B/step, "
                      f"{rl['dominant']}-bound")
        else:
            detail = f"refused: {row['refusal']}"
        print(f"  {row['id']:>18} × {row['backend']:<11} "
              f"{row['status']:<7} {detail}", flush=True)

    report = run(ids=ids, backends=backends, batch=batch, smoke=args.smoke,
                 train=args.train, progress=progress)
    for path in (args.json, args.regen_baseline):
        if path:
            with open(path, "w") as f:
                json.dump(report, f, indent=2, sort_keys=True)
            print(f"repro.analysis.cost: wrote {path}")
    if args.table:
        print(summary_table(report))
    s = report["summary"]
    print(f"repro.analysis.cost: {s['cells']} cells "
          f"({s['hosted']} hosted, {s['refused']} refused)")
    rc = 0
    for r in s["unexpected_refusals"]:
        print(f"  UNEXPECTED REFUSAL: {r}")
        rc = 1
    if args.check:
        try:
            with open(args.check) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"  BASELINE MISSING: {args.check} — run "
                  f"--regen-baseline {args.check} and commit it")
            return 1
        problems, notes = check(report, baseline)
        for n in notes:
            print(f"  note: {n}")
        for p in problems:
            print(f"  COST REGRESSION: {p}")
        print(f"repro.analysis.cost: gate "
              f"{'FAILED' if problems else 'ok'} vs {args.check} "
              f"({len(problems)} problem(s), {len(notes)} note(s))")
        rc = 1 if problems else rc
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
