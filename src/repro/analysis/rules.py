"""Rule catalog + pragma grammar for the JAX-aware AST lint.

Each rule names one invariant the compiled stack depends on. The catalog is
data (`RULES`), so the CLI, the docs generator and the pragma validator all
answer from one table. Intentional violations are allowlisted in source:

    risky_line()   # repro: allow[rule-name] why this is safe here

The pragma applies to its own line and to the line directly below it (so it
can sit on its own line above a multi-line statement). Several rules can be
listed comma-separated: `# repro: allow[key-reuse,tracer-branch] ...`.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, Set

#: rule id -> one-line description (the catalog docs/analysis.md renders)
RULES: Dict[str, str] = {
    "key-reuse": (
        "a locally-derived PRNG key is consumed by two calls (or used again "
        "after being split) — every consumer must get its own split/fold_in"),
    "host-read-in-jit": (
        "wall-clock, Python random, numpy.random or environment reads inside "
        "a function reachable from jax.jit in this module — the value freezes "
        "at trace time and breaks deterministic resume"),
    "use-after-donate": (
        "a value passed in a donated argument position is read after the "
        "donating call — its buffer may already be reused by XLA"),
    "tracer-branch": (
        "Python if/while on a value produced by jnp/lax/random inside a "
        "jit-reachable function — branches on tracers raise at trace time or "
        "silently specialize"),
    "unguarded-mutation": (
        "shared attribute mutated outside the owning class's lock/condition "
        "in a class that synchronizes with threading primitives"),
    "lock-discipline": (
        "a field that is written under the class's lock/condition elsewhere "
        "is written — or a helper that writes it is called — without holding "
        "the lock; every cross-thread writer of a guarded field must share "
        "the guard"),
    "donation-lifetime": (
        "a donated buffer stays reachable after the donating call through an "
        "alias, a helper-function caller, or a second donated argument "
        "position — aliases and transitive callers must treat the donated "
        "value as dead"),
    "silent-except": (
        "broad `except Exception` (or bare except) that neither re-raises "
        "nor logs — unexpected errors vanish"),
    "wall-clock": (
        "time.time() used for timing — wall clock can step backwards; use "
        "time.perf_counter() (durations) or time.monotonic() (deadlines)"),
}

_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s\-]+)\]")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


def pragma_lines(source: str) -> Dict[int, Set[str]]:
    """Line number -> rule ids allowlisted on that line.

    A pragma on line L covers violations reported at L and L+1; unknown rule
    names in a pragma are themselves reported by the linter (a typo'd pragma
    that silently allowlists nothing is worse than no pragma).
    """
    out: Dict[int, Set[str]] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out
    # only real COMMENT tokens carry pragmas — a pragma *example* quoted in
    # a docstring (like the one above) must not allowlist anything
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if not m:
            continue
        i = tok.start[0]
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        out.setdefault(i + 1, set()).update(rules)
    return out
