"""repro.analysis — static-analysis gates for the JAX/Pallas stack.

The paper's efficiency claim rests on invariants the test suite can only
spot-check: zero host transfers on compiled step paths, full donation of
the XLA-resident carry, no silent recompilation, bit-exact PRNG key
chains, and lock-guarded shared state in the threaded serving layer. This
package enforces them mechanically, in three passes behind one `make
analyze` gate:

  - `repro.analysis.lint`  : AST lint — JAX-specific source rules
    (PRNG key reuse, host reads inside jitted code, use-after-donate,
    Python branches on tracers, unguarded cross-thread mutation, silent
    exception swallows, non-monotonic timing). `# repro: allow[rule]`
    pragmas mark intentional, documented exceptions.
  - `repro.analysis.audit` : compiled-artifact audit — lowers the actual
    step program for every registry id x backend (vmap / pallas / async /
    sharded) and gates zero host-transfer instructions, 100% carry
    donation, and a bounded jit-trace count (the async recv-size
    respecialization hazard as a named budget, not folklore). Emits the
    machine-readable `BENCH_hlo_audit.json` report.
  - `repro.analysis.retrace` : the reusable `RetraceGuard` wrapper the
    audit (and any runtime loop) uses to turn silent recompiles into
    loud `RetraceError`s.

CLI entry points (what `make analyze` runs):

  python -m repro.analysis.lint src
  python -m repro.analysis.audit --smoke --json BENCH_hlo_audit.json
"""
__all__ = [
    "RULES",
    "RetraceError",
    "RetraceGuard",
    "Violation",
    "lint_paths",
    "lint_source",
]

# Lazy (PEP 562) so `python -m repro.analysis.lint` doesn't import the
# submodule twice (runpy's "found in sys.modules" warning) and importing
# the package for RULES alone stays dependency-free.
_EXPORTS = {
    "RULES": "repro.analysis.rules",
    "Violation": "repro.analysis.rules",
    "lint_paths": "repro.analysis.lint",
    "lint_source": "repro.analysis.lint",
    "RetraceError": "repro.analysis.retrace",
    "RetraceGuard": "repro.analysis.retrace",
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
