"""JAX-aware AST lint over the source tree (`python -m repro.analysis.lint`).

One module is analyzed at a time; all reasoning is module-local and
heuristic by design — the goal is to catch the failure modes that have
actually bitten this stack (key reuse, host reads traced into compiled
code, donated-buffer aliasing, tracer branches, unlocked shared state)
with zero runtime cost and no imports of the linted code.

Per module the linter builds:
  - an import-alias table, so `jnp.where`, `jax.numpy.where` and
    `from jax import numpy as jnp` all canonicalize to `jax.numpy.where`;
  - the set of *jit roots*: functions decorated with `jax.jit` /
    `partial(jax.jit, ...)` plus anything passed to a `jax.jit(...)` call
    (`jax.jit(self._step_impl, donate_argnums=(0,))` marks `_step_impl`);
  - a name-level call graph, walked from the roots to the set of
    *jit-reachable* functions (the scope of the tracer-sensitive rules);
  - the table of *donating callables*: names/attributes bound to
    `jax.jit(..., donate_argnums=...)`, with their donated positions.

Rules are documented in `repro.analysis.rules.RULES`; intentional sites
carry a `# repro: allow[rule]` pragma (same line or the line above).
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.rules import RULES, Violation, pragma_lines

# attribute reads that are static under tracing (safe in a Python branch)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "weak_type"}

# canonical producers whose results are tracer-valued inside jit
_TRACER_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.",
                    "jax.scipy.")

# canonical producers of PRNG keys (assignment RHS types a name as a key)
_KEY_PRODUCERS = {"jax.random.PRNGKey", "jax.random.key", "jax.random.split",
                  "jax.random.fold_in", "jax.random.clone"}

# canonical calls that read host state a jit trace would freeze
_HOST_READS = {"time.time", "time.time_ns", "time.monotonic",
               "time.monotonic_ns", "time.perf_counter",
               "time.perf_counter_ns", "time.process_time", "os.getenv",
               "os.environ.get", "datetime.datetime.now",
               "datetime.datetime.utcnow", "open", "input"}
_HOST_READ_PREFIXES = ("random.", "numpy.random.")

_LOCK_FACTORIES = {"threading.Lock", "threading.RLock", "threading.Condition",
                   "threading.Semaphore", "threading.BoundedSemaphore"}

_LOG_MARKERS = ("print", "warn", "log", "record", "report")


def _dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Imports:
    """Alias table: first path component rewritten to its imported target."""

    def __init__(self, tree: ast.Module):
        self.table: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.table[(a.asname or a.name.split(".")[0])] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.table[a.asname or a.name] = f"{node.module}.{a.name}"

    def canon(self, dotted: Optional[str]) -> Optional[str]:
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        head = self.table.get(head, head)
        return f"{head}.{rest}" if rest else head

    def canon_call(self, call: ast.Call) -> Optional[str]:
        return self.canon(_dotted(call.func))


def _stmt_children(stmt: ast.stmt) -> List[ast.stmt]:
    """Nested statements of a compound statement (not new scopes)."""
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return []
    out: List[ast.stmt] = []
    for field in ("body", "orelse", "finalbody"):
        out.extend(getattr(stmt, field, []) or [])
    for h in getattr(stmt, "handlers", []) or []:
        out.extend(h.body)
    return out


def _flat_stmts(body: Sequence[ast.stmt]) -> Iterable[ast.stmt]:
    """All statements of a function body in source order, same scope only."""
    for st in body:
        yield st
        yield from _flat_stmts(_stmt_children(st))


def _header_nodes(stmt: ast.stmt) -> List[ast.AST]:
    """The statement's own expressions — child exprs, not nested statements."""
    return [n for n in ast.iter_child_nodes(stmt)
            if isinstance(n, (ast.expr, ast.withitem, ast.ExceptHandler))
            and not isinstance(n, (ast.Lambda,))]


def _walk_exprs(nodes: Iterable[ast.AST]) -> Iterable[ast.AST]:
    for n in nodes:
        for sub in ast.walk(n):
            # lambdas are separate (deferred) scopes; their bodies don't
            # execute at this statement
            if isinstance(sub, ast.Lambda):
                continue
            yield sub


def _store_names(stmt: ast.stmt) -> Set[str]:
    """Bare names (re)bound by this statement."""
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    elif isinstance(stmt, ast.With):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def _store_keys(stmt: ast.stmt) -> Set[Tuple[str, str]]:
    """(kind, name) keys (re)bound: bare names and `self.attr` targets."""
    out: Set[Tuple[str, str]] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                out.add(("name", n.id))
            elif (isinstance(n, ast.Attribute)
                  and isinstance(n.value, ast.Name) and n.value.id == "self"):
                out.add(("self", n.attr))
    return out


def _is_jax_jit(node: ast.AST, imports: _Imports) -> bool:
    return imports.canon(_dotted(node)) == "jax.jit"


def _donate_positions(call: ast.Call) -> Tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, int))
    return ()


class _Module:
    """Per-module analysis context shared by all rules."""

    def __init__(self, tree: ast.Module, source: str, path: str):
        self.tree = tree
        self.path = path
        self.imports = _Imports(tree)
        self.pragmas = pragma_lines(source)
        self.violations: List[Violation] = []
        self.funcs: List[ast.FunctionDef] = [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        self.by_name: Dict[str, List[ast.FunctionDef]] = {}
        for f in self.funcs:
            self.by_name.setdefault(f.name, []).append(f)
        self.donators = self._find_donators()
        self.transitive_donators = self._find_transitive_donators()
        self.jit_reachable = self._jit_reachable()

    # -- shared infrastructure -------------------------------------------
    def report(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        allowed = self.pragmas.get(line, set())
        if rule in allowed or "*" in allowed:
            return
        self.violations.append(Violation(
            self.path, line, getattr(node, "col_offset", 0), rule, message))

    def _find_donators(self) -> Dict[Tuple[str, str], Tuple[int, ...]]:
        """(kind, name) -> donated positions, for every binding of a
        `jax.jit(..., donate_argnums=...)` result to a name or self attr."""
        out: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Assign):
                continue
            v = node.value
            if not (isinstance(v, ast.Call) and _is_jax_jit(v.func, self.imports)):
                continue
            pos = _donate_positions(v)
            if not pos:
                continue
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[("name", t.id)] = pos
                elif (isinstance(t, ast.Attribute)
                      and isinstance(t.value, ast.Name)
                      and t.value.id == "self"):
                    out[("self", t.attr)] = pos
        # decorated defs donate too: @jax.jit(donate_argnums=...) and
        # @partial(jax.jit, donate_argnums=...); positions are rebased to
        # *call-site* arg indices for methods (self is jit arg 0)
        for f in self.funcs:
            for dec in f.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                if _is_jax_jit(dec.func, self.imports):
                    pos = _donate_positions(dec)
                elif (self.imports.canon(_dotted(dec.func))
                      in ("functools.partial", "partial")
                      and dec.args and _is_jax_jit(dec.args[0], self.imports)):
                    pos = _donate_positions(dec)
                else:
                    continue
                if not pos:
                    continue
                params = [a.arg for a in f.args.args]
                if params and params[0] == "self":
                    out[("self", f.name)] = tuple(p - 1 for p in pos if p >= 1)
                else:
                    out[("name", f.name)] = pos
        return out

    def _find_transitive_donators(self) -> Dict[Tuple[str, str],
                                                Tuple[int, ...]]:
        """(kind, name) -> call-site positions a *helper* forwards into a
        donated position of a known donating callable — the PR-9
        `_donate_safe` bug class: the helper's caller still holds the name,
        but the buffer is gone. Computed to fixpoint so helpers of helpers
        donate too."""
        table: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        for _ in range(4):  # call chains deeper than this don't occur here
            grew = False
            for f in self.funcs:
                params = [a.arg for a in f.args.args]
                offset = 1 if params and params[0] == "self" else 0
                donated: Set[int] = set(table.get(("name", f.name), ())) | \
                    set(table.get(("self", f.name), ()))
                known = {**self.donators, **table}
                for node in ast.walk(f):
                    if not isinstance(node, ast.Call):
                        continue
                    key = None
                    if isinstance(node.func, ast.Name):
                        key = ("name", node.func.id)
                    elif (isinstance(node.func, ast.Attribute)
                          and isinstance(node.func.value, ast.Name)
                          and node.func.value.id == "self"):
                        key = ("self", node.func.attr)
                    pos = known.get(key or ("", ""))
                    if not pos:
                        continue
                    for p in pos:
                        if p >= len(node.args):
                            continue
                        arg = node.args[p]
                        if (isinstance(arg, ast.Name)
                                and arg.id in params[offset:]):
                            donated.add(params.index(arg.id) - offset)
                if donated:
                    new = tuple(sorted(donated))
                    for key in ((("self", f.name),) if offset
                                else (("name", f.name),)):
                        if key not in self.donators and table.get(key) != new:
                            table[key] = new
                            grew = True
            if not grew:
                break
        return table

    def _jit_roots(self) -> Set[str]:
        roots: Set[str] = set()
        for f in self.funcs:
            for dec in f.decorator_list:
                if _is_jax_jit(dec, self.imports):
                    roots.add(f.name)
                elif isinstance(dec, ast.Call):
                    if _is_jax_jit(dec.func, self.imports):
                        roots.add(f.name)
                    elif (self.imports.canon(_dotted(dec.func))
                          in ("functools.partial", "partial")
                          and dec.args
                          and _is_jax_jit(dec.args[0], self.imports)):
                        roots.add(f.name)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and _is_jax_jit(node.func, self.imports):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        roots.add(arg.id)
                    elif isinstance(arg, ast.Attribute):
                        roots.add(arg.attr)
        return roots

    def _called_names(self, f: ast.FunctionDef) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(f):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Name):
                    out.add(node.func.id)
                elif isinstance(node.func, ast.Attribute):
                    v = node.func.value
                    if isinstance(v, ast.Name) and v.id == "self":
                        out.add(node.func.attr)
        return out

    def _jit_reachable(self) -> Set[ast.FunctionDef]:
        seen: Set[str] = set()
        frontier = list(self._jit_roots())
        reachable: Set[ast.FunctionDef] = set()
        while frontier:
            name = frontier.pop()
            if name in seen:
                continue
            seen.add(name)
            for f in self.by_name.get(name, []):
                reachable.add(f)
                frontier.extend(self._called_names(f))
        return reachable

    # -- rules ------------------------------------------------------------
    def run(self) -> List[Violation]:
        for f in self.funcs:
            self._rule_key_reuse(f)
            self._rule_use_after_donate(f)
            self._rule_donation_lifetime(f)
            if f in self.jit_reachable:
                self._rule_host_read(f)
                self._rule_tracer_branch(f)
        self._rule_unguarded_mutation()
        self._rule_lock_discipline()
        self._rule_silent_except()
        self._rule_wall_clock()
        self._check_pragma_rules()
        return self.violations

    def _check_pragma_rules(self) -> None:
        seen: Set[Tuple[int, str]] = set()
        for line, rules in self.pragmas.items():
            for r in rules - set(RULES) - {"*"}:
                if (line, r) in seen or (line - 1, r) in seen:
                    continue
                seen.add((line, r))
                self.violations.append(Violation(
                    self.path, line, 0, "silent-except",
                    f"pragma names unknown rule {r!r} (known: "
                    f"{sorted(RULES)})"))

    def _rule_key_reuse(self, f: ast.FunctionDef) -> None:
        """A locally-derived key consumed by >1 call without a re-derive."""
        key_names: Set[str] = set()
        uses: Dict[str, int] = {}
        for stmt in _flat_stmts(f.body):
            header = _header_nodes(stmt)
            # 1) consumptions in this statement's expressions
            for node in _walk_exprs(header):
                if not isinstance(node, ast.Call):
                    continue
                callee = self.imports.canon_call(node) or ""
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in key_names:
                        uses[arg.id] = uses.get(arg.id, 0) + 1
                        if uses[arg.id] == 2:
                            self.report(
                                "key-reuse", node,
                                f"PRNG key {arg.id!r} consumed more than once "
                                f"(second consumer: {callee or 'call'}); "
                                "split/fold_in a fresh key per consumer")
            # 2) (re)bindings: key-producing RHS types the targets as keys,
            #    anything else untypes them
            stores = _store_names(stmt)
            produced = False
            if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
                produced = (self.imports.canon_call(stmt.value)
                            in _KEY_PRODUCERS)
            elif (isinstance(stmt, ast.Assign)
                  and isinstance(stmt.value, ast.Subscript)
                  and isinstance(stmt.value.value, ast.Call)):
                produced = (self.imports.canon_call(stmt.value.value)
                            in _KEY_PRODUCERS)
            for name in stores:
                uses[name] = 0
                if produced:
                    key_names.add(name)
                else:
                    key_names.discard(name)

    def _rule_use_after_donate(self, f: ast.FunctionDef) -> None:
        if not self.donators:
            return
        dead: Dict[Tuple[str, str], int] = {}  # key -> donating line
        for stmt in _flat_stmts(f.body):
            header = _header_nodes(stmt)
            # 1) reads of already-donated values
            for node in _walk_exprs(header):
                key = None
                if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                    key = ("name", node.id)
                elif (isinstance(node, ast.Attribute)
                      and isinstance(node.value, ast.Name)
                      and node.value.id == "self"
                      and isinstance(node.ctx, ast.Load)):
                    key = ("self", node.attr)
                if key in dead:
                    what = key[1] if key[0] == "name" else f"self.{key[1]}"
                    self.report(
                        "use-after-donate", node,
                        f"{what} was donated on line {dead[key]} and read "
                        "here — XLA may already have reused its buffers")
            # 2) donations made by calls in this statement
            for node in _walk_exprs(header):
                if not isinstance(node, ast.Call):
                    continue
                callee_key = None
                if isinstance(node.func, ast.Name):
                    callee_key = ("name", node.func.id)
                elif (isinstance(node.func, ast.Attribute)
                      and isinstance(node.func.value, ast.Name)
                      and node.func.value.id == "self"):
                    callee_key = ("self", node.func.attr)
                pos = self.donators.get(callee_key or ("", ""))
                if not pos:
                    continue
                for p in pos:
                    if p >= len(node.args):
                        continue
                    arg = node.args[p]
                    if isinstance(arg, ast.Name):
                        dead[("name", arg.id)] = node.lineno
                    elif (isinstance(arg, ast.Attribute)
                          and isinstance(arg.value, ast.Name)
                          and arg.value.id == "self"):
                        dead[("self", arg.attr)] = node.lineno
            # 3) rebindings resurrect
            for key in _store_keys(stmt):
                dead.pop(key, None)

    def _rule_host_read(self, f: ast.FunctionDef) -> None:
        for node in ast.walk(f):
            name = None
            if isinstance(node, ast.Call):
                name = self.imports.canon_call(node)
            elif isinstance(node, (ast.Attribute, ast.Subscript)):
                d = self.imports.canon(_dotted(node))
                if d == "os.environ":
                    name = d
            if name is None:
                continue
            if name in _HOST_READS or name.startswith(_HOST_READ_PREFIXES):
                self.report(
                    "host-read-in-jit", node,
                    f"{name} inside jit-reachable `{f.name}` — the read "
                    "happens once at trace time, not per step")

    def _rule_tracer_branch(self, f: ast.FunctionDef) -> None:
        tracer_names: Set[str] = set()
        for stmt in _flat_stmts(f.body):
            if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
                callee = self.imports.canon_call(stmt.value) or ""
                if callee.startswith(_TRACER_PREFIXES):
                    tracer_names |= _store_names(stmt)
            elif isinstance(stmt, ast.Assign):
                # non-call RHS: conservatively untype reassigned names
                tracer_names -= _store_names(stmt)
            if not isinstance(stmt, (ast.If, ast.While)):
                continue
            bad = self._tracer_in_test(stmt.test, tracer_names)
            if bad:
                self.report(
                    "tracer-branch", stmt,
                    f"Python {'if' if isinstance(stmt, ast.If) else 'while'} "
                    f"on tracer-valued {bad} in jit-reachable `{f.name}`; "
                    "use jnp.where / lax.cond")

    def _tracer_in_test(self, test: ast.expr, tracer_names: Set[str]
                        ) -> Optional[str]:
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(test):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for node in ast.walk(test):
            if isinstance(node, ast.Call):
                callee = self.imports.canon_call(node) or ""
                if callee.startswith(_TRACER_PREFIXES):
                    return callee
            if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                    and node.id in tracer_names):
                parent = parents.get(node)
                if (isinstance(parent, ast.Attribute)
                        and parent.attr in _STATIC_ATTRS):
                    continue  # x.shape / x.ndim are static under tracing
                return node.id
        return None

    def _rule_unguarded_mutation(self) -> None:
        for cls in ast.walk(self.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = self._lock_attrs(cls)
            if not locks:
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if meth.name == "__init__":
                    continue
                self._scan_mutations(meth, locks, guarded=False)

    def _lock_attrs(self, cls: ast.ClassDef) -> Set[str]:
        locks: Set[str] = set()
        for node in ast.walk(cls):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            if self.imports.canon_call(node.value) not in _LOCK_FACTORIES:
                continue
            for t in node.targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    locks.add(t.attr)
        return locks

    def _scan_mutations(self, node, locks: Set[str], guarded: bool) -> None:
        for stmt in (node.body if hasattr(node, "body") else []):
            now_guarded = guarded
            if isinstance(stmt, ast.With):
                for item in stmt.items:
                    d = _dotted(item.context_expr)
                    if d and d.startswith("self.") and d[5:] in locks:
                        now_guarded = True
            if not now_guarded and isinstance(
                    stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets: List[ast.AST] = (
                    list(stmt.targets) if isinstance(stmt, ast.Assign)
                    else [stmt.target])
                # descend into tuple/list unpacking targets
                flat: List[ast.AST] = []
                while targets:
                    t = targets.pop()
                    if isinstance(t, (ast.Tuple, ast.List)):
                        targets.extend(t.elts)
                    else:
                        flat.append(t)
                for t in flat:
                    base = t
                    while isinstance(base, (ast.Subscript, ast.Starred)):
                        base = base.value
                    if (isinstance(base, ast.Attribute)
                            and isinstance(base.value, ast.Name)
                            and base.value.id == "self"
                            and base.attr not in locks):
                        self.report(
                            "unguarded-mutation", stmt,
                            f"self.{base.attr} mutated outside "
                            f"`with self.{sorted(locks)[0]}:` in a "
                            "lock-owning class")
            # recurse into nested statements with the (possibly) new guard
            for child in _stmt_children(stmt):
                self._scan_mutations_stmt(child, locks, now_guarded)

    def _scan_mutations_stmt(self, stmt: ast.stmt, locks: Set[str],
                             guarded: bool) -> None:
        class _Shim:
            body = [stmt]
        self._scan_mutations(_Shim, locks, guarded)

    def _lock_scan(self, meth, locks: Set[str]):
        """(guarded_writes, unguarded_writes, calls, acquires) for one
        method: which self fields it writes under / outside `with
        self.<lock>:`, which self methods it calls (and under which guard
        state), and whether it ever takes a lock itself."""
        guarded_w: Set[str] = set()
        unguarded_w: List[Tuple[str, ast.stmt]] = []
        calls: List[Tuple[str, ast.AST, bool]] = []
        acquires = False

        def walk(stmts, guarded):
            nonlocal acquires
            for stmt in stmts:
                g = guarded
                if isinstance(stmt, ast.With):
                    for item in stmt.items:
                        d = _dotted(item.context_expr)
                        if d and d.startswith("self.") and d[5:] in locks:
                            g = acquires = True
                for kind, attr in _store_keys(stmt):
                    if kind != "self" or attr in locks:
                        continue
                    if g:
                        guarded_w.add(attr)
                    else:
                        unguarded_w.append((attr, stmt))
                for node in _walk_exprs(_header_nodes(stmt)):
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Attribute)
                            and isinstance(node.func.value, ast.Name)
                            and node.func.value.id == "self"):
                        calls.append((node.func.attr, node, g))
                walk(_stmt_children(stmt), g)

        walk(meth.body, False)
        return guarded_w, unguarded_w, calls, acquires

    def _rule_lock_discipline(self) -> None:
        """Per lock-owning class: fields written under the lock anywhere
        define the guarded set; a write to a guarded field without the
        lock — or a call, without the lock, to a helper whose writes are
        only correct because its callers normally hold it — breaks the
        discipline. Finer than unguarded-mutation (which flags every bare
        self-write): this one follows the *field* across methods and
        through one level of helper calls."""
        for cls in ast.walk(self.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            locks = self._lock_attrs(cls)
            if not locks:
                continue
            methods = [m for m in cls.body
                       if isinstance(m, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
            scans = {m.name: self._lock_scan(m, locks) for m in methods}
            guarded_fields: Set[str] = set()
            for name, (gw, _, _, _) in scans.items():
                if name != "__init__":
                    guarded_fields |= gw
            # helpers: never take the lock themselves, write fields bare,
            # and have at least one lock-held call site — i.e. they *rely*
            # on the caller's guard, so their writes are guarded by
            # convention and every bare call site breaks it
            guarded_sites: Set[str] = set()
            for name, (_, _, calls, _) in scans.items():
                guarded_sites |= {c for c, _, g in calls if g}
            helpers = {
                name for name, (_, uw, _, acq) in scans.items()
                if name != "__init__" and not acq and name in guarded_sites
                and uw}
            for name in helpers:
                guarded_fields |= {a for a, _ in scans[name][1]}
            if not guarded_fields:
                continue
            for meth in methods:
                if meth.name == "__init__":
                    continue
                _, unguarded_w, calls, _ = scans[meth.name]
                if meth.name not in helpers:
                    for attr, stmt in unguarded_w:
                        if attr in guarded_fields:
                            self.report(
                                "lock-discipline", stmt,
                                f"self.{attr} is written under "
                                f"self.{sorted(locks)[0]} elsewhere in "
                                f"{cls.name} but written here without the "
                                "lock — a concurrent writer can interleave")
                for callee, node, g in calls:
                    if callee in helpers and not g:
                        fields = sorted({a for a, _ in scans[callee][1]}
                                        & guarded_fields)
                        self.report(
                            "lock-discipline", node,
                            f"self.{callee}() writes lock-guarded "
                            f"{', '.join('self.' + a for a in fields)} and "
                            "its other call sites hold "
                            f"self.{sorted(locks)[0]} — call it with the "
                            "lock held")

    def _rule_donation_lifetime(self, f: ast.FunctionDef) -> None:
        """Donated buffers reachable after the donating call through an
        alias (`alias = carry; step(carry); alias`), through a helper
        boundary (the helper forwards its parameter into a donated
        position, so the *caller's* binding dies), or donated twice in one
        call (two argument positions resolving to one buffer). Direct
        same-name reads after a direct donating call stay with
        use-after-donate; this rule covers the flows that one misses."""
        donators = {**self.donators, **self.transitive_donators}
        if not donators:
            return
        aliases: Dict[str, Tuple[str, str]] = {}
        dead: Dict[Tuple[str, str], Tuple[int, bool]] = {}  # -> (line, via helper)

        def root_of(node: ast.AST) -> Optional[Tuple[str, str]]:
            if isinstance(node, ast.Name):
                return aliases.get(node.id, ("name", node.id))
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                return ("self", node.attr)
            return None

        for stmt in _flat_stmts(f.body):
            header = _header_nodes(stmt)
            # 1) reads of dead buffers: through an alias always, directly
            #    only when the donation went through a helper (the direct
            #    case is use-after-donate's)
            for node in _walk_exprs(header):
                key = root = None
                if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                             ast.Load):
                    key = ("name", node.id)
                    root = aliases.get(node.id, key)
                elif (isinstance(node, ast.Attribute)
                      and isinstance(node.value, ast.Name)
                      and node.value.id == "self"
                      and isinstance(node.ctx, ast.Load)):
                    key = root = ("self", node.attr)
                if root not in dead:
                    continue
                line, via_helper = dead[root]
                if key != root or via_helper:
                    what = key[1] if key[0] == "name" else f"self.{key[1]}"
                    how = ("donated through a helper call"
                           if key == root else
                           f"an alias of {root[1]!r}, donated")
                    self.report(
                        "donation-lifetime", node,
                        f"{what} is {how} on line {line} and read here — "
                        "the buffer may already be reused by XLA")
            # 2) donations (and double-donations) made by this statement
            for node in _walk_exprs(header):
                if not isinstance(node, ast.Call):
                    continue
                callee_key = None
                if isinstance(node.func, ast.Name):
                    callee_key = ("name", node.func.id)
                elif (isinstance(node.func, ast.Attribute)
                      and isinstance(node.func.value, ast.Name)
                      and node.func.value.id == "self"):
                    callee_key = ("self", node.func.attr)
                pos = donators.get(callee_key or ("", ""))
                if not pos:
                    continue
                via_helper = callee_key in self.transitive_donators
                seen: Dict[Tuple[str, str], int] = {}
                for p in pos:
                    if p >= len(node.args):
                        continue
                    root = root_of(node.args[p])
                    if root is None:
                        continue
                    if root in seen:
                        what = (root[1] if root[0] == "name"
                                else f"self.{root[1]}")
                        self.report(
                            "donation-lifetime", node,
                            f"{what} is donated twice in one call (arg "
                            f"positions {seen[root]} and {p}) — XLA would "
                            "alias one buffer to two outputs; dedupe "
                            "before donating")
                    seen[root] = p
                    dead[root] = (node.lineno, via_helper)
            # 3) rebinding resurrects the buffer and retargets aliases
            for key in _store_keys(stmt):
                dead.pop(key, None)
                if key[0] == "name":
                    aliases.pop(key[1], None)
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, (ast.Name, ast.Attribute))):
                root = root_of(stmt.value)
                if root is not None:
                    aliases[stmt.targets[0].id] = root

    def _rule_silent_except(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._is_broad(node.type):
                continue
            if self._handler_is_loud(node):
                continue
            self.report(
                "silent-except", node,
                "broad except swallows the error silently — narrow the "
                "exception type, or log and re-raise the unexpected")

    def _is_broad(self, type_node: Optional[ast.expr]) -> bool:
        if type_node is None:
            return True
        names = ([type_node] if not isinstance(type_node, ast.Tuple)
                 else list(type_node.elts))
        for n in names:
            if self.imports.canon(_dotted(n)) in ("Exception", "BaseException"):
                return True
        return False

    def _handler_is_loud(self, handler: ast.ExceptHandler) -> bool:
        for node in ast.walk(handler):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call):
                d = (self.imports.canon_call(node) or "").lower()
                if any(m in d for m in _LOG_MARKERS):
                    return True
        return False

    def _rule_wall_clock(self) -> None:
        for node in ast.walk(self.tree):
            if (isinstance(node, ast.Call)
                    and self.imports.canon_call(node) == "time.time"):
                self.report(
                    "wall-clock", node,
                    "time.time() is not monotonic; use time.perf_counter() "
                    "for durations (time.monotonic() for deadlines)")


def lint_source(source: str, path: str = "<string>",
                select: Optional[Set[str]] = None) -> List[Violation]:
    """Lint one module's source; `select` restricts to a subset of rules."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 1, e.offset or 0, "silent-except",
                          f"syntax error: {e.msg}")]
    out = _Module(tree, source, path).run()
    if select is not None:
        out = [v for v in out if v.rule in select]
    return sorted(out, key=lambda v: (v.path, v.line, v.col))


def lint_paths(paths: Iterable[str],
               select: Optional[Set[str]] = None) -> List[Violation]:
    """Lint every `.py` file under `paths` (files or directories)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        else:
            files.append(p)
    out: List[Violation] = []
    for path in sorted(set(files)):
        with open(path, encoding="utf-8") as f:
            out.extend(lint_source(f.read(), path, select=select))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX-aware AST lint (rules: %s)" % ", ".join(sorted(RULES)))
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--select", default="",
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write violations as JSON")
    args = ap.parse_args(argv)
    select = {r.strip() for r in args.select.split(",") if r.strip()} or None
    if select and (unknown := select - set(RULES)):
        ap.error(f"unknown rules {sorted(unknown)}; known: {sorted(RULES)}")
    violations = lint_paths(args.paths, select=select)
    for v in violations:
        print(v)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([v.__dict__ for v in violations], f, indent=2)
    n = len(violations)
    print(f"repro.analysis.lint: {n} violation{'s' if n != 1 else ''} in "
          f"{', '.join(args.paths)}")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
