"""Retrace guard: turn silent jit recompiles into loud errors.

`jax.jit` retraces whenever it sees a new (shape, dtype, static-arg)
signature. On the hot step path that is almost always a bug — a shape
leak, a weak-type flip, a Python scalar where an array was meant — and it
costs a full lower+compile, silently. `RetraceGuard` wraps a jitted
callable with an explicit *trace budget*: the number of distinct
signatures the call site is allowed to own. Exceeding it raises
`RetraceError` at the exact call that triggered the extra trace, instead
of showing up later as a mysteriously slow benchmark.

The audit sweep uses the same budget notion statically: the async pool's
recv path is allowlisted at budget 1 (PR 6 pinned the ready-set-size
respecialization hazard by moving row selection host-side), and the audit
fails if any pool's step function ever owns more traces than its budget.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional


class RetraceError(RuntimeError):
    """A guarded jit function exceeded its trace budget."""

    def __init__(self, name: str, budget: int, traces: int):
        self.name = name
        self.budget = budget
        self.traces = traces
        super().__init__(
            f"{name}: {traces} distinct jit traces exceed the budget of "
            f"{budget} — a call-site signature is unstable (shape/dtype/"
            "static-arg leak). Stabilize the inputs or raise the budget "
            "explicitly if the extra specialization is intentional.")


def trace_count(jitted: Any) -> Optional[int]:
    """Number of compiled specializations a jitted callable holds, if
    the wrapper exposes it (None on foreign callables)."""
    probe = getattr(jitted, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except TypeError:  # property-style on some jax versions
        return int(probe)


class RetraceGuard:
    """Wrap a `jax.jit`-ed callable and enforce a trace budget per call.

    >>> step = RetraceGuard(jax.jit(fn), budget=1, name="envpool.step")
    >>> step(carry, actions)          # first call: traces, ok
    >>> step(carry, actions)          # cached, ok
    >>> step(bad_shaped, actions)     # RetraceError

    The check runs after each call, so the offending call completes (its
    result is not lost) but the guard fails before the next one.
    """

    def __init__(self, jitted: Callable[..., Any], budget: int = 1,
                 name: Optional[str] = None):
        if trace_count(jitted) is None:
            raise TypeError(
                "RetraceGuard needs a jax.jit-wrapped callable exposing "
                "_cache_size(); got %r" % (jitted,))
        self._fn = jitted
        self.budget = int(budget)
        self.name = name or getattr(jitted, "__name__", repr(jitted))
        functools.update_wrapper(self, jitted, updated=())

    @property
    def traces(self) -> int:
        return trace_count(self._fn) or 0

    def check(self) -> int:
        """Raise RetraceError if over budget; return the trace count."""
        n = self.traces
        if n > self.budget:
            raise RetraceError(self.name, self.budget, n)
        return n

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        out = self._fn(*args, **kwargs)
        self.check()
        return out
