"""Compiled-artifact audit: lower every env id × backend, gate the HLO.

`python -m repro.analysis.audit --smoke --json BENCH_hlo_audit.json`

This generalizes `launch/hlo_analysis.py` from the per-test ad-hoc asserts
(fig4 / test_pool / fig_async each checked one pool) into a registry-driven
sweep. For every registered env id × every pool backend it lowers the
*donated* compiled step program — `pool._jit_step`, the one the stateful
fast path actually dispatches (`step_lowered()` re-jits without donation
and would audit the wrong artifact) — and gates three invariants:

  residency : `host_transfer_ops(compiled)` is empty — no infeed/outfeed/
              send/recv/callback custom-call on the step path;
  donation  : every carry leaf parameter carries `tf.aliasing_output` in
              the lowered StableHLO (donation *intent* survives on CPU
              even where the runtime drops the aliasing itself);
  retraces  : executing the async send/recv path across ready-set sizes
              1, 2 and N owns at most `RETRACE_BUDGET["async"]` jit
              traces. PR 6 moved recv row-selection host-side precisely
              so the ready-set size never re-specializes the program;
              this turns that from folklore into a named, gated fact.

Backends that cannot host an id refuse by *named* exception — a pallas
cell on an env without fused megastep support raises ValueError, exactly
as `EnvPool(backend="pallas")` documents — and the refusal is recorded as
a row (`status: "refused"`), so the report still covers the full registry
(the same hosted-or-named-refusal contract the conformance matrix uses).
Unexpected refusal classes are violations.

Beyond the per-step pool matrix, full sweeps also audit the *fused train*
programs (`backend: "train_fused"`): for each committed training-golden id
(repro.train.fused.GOLDEN_TRAIN_IDS) the donated K-step train chunk —
rollout, replay ring, learner and target sync in ONE program — is lowered
via `lower_train_chunk` and held to the same residency + full-carry-
donation gates, replay ring and optimizer state included. This certifies
the tentpole claim machine-checkably: nothing crosses the host boundary
inside a fused training chunk, and the whole carry updates in place.

The JSON report (`BENCH_hlo_audit.json`) is machine-readable: one row per
(id, backend) with residency/donation/flops/bytes, a `violations` list,
and `ok`. Exit status is nonzero iff any violation is unallowlisted.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.retrace import trace_count
from repro.core.env import supports_fused_step
from repro.core.registry import make, registered
from repro.launch.hlo_analysis import (analyze_hlo, donated_params,
                                       host_transfer_ops)

#: pool flavors audited per id (the four step-dispatch paths of the stack)
BACKENDS = ("vmap", "pallas", "async", "sharded")

#: backend tag of the fused-train audit rows (not a pool flavor: the cell
#: ids are "<algo>/<env_id>" training-golden ids, not registry env ids)
TRAIN_BACKEND = "train_fused"

#: refusal classes that are legitimate "this backend cannot host this id"
#: answers rather than bugs (mirrors the conformance matrix contract)
EXPECTED_REFUSALS = ("ValueError", "AsyncUnsupportedError")

#: named allowlisted retrace facts: jit-trace budget per backend. The async
#: budget of 1 IS the PR-6 recv-size respecialization fix — recv masks on
#: device and row-selects host-side, so ready-set size never retraces.
RETRACE_BUDGET: Dict[str, int] = {"async": 1}

#: ids whose async retrace budget is *executed* (not just lowered) in smoke
#: mode — one classic control env, one tabular env, one pixel env
RETRACE_SMOKE_IDS = ("CartPole-v1", "FrozenLake-v0", "Pong-raw")

_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _sds(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(jnp.shape(x), x.dtype)


def _build_pool(env_id: str, backend: str, batch: int):
    """Construct the pool flavor under audit (may raise a refusal)."""
    if backend == "vmap":
        from repro.pool.envpool import EnvPool
        return EnvPool(env_id, batch, backend="vmap")
    if backend == "pallas":
        from repro.pool.envpool import EnvPool
        return EnvPool(env_id, batch, backend="pallas")
    if backend == "async":
        from repro.pool.async_pool import AsyncEnvPool
        return AsyncEnvPool(env_id, batch, backend="auto")
    if backend == "sharded":
        from repro.pool.sharded import ShardedEnvPool, default_pool_mesh
        return ShardedEnvPool(env_id, batch, mesh=default_pool_mesh(1))
    raise ValueError(f"unknown audit backend {backend!r}")


def _lower_step(pool, backend: str):
    """Lower the *donated* step program with abstract args (no execution).

    Shapes come from `jax.eval_shape` over the pool's own init path, so the
    audited carry is exactly the one the stateful fast path donates.
    """
    acts = _sds(pool.sample_actions(0))
    if backend == "async":
        carry = jax.eval_shape(pool._init_impl, _KEY_SDS)
        active = jax.ShapeDtypeStruct((pool.num_slots,), jnp.bool_)
        return pool._jit_step.lower(carry, acts, active, _KEY_SDS), carry
    carry, _ = jax.eval_shape(pool._stateful_reset, _KEY_SDS)
    return pool._jit_step.lower(carry, acts), carry


def _run_async_retrace(env_id: str, slots: int) -> int:
    """Execute the send/recv path across ready-set sizes 1, 2 and `slots`;
    return how many jit traces `_jit_step` owns afterwards."""
    from repro.pool.async_pool import AsyncEnvPool

    pool = AsyncEnvPool(env_id, slots, backend="auto")
    sids = [pool.admit(seed=i)[0] for i in range(slots)]
    acts = jax.device_get(pool.sample_actions(0))
    for ready in (sids[:1], sids[:2], sids):
        pool.send(acts[: len(ready)], ready)
        pool.recv()
    return trace_count(pool._jit_step) or 0


def _gate_lowered(row: Dict[str, Any], lowered, carry) -> Dict[str, Any]:
    """Shared residency/donation gate body: fill `row` from a lowered
    donated program whose argument 0 is `carry`."""
    carry_leaves = len(jax.tree.leaves(carry))
    donated = donated_params(lowered.as_text())
    hlo = lowered.compile().as_text()
    transfers = host_transfer_ops(hlo)
    analysis = analyze_hlo(hlo)
    row.update(
        status="ok",
        carry_params=carry_leaves,
        donated_params=len([p for p in donated if p < carry_leaves]),
        donation=(len([p for p in donated if p < carry_leaves])
                  / max(carry_leaves, 1)),
        host_transfer_ops=transfers,
        flops=analysis.flops,
        bytes=analysis.bytes,
    )
    return row


def audit_cell(env_id: str, backend: str, batch: int,
               run_retrace: bool = False) -> Dict[str, Any]:
    """Audit one (id, backend) cell; returns its report row."""
    row: Dict[str, Any] = {"id": env_id, "backend": backend, "batch": batch}
    try:
        pool = _build_pool(env_id, backend, batch)
        lowered, carry = _lower_step(pool, backend)
    except Exception as e:  # repro: allow[silent-except] named-refusal protocol: class+message recorded in the row, judged against EXPECTED_REFUSALS
        row.update(status="refused", refusal=type(e).__name__,
                   refusal_msg=str(e).splitlines()[0][:200])
        return row
    row = _gate_lowered(row, lowered, carry)
    if run_retrace and backend in RETRACE_BUDGET:
        row["retraces"] = _run_async_retrace(env_id, batch)
        row["retrace_budget"] = RETRACE_BUDGET[backend]
    return row


def audit_train_cell(gid: str, chunk: int = 8) -> Dict[str, Any]:
    """Audit one fused-train program (a GOLDEN_TRAIN_IDS "<algo>/<env>" id).

    Lowers the exact donated chunk `repro.train.fused.run_fused`
    dispatches — K train steps scanned into one program — and gates it
    like a pool cell: zero host-transfer ops, and EVERY carry leaf
    (network params, optimizer moments, the replay ring, pool state, key
    chain) donated.
    """
    from repro.train.fused import golden_train_setup, lower_train_chunk

    row: Dict[str, Any] = {"id": gid, "backend": TRAIN_BACKEND,
                           "chunk": chunk}
    try:
        algo, env_id, cfg, _ = golden_train_setup(gid)
        row["batch"] = cfg.num_envs
        lowered, carry = lower_train_chunk(algo, env_id, cfg, chunk=chunk)
    except Exception as e:  # repro: allow[silent-except] named-refusal protocol (see audit_cell)
        row.update(status="refused", refusal=type(e).__name__,
                   refusal_msg=str(e).splitlines()[0][:200])
        return row
    return _gate_lowered(row, lowered, carry)


def row_violations(row: Dict[str, Any]) -> List[str]:
    """Gate one row; returns human-readable violation strings (empty = ok)."""
    tag = f"{row['id']}×{row['backend']}"
    if row["status"] == "refused":
        if row["refusal"] in EXPECTED_REFUSALS:
            return []
        return [f"{tag}: unexpected refusal {row['refusal']}: "
                f"{row.get('refusal_msg', '')}"]
    out = []
    if row["host_transfer_ops"]:
        out.append(f"{tag}: {len(row['host_transfer_ops'])} host-transfer "
                   f"op(s) on the compiled step path: "
                   f"{row['host_transfer_ops'][:3]}")
    if row["donation"] < 1.0:
        out.append(f"{tag}: carry donation {row['donated_params']}/"
                   f"{row['carry_params']} — step does not donate its full "
                   "carry")
    if "retraces" in row and row["retraces"] > row["retrace_budget"]:
        out.append(f"{tag}: {row['retraces']} jit traces exceed the "
                   f"allowlisted budget of {row['retrace_budget']} "
                   "(ready-set-size respecialization?)")
    return out


def plan(ids: Optional[Sequence[str]] = None,
         backends: Sequence[str] = BACKENDS) -> List[Tuple[str, str]]:
    """The full audit matrix: every registry id × every backend."""
    ids = list(ids) if ids else sorted(registered())
    return [(i, b) for i in ids for b in backends]


def run(ids: Optional[Sequence[str]] = None,
        backends: Sequence[str] = BACKENDS, batch: int = 4,
        smoke: bool = True, train: Optional[bool] = None,
        progress=None) -> Dict[str, Any]:
    """Run the sweep; returns the report dict (see module docstring).

    `train` adds the fused-train cells (one per GOLDEN_TRAIN_IDS id) after
    the pool matrix; None means auto — on for full-registry sweeps, off
    when an explicit `ids` subset is being audited (the subset names env
    ids, not "<algo>/<env>" training ids).
    """
    cells = plan(ids, backends)
    train = (ids is None) if train is None else train
    retrace_ids = (set(RETRACE_SMOKE_IDS) if smoke
                   else {i for i in {c[0] for c in cells}
                         if supports_fused_step(make(i))})
    rows, violations = [], []
    for env_id, backend in cells:
        row = audit_cell(env_id, backend, batch,
                         run_retrace=(backend in RETRACE_BUDGET
                                      and env_id in retrace_ids))
        rows.append(row)
        violations.extend(row_violations(row))
        if progress:
            progress(row)
    train_ids: Tuple[str, ...] = ()
    if train:
        from repro.train.fused import GOLDEN_TRAIN_IDS

        train_ids = GOLDEN_TRAIN_IDS
        for gid in train_ids:
            row = audit_train_cell(gid)
            rows.append(row)
            violations.extend(row_violations(row))
            if progress:
                progress(row)
    hosted = [r for r in rows if r["status"] == "ok"]
    report = {
        "meta": {
            "smoke": smoke,
            "batch": batch,
            "jax": jax.__version__,
            "platform": jax.default_backend(),
            "backends": list(backends),
            "ids": sorted({c[0] for c in cells}),
            "train_cells": list(train_ids),
            "retrace_budget": dict(RETRACE_BUDGET),
        },
        "rows": rows,
        "summary": {
            "cells": len(rows),
            "hosted": len(hosted),
            "refused": len(rows) - len(hosted),
            "fully_donated": sum(r["donation"] == 1.0 for r in hosted),
            "host_resident": sum(not r["host_transfer_ops"] for r in hosted),
        },
        "violations": violations,
        "ok": not violations,
    }
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="registry-driven compiled-artifact audit "
                    "(residency / donation / retrace gates)")
    ap.add_argument("--smoke", action="store_true",
                    help="small batch, retrace execution on the smoke ids "
                         "only (the make-analyze / bench-json mode)")
    ap.add_argument("--ids", default="",
                    help="comma-separated id subset (default: full registry)")
    ap.add_argument("--backends", default=",".join(BACKENDS),
                    help=f"comma-separated backend subset of {BACKENDS}")
    ap.add_argument("--batch", type=int, default=0,
                    help="envs/slots per pool (default: 4 smoke, 16 full)")
    ap.add_argument("--train", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="audit the fused-train programs too (default: auto "
                         "— on for full-registry sweeps, off with --ids)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the report as JSON")
    args = ap.parse_args(argv)
    ids = [i.strip() for i in args.ids.split(",") if i.strip()] or None
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    if unknown := set(backends) - set(BACKENDS):
        ap.error(f"unknown backends {sorted(unknown)}; expected {BACKENDS}")
    batch = args.batch or (4 if args.smoke else 16)

    def progress(row):
        status = row["status"]
        if status == "ok":
            detail = (f"donated {row['donated_params']}/{row['carry_params']}"
                      f", {len(row['host_transfer_ops'])} host op(s)")
            if "retraces" in row:
                detail += (f", {row['retraces']}/{row['retrace_budget']} "
                           "traces")
        else:
            detail = f"refused: {row['refusal']}"
        print(f"  {row['id']:>18} × {row['backend']:<7} {status:<7} {detail}",
              flush=True)

    report = run(ids=ids, backends=backends, batch=batch, smoke=args.smoke,
                 train=args.train, progress=progress)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    s = report["summary"]
    print(f"repro.analysis.audit: {s['cells']} cells "
          f"({s['hosted']} hosted, {s['refused']} refused), "
          f"{s['fully_donated']}/{s['hosted']} fully donated, "
          f"{s['host_resident']}/{s['hosted']} host-transfer-free, "
          f"{len(report['violations'])} violation(s)")
    for v in report["violations"]:
        print(f"  VIOLATION: {v}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
