"""Energy & carbon tracking — reproduces the paper's Table II methodology.

The paper uses experiment-impact-tracker (Henderson et al. 2020) to compare
CaiRL vs AI Gym emissions. This container's kernel exposes no RAPL, so we
follow the same accounting with a power-envelope model:

    energy_kwh = Σ_component  utilisation × TDP_watts × hours / 1000
    co2_kg     = energy_kwh × carbon_intensity

CPU utilisation comes from process CPU-time / wall-time (os.times), the same
signal the tracker falls back to. The paper's subtraction trick — "We measure
the emissions by subtracting the DQN time usage with the total time to only
account for the environment run-time costs" — is exposed via
`Impact.minus(other)`. Constants are module-level and documented so results
are auditable.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

# Power envelope (paper hardware: Intel 8700K (95 W TDP) + RTX 2080 Ti; our
# runtime is this container's CPU — same model, different constants).
CPU_TDP_WATTS = 95.0
# World-average grid intensity, kgCO2/kWh (IEA 2021; Henderson et al. default).
CARBON_INTENSITY_KG_PER_KWH = 0.475
# Accelerator envelope for the *static* cost model (TPU v5e chip TDP class);
# pairs with the benchmarks/roofline.py per-chip ceilings.
ACCELERATOR_TDP_WATTS = 200.0


@dataclasses.dataclass
class Impact:
    wall_s: float
    cpu_s: float

    @property
    def utilisation(self) -> float:
        return min(self.cpu_s / self.wall_s, float(os.cpu_count() or 1)) if self.wall_s > 0 else 0.0

    @property
    def energy_kwh(self) -> float:
        return self.utilisation * CPU_TDP_WATTS * (self.wall_s / 3600.0) / 1000.0

    @property
    def energy_mwh(self) -> float:
        """Milliwatt-hours, the unit of the paper's Table II."""
        return self.energy_kwh * 1e6

    @property
    def co2_kg(self) -> float:
        return self.energy_kwh * CARBON_INTENSITY_KG_PER_KWH

    def minus(self, other: "Impact") -> "Impact":
        """Paper's subtraction: isolate env cost by removing learner cost."""
        return Impact(max(self.wall_s - other.wall_s, 0.0), max(self.cpu_s - other.cpu_s, 0.0))

    def report(self) -> dict:
        return {
            "wall_s": self.wall_s,
            "cpu_s": self.cpu_s,
            "utilisation": self.utilisation,
            "energy_mWh": self.energy_mwh,
            "co2_kg": self.co2_kg,
        }


@dataclasses.dataclass
class StaticImpact:
    """Compile-time Table II analogue: energy/CO₂ from the *static* roofline
    time bound instead of a measured wall clock.

    `seconds_per_step` is the HLO-derived roofline bound per env step
    (max of compute/memory/collective time, divided by env steps per
    program — see `repro.analysis.cost`); `watts` the power envelope the
    bound is charged against. Deterministic by construction: the same
    compiled artifact always yields the same joules, so these numbers can
    be *gated*, where measured joules can only be observed.
    """

    seconds_per_step: float
    watts: float = ACCELERATOR_TDP_WATTS

    @property
    def joules_per_step(self) -> float:
        return self.seconds_per_step * self.watts

    @property
    def joules_per_mstep(self) -> float:
        """Joules per million env steps (the Table II normalisation)."""
        return self.joules_per_step * 1e6

    @property
    def kwh_per_mstep(self) -> float:
        return self.joules_per_mstep / 3.6e6

    @property
    def co2_g_per_mstep(self) -> float:
        return self.kwh_per_mstep * CARBON_INTENSITY_KG_PER_KWH * 1e3

    def report(self) -> dict:
        return {
            "seconds_per_step": self.seconds_per_step,
            "watts": self.watts,
            "joules_per_mstep": self.joules_per_mstep,
            "kwh_per_mstep": self.kwh_per_mstep,
            "co2_g_per_mstep": self.co2_g_per_mstep,
        }


class ImpactTracker:
    """Context manager: `with ImpactTracker() as t: ...; t.impact.report()`."""

    def __init__(self):
        self.impact: Optional[Impact] = None

    def __enter__(self):
        self._wall0 = time.perf_counter()
        t = os.times()
        self._cpu0 = t.user + t.system + t.children_user + t.children_system
        return self

    def __exit__(self, *exc):
        wall = time.perf_counter() - self._wall0
        t = os.times()
        cpu = (t.user + t.system + t.children_user + t.children_system) - self._cpu0
        self.impact = Impact(wall_s=wall, cpu_s=cpu)
        return False
