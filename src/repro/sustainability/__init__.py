"""sustainability subsystem."""
