"""`from repro import cairl; e = cairl.make("CartPole-v1")` — Listing 2 drop-in.

`make` returns the stateful Gym-compatible shim (reset/step/render), matching
the paper's migration story: change one import line, keep the experiment code.
For compiled fast paths use `cairl.make_functional` + `cairl.rollout`.
"""
from repro.core.registry import make_compat as make  # noqa: F401  (Gym drop-in)
from repro.core.registry import make as make_functional  # noqa: F401
from repro.core.registry import registered  # noqa: F401
from repro.core.runner import rollout, rollout_random  # noqa: F401
from repro.pool import EnvPool, HostPool, ShardedEnvPool, make_pool  # noqa: F401
