"""`from repro import cairl; e = cairl.make("CartPole-v1")` — Listing 2 drop-in.

`make` returns the stateful Gym-compatible shim (reset/step/render), matching
the paper's migration story: change one import line, keep the experiment code.
For compiled fast paths use `cairl.make_functional` + `cairl.rollout`, or go
straight to `cairl.make_vec(id, num_envs)` — the unified vector frontend over
every pool backend. `cairl.spec(id)` exposes the declarative `EnvSpec`
(transform pipeline, tags, time limit) behind each registered id.
"""
from repro.core.registry import make_compat as make  # noqa: F401  (Gym drop-in)
from repro.core.registry import make as make_functional  # noqa: F401
from repro.core.registry import registered, spec, spec_of  # noqa: F401
from repro.core.runner import rollout, rollout_random  # noqa: F401
from repro.pool import (EnvPool, HostPool, ShardedEnvPool,  # noqa: F401
                        make_pool, make_vec)
