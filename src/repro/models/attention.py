"""Attention blocks: GQA (full / sliding-window) and MLA, with KV caches.

Training/prefill attention is *query-chunked* (exact, flash-style memory
profile in pure jnp): scores are materialised only for (B, H, q_chunk, L) at
a time, which keeps per-device activation memory bounded for the 32k cells.
On TPU the Pallas kernel (kernels/attention) replaces the inner computation.

Cache layout (decode): k/v (B, Hkv, S_max, hd) updated in-place with
dynamic_update_slice at `pos`; sliding-window blocks keep S_max = window and
write at `pos % window` (ring), so danube/gemma3-local caches are O(window).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding.rules import BATCH_AXES, shard_hint

_NEG = -1e30


# -- parameter init -----------------------------------------------------------
def gqa_init(key, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype, fan_in=hq * hd),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((hd,), dtype)
        p["k_scale"] = jnp.zeros((hd,), dtype)
    return p


def mla_init(key, cfg, dtype):
    d, hq = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "w_dq": dense_init(ks[0], (d, qr), dtype),
        "q_scale": jnp.zeros((qr,), dtype),
        "w_uq": dense_init(ks[1], (qr, hq * (nope + rope)), dtype, fan_in=qr),
        "w_dkv": dense_init(ks[2], (d, kvr + rope), dtype),   # latent + shared rope-k
        "kv_scale": jnp.zeros((kvr,), dtype),
        "w_ukv": dense_init(ks[3], (kvr, hq * (nope + vd)), dtype, fan_in=kvr),
        "wo": dense_init(ks[4], (hq * vd, d), dtype, fan_in=hq * vd),
    }


# -- exact chunked attention core ---------------------------------------------
def _attend_chunked(
    q: jax.Array,           # (B, Hq, Lq, hd)
    k: jax.Array,           # (B, Hkv, Lk, hd)
    v: jax.Array,           # (B, Hkv, Lk, hd)
    *,
    causal: bool,
    window: int,
    q_offset,               # scalar: absolute position of q[0]
    q_chunk: int = 512,
    kv_valid_len=None,      # scalar: number of valid cache slots (decode)
    scale: float | None = None,
) -> jax.Array:
    b, hq, lq, hd = q.shape
    _, hkv, lk, _ = k.shape
    vd = v.shape[-1]
    group = hq // hkv
    scale = (hd ** -0.5) if scale is None else scale
    q_chunk = min(q_chunk, lq)
    while lq % q_chunk:  # static: largest divisor of lq not above q_chunk
        q_chunk -= 1
    nq = lq // q_chunk

    kpos = jnp.arange(lk)
    k_ = k.reshape(b, hkv, 1, lk, hd)
    v_ = v.reshape(b, hkv, 1, lk, vd)

    @jax.checkpoint  # flash-style: recompute scores in backward, never store p
    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=2)
        qs = qs.reshape(b, hkv, group, q_chunk, hd)
        s = jnp.einsum("bhgqd,bhgkd->bhgqk", qs.astype(jnp.float32), k_.astype(jnp.float32)) * scale
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, lk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        if kv_valid_len is not None:
            mask &= (kpos < kv_valid_len)[None, :]
        s = jnp.where(mask[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhgkd->bhgqd", p, v_.astype(jnp.float32))
        return o.reshape(b, hq, q_chunk, vd).astype(q.dtype)

    if nq == 1:
        return one_chunk(0)
    out = jax.lax.map(one_chunk, jnp.arange(nq))           # (nq, B, Hq, qc, vd)
    return jnp.moveaxis(out, 0, 2).reshape(b, hq, lq, vd)


# -- GQA block ----------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array    # (B, Hkv, S, hd)
    v: jax.Array    # (B, Hkv, S, hd)


def gqa_cache_init(cfg, batch: int, max_seq: int, window: int, dtype) -> KVCache:
    s = min(window, max_seq) if window > 0 else max_seq
    shape = (batch, cfg.num_kv_heads, s, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def gqa_apply(
    params,
    cfg,
    x: jax.Array,                     # (B, L, d)
    *,
    window: int = 0,
    positions: Optional[jax.Array] = None,    # (L,)
    cache: Optional[KVCache] = None,
    cache_pos=None,                   # scalar absolute position of x[0]
    causal: bool = True,
    q_chunk: int = 512,
) -> Tuple[jax.Array, Optional[KVCache]]:
    b, l, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x.dtype
    positions = positions if positions is not None else jnp.arange(l)

    q = (x @ params["wq"].astype(dt)).reshape(b, l, hq, hd)
    k = (x @ params["wk"].astype(dt)).reshape(b, l, hkv, hd)
    v = (x @ params["wv"].astype(dt)).reshape(b, l, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_scale"], cfg.norm_eps)
    if cache is not None and jnp.ndim(cache_pos) == 1:
        rope_pos = cache_pos[:, None, None]    # per-slot decode: (B,1,1)
    else:
        rope_pos = positions                   # (L,)
    q = apply_rope(q.swapaxes(1, 2), rope_pos, cfg.rope_theta)    # (B, Hq, L, hd)
    k = apply_rope(k.swapaxes(1, 2), rope_pos, cfg.rope_theta)    # (B, Hkv, L, hd)
    v = v.swapaxes(1, 2)
    # Pin TP layouts: batch on (pod,data); heads on model where divisible
    # (GQA kv heads replicate within their group when hkv < model size).
    q = shard_hint(q, BATCH_AXES, "model", None, None)
    k = shard_hint(k, BATCH_AXES, "model", None, None)
    v = shard_hint(v, BATCH_AXES, "model", None, None)

    new_cache = None
    if cache is not None:
        s_max = cache.k.shape[2]
        ring = window > 0 and s_max == window
        per_slot = jnp.ndim(cache_pos) == 1  # continuous batching: (B,) positions
        if per_slot:
            # one-token decode with heterogeneous per-slot positions
            slot = (cache_pos % s_max) if ring else cache_pos
            bi = jnp.arange(b)
            ck = cache.k.at[bi, :, slot].set(k[:, :, 0].astype(cache.k.dtype))
            cv = cache.v.at[bi, :, slot].set(v[:, :, 0].astype(cache.v.dtype))
        elif ring:
            # Ring cache: keep only the last `window` positions.
            take = min(l, s_max)
            slots = (cache_pos + l - take + jnp.arange(take)) % s_max
            ck = cache.k.at[:, :, slots].set(k[:, :, l - take:].astype(cache.k.dtype))
            cv = cache.v.at[:, :, slots].set(v[:, :, l - take:].astype(cache.v.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, 0, cache_pos, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, 0, cache_pos, 0))
        new_cache = KVCache(ck, cv)
        if per_slot:
            # attend over slots valid for each batch row
            kpos_ring = jnp.arange(s_max)
            if ring:
                base = (cache_pos // s_max)[:, None] * s_max
                abs_pos = kpos_ring[None, :] + base
                abs_pos = jnp.where(kpos_ring[None, :] > (cache_pos % s_max)[:, None],
                                    abs_pos - s_max, abs_pos)
                valid = (abs_pos <= cache_pos[:, None]) & \
                        (abs_pos > (cache_pos - window)[:, None]) & (abs_pos >= 0)
            else:
                valid = kpos_ring[None, :] <= cache_pos[:, None]
                if window > 0:
                    valid &= kpos_ring[None, :] > (cache_pos - window)[:, None]
            s = jnp.einsum("bhqd,bhkd->bhqk",
                           q.reshape(b, hkv, hq // hkv * l, hd).astype(jnp.float32),
                           ck.astype(jnp.float32)) * (hd ** -0.5)
            s = s.reshape(b, hq, l, s_max)
            s = jnp.where(valid[:, None, None, :], s, _NEG)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", p.reshape(b, hkv, -1, s_max),
                           cv.astype(jnp.float32)).reshape(b, hq, l, hd).astype(dt)
        elif ring and l > 1:
            # SWA prefill (single-shot, cache_pos == 0): attend over the local
            # window of the fresh k/v directly; the ring holds the tail.
            o = _attend_chunked(q, k, v, causal=True, window=window,
                                q_offset=0, q_chunk=q_chunk)
        elif ring:
            # SWA decode: attend over ring slots with ring-aware positions.
            kpos_ring = jnp.arange(s_max)
            slot = cache_pos % s_max
            abs_pos = kpos_ring + (cache_pos // s_max) * s_max
            abs_pos = jnp.where(kpos_ring > slot, abs_pos - s_max, abs_pos)
            valid = (abs_pos <= cache_pos) & (abs_pos > cache_pos - window) & (abs_pos >= 0)
            s = jnp.einsum("bhqd,bhkd->bhqk",
                           q.reshape(b, hkv, hq // hkv * l, hd).astype(jnp.float32),
                           ck.astype(jnp.float32)) * (hd ** -0.5)
            s = s.reshape(b, hq, l, s_max)
            s = jnp.where(valid[None, None, None, :], s, _NEG)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", p.reshape(b, hkv, -1, s_max),
                           cv.astype(jnp.float32)).reshape(b, hq, l, hd).astype(dt)
        else:
            # causal w.r.t. absolute positions: kpos <= qpos also masks the
            # not-yet-written tail of the cache (all written slots < pos+l).
            o = _attend_chunked(q, ck, cv, causal=True, window=window,
                                q_offset=cache_pos, q_chunk=q_chunk)
    else:
        o = _attend_chunked(q, k, v, causal=causal, window=window,
                            q_offset=0, q_chunk=q_chunk)

    o = shard_hint(o, BATCH_AXES, "model", None, None)
    out = o.swapaxes(1, 2).reshape(b, l, hq * hd) @ params["wo"].astype(dt)
    out = shard_hint(out, BATCH_AXES, None, None)
    return out, new_cache


# -- MLA block ------------------------------------------------------------------
class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora_rank) compressed latent
    k_rope: jax.Array  # (B, S, rope_dim) shared positional key


def mla_cache_init(cfg, batch: int, max_seq: int, dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    )


def mla_apply(
    params,
    cfg,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[MLACache] = None,
    cache_pos=None,
    q_chunk: int = 512,
) -> Tuple[jax.Array, Optional[MLACache]]:
    b, l, d = x.shape
    hq = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = x.dtype
    positions = positions if positions is not None else jnp.arange(l)

    # queries
    cq = rms_norm(x @ params["w_dq"].astype(dt), params["q_scale"], cfg.norm_eps)
    q = (cq @ params["w_uq"].astype(dt)).reshape(b, l, hq, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions, cfg.rope_theta)  # (B,H,L,rope)
    q_nope = q_nope.swapaxes(1, 2)

    # compressed kv latent + shared rotary key
    dkv = x @ params["w_dkv"].astype(dt)                    # (B, L, kvr + rope)
    c_kv = rms_norm(dkv[..., : cfg.kv_lora_rank], params["kv_scale"], cfg.norm_eps)
    k_rope_new = apply_rope(dkv[..., cfg.kv_lora_rank:][:, None], positions, cfg.rope_theta)[:, 0]

    new_cache = None
    if cache is not None:
        c_kv_all = jax.lax.dynamic_update_slice(cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache_pos, 0))
        k_rope_all = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, cache_pos, 0))
        new_cache = MLACache(c_kv_all, k_rope_all)
        q_offset = cache_pos
    else:
        c_kv_all, k_rope_all = c_kv, k_rope_new
        q_offset = 0
    kv_valid = None
    causal = True  # kpos <= qpos also masks the unwritten cache tail

    kvr = cfg.kv_lora_rank
    scale = (nope + rope) ** -0.5  # scale uses the full qk dim
    if cfg.mla_absorb:
        # Absorbed form (beyond-paper; DeepSeek-V2 "weight absorption"):
        # attention runs in the LATENT space. W_uk folds into the query and
        # W_uv into the output, so keys/values are the (B, S, kvr) latent
        # SHARED across heads — per-head K/V (B, H, S, nope+vd) is never
        # materialised, cutting attention HBM traffic ~H× at prefill/decode.
        w_ukv = params["w_ukv"].astype(dt).reshape(kvr, hq, nope + vd)
        w_uk = w_ukv[..., :nope]                              # (kvr, H, nope)
        w_uv = w_ukv[..., nope:]                              # (kvr, H, vd)
        q_lat = jnp.einsum("blhn,khn->blhk", q_nope.swapaxes(1, 2), w_uk).swapaxes(1, 2)
        q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)     # (B, H, L, kvr+rope)
        k_eff = jnp.concatenate([c_kv_all, k_rope_all.astype(c_kv_all.dtype)],
                                axis=-1)[:, None]             # (B, 1, S, kvr+rope)
        v_lat = c_kv_all[:, None]                             # (B, 1, S, kvr)
        q_eff = shard_hint(q_eff, BATCH_AXES, "model", None, None)
        o_lat = _attend_chunked(q_eff, k_eff, v_lat, causal=causal, window=0,
                                q_offset=q_offset, q_chunk=q_chunk,
                                kv_valid_len=kv_valid, scale=scale)
        o = jnp.einsum("blhk,khv->blhv", o_lat.swapaxes(1, 2), w_uv).swapaxes(1, 2)
    else:
        # naive form: expand latent to per-head keys/values
        ukv = (c_kv_all @ params["w_ukv"].astype(dt)).reshape(b, -1, hq, nope + vd)
        k_nope = ukv[..., :nope].swapaxes(1, 2)               # (B, H, S, nope)
        v = ukv[..., nope:].swapaxes(1, 2)                    # (B, H, S, vd)
        k_rope_b = jnp.broadcast_to(k_rope_all[:, None], (b, hq, k_rope_all.shape[1], rope))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        q_full = shard_hint(q_full, BATCH_AXES, "model", None, None)
        k_full = shard_hint(k_full, BATCH_AXES, "model", None, None)
        v = shard_hint(v, BATCH_AXES, "model", None, None)
        o = _attend_chunked(q_full, k_full, v, causal=causal, window=0,
                            q_offset=q_offset, q_chunk=q_chunk,
                            kv_valid_len=kv_valid, scale=scale)
    o = shard_hint(o, BATCH_AXES, "model", None, None)
    out = o.swapaxes(1, 2).reshape(b, l, hq * vd) @ params["wo"].astype(dt)
    out = shard_hint(out, BATCH_AXES, None, None)
    return out, new_cache


# -- cross attention (whisper decoder) -----------------------------------------
def cross_init(key, cfg, dtype):
    d, hq, hd = cfg.d_model, cfg.num_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hq * hd), dtype),
        "wv": dense_init(ks[2], (d, hq * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype, fan_in=hq * hd),
    }


def cross_kv(params, cfg, enc: jax.Array):
    """Precompute encoder K/V once (prefill); reused every decode step."""
    b, t, d = enc.shape
    hq, hd = cfg.num_heads, cfg.hd
    k = (enc @ params["wk"].astype(enc.dtype)).reshape(b, t, hq, hd).swapaxes(1, 2)
    v = (enc @ params["wv"].astype(enc.dtype)).reshape(b, t, hq, hd).swapaxes(1, 2)
    return k, v


def cross_apply(params, cfg, x: jax.Array, kv: Tuple[jax.Array, jax.Array],
                q_chunk: int = 512) -> jax.Array:
    b, l, d = x.shape
    hq, hd = cfg.num_heads, cfg.hd
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, l, hq, hd).swapaxes(1, 2)
    k, v = kv
    o = _attend_chunked(q, k, v, causal=False, window=0, q_offset=0, q_chunk=q_chunk)
    return o.swapaxes(1, 2).reshape(b, l, hq * hd) @ params["wo"].astype(x.dtype)
