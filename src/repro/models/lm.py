"""Unified LM: init / forward / loss / prefill / decode for every family.

Families:
  decoder-only ("dense"/"moe"/"ssm"/"hybrid"/"vlm"): tokens -> logits.
  encoder-decoder ("audio", whisper): stubbed frame embeddings -> encoder;
  tokens -> decoder with cross attention (frontend conv stack is a stub per
  the assignment: `input_specs()` supplies precomputed frame embeddings).

The LM head is tied to the embedding by default; the loss never materialises
(B, L, V) logits (layers.chunked_cross_entropy).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import chunked_cross_entropy, dense_init, embed_init, rms_norm
from repro.models.stack import (
    shared_block_init,
    stack_apply,
    stack_cache_init,
    stack_decode,
    stack_init,
    stack_prefill,
)

AUX_WEIGHT = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _has_shared(cfg: ModelConfig) -> bool:
    return any("attn_shared" in blocks for blocks, _ in cfg.segments)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    pdt = _pdtype(cfg)
    key, k_embed, k_stack, k_shared, k_enc, k_head = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, pdt),
        "final_scale": jnp.zeros((cfg.d_model,), pdt),
        "segments": stack_init(k_stack, cfg, cfg.segments, pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), pdt)
    if _has_shared(cfg):
        params["shared"] = shared_block_init(k_shared, cfg, pdt)
    if cfg.is_encoder_decoder:
        params["enc_segments"] = stack_init(k_enc, cfg, cfg.encoder_segments, pdt)
        params["enc_final_scale"] = jnp.zeros((cfg.d_model,), pdt)
    return params


def encode(cfg: ModelConfig, params, frames: jax.Array, remat: str = "none") -> jax.Array:
    """Encoder side (whisper): frames (B, T, d) stub embeddings -> (B, T, d)."""
    x = frames.astype(_dtype(cfg))
    positions = jnp.arange(x.shape[1])
    x, _ = stack_apply(params["enc_segments"], cfg, cfg.encoder_segments, x,
                       positions=positions, remat=remat)
    return rms_norm(x, params["enc_final_scale"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            remat: str = "none") -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B, L, d), aux loss)."""
    from repro.sharding.rules import BATCH_AXES, shard_hint

    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(_dtype(cfg))
    x = shard_hint(x, BATCH_AXES, None, None)
    positions = jnp.arange(tokens.shape[1])
    shared = params.get("shared")
    enc_out = encode(cfg, params, batch["frames"], remat) if cfg.is_encoder_decoder else None
    x, aux = stack_apply(params["segments"], cfg, cfg.segments, x,
                         positions=positions, shared=shared, enc_out=enc_out, remat=remat)
    return rms_norm(x, params["final_scale"], cfg.norm_eps), aux


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            remat: str = "none") -> jax.Array:
    hidden, aux = forward(cfg, params, batch, remat)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    ce = chunked_cross_entropy(hidden, head, batch["labels"],
                               mask=batch.get("mask"),
                               transpose_head=cfg.tie_embeddings)
    return ce + AUX_WEIGHT * aux


def logits_for(cfg: ModelConfig, params, hidden: jax.Array) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    h = head.T if cfg.tie_embeddings else head
    return (hidden @ h.astype(hidden.dtype)).astype(jnp.float32)


# -------------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    dt = _dtype(cfg)
    return stack_cache_init(cfg, cfg.segments, batch, max_seq, dt, enc_len=cfg.encoder_len)


def prefill(cfg: ModelConfig, params, batch: Dict[str, jax.Array], max_seq: int):
    """Run the prompt through the stack, filling caches. Returns (last_logits, caches)."""
    tokens = batch["tokens"]
    b, l = tokens.shape
    caches = init_cache(cfg, b, max_seq)
    x = params["embed"][tokens].astype(_dtype(cfg))
    positions = jnp.arange(l)
    shared = params.get("shared")
    enc_out = encode(cfg, params, batch["frames"]) if cfg.is_encoder_decoder else None
    if cfg.is_encoder_decoder:
        # compute & store cross-attention KV once
        from repro.models.attention import cross_kv

        def fill_cross(seg_params, seg_cache):
            def body(_, xs):
                layer_params, layer_cache = xs
                out = dict(layer_cache)
                k, v = cross_kv(layer_params["b0"]["cross"], cfg, enc_out)
                out["b0"] = dict(layer_cache["b0"], cross_k=k.astype(_dtype(cfg)),
                                 cross_v=v.astype(_dtype(cfg)))
                return 0, out

            _, new = jax.lax.scan(body, 0, (seg_params, seg_cache))
            return new

        caches = [fill_cross(sp, sc) for sp, sc in zip(params["segments"], caches)]
    x, caches = stack_prefill(params["segments"], caches, cfg, cfg.segments, x,
                              positions=positions, shared=shared, enc_out=enc_out)
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    return logits_for(cfg, params, x[:, -1:]), caches


def decode_step(cfg: ModelConfig, params, caches, tokens: jax.Array, pos):
    """tokens: (B, 1) the token decoded at absolute position `pos`."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    shared = params.get("shared")
    x, caches = stack_decode(params["segments"], caches, cfg, cfg.segments, x,
                             jnp.asarray(pos), shared=shared)
    x = rms_norm(x, params["final_scale"], cfg.norm_eps)
    return logits_for(cfg, params, x)[:, 0], caches
