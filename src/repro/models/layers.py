"""Shared neural building blocks (pure functions over pytree params)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# -- initialisers -------------------------------------------------------------
def dense_init(key, shape: Tuple[int, ...], dtype, fan_in: int | None = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)


# -- norms --------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# -- rotary -------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, hd); positions: (L,) or broadcastable to x[..., :, 0]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- FFN ----------------------------------------------------------------------
def swiglu_init(key, d: int, ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, (d, 2 * ff), dtype),    # [gate | up] fused
        "w_out": dense_init(k2, (ff, d), dtype, fan_in=ff),
    }


def swiglu_apply(params, x: jax.Array) -> jax.Array:
    from repro.sharding.rules import BATCH_AXES, shard_hint

    ff = params["w_out"].shape[0]
    gate_up = (x @ params["w_in"].astype(x.dtype)).reshape(x.shape[:-1] + (2, ff))
    gate_up = shard_hint(gate_up, BATCH_AXES, None, None, "model")
    out = (jax.nn.silu(gate_up[..., 0, :]) * gate_up[..., 1, :]) @ params["w_out"].astype(x.dtype)
    return shard_hint(out, BATCH_AXES, None, None)


# -- loss ---------------------------------------------------------------------
def chunked_cross_entropy(
    hidden: jax.Array,       # (B, L, d)
    embed: jax.Array,        # (V, d)  (tied head) or head matrix (d, V)
    labels: jax.Array,       # (B, L) int32
    mask: jax.Array | None = None,
    chunk: int = 512,
    transpose_head: bool = True,
) -> jax.Array:
    """Cross-entropy without materialising (B, L, V) logits.

    Scans over sequence chunks; peak memory is (B, chunk, V). Crucial for the
    262k-vocab gemma3 cells.
    """
    b, l, d = hidden.shape
    chunk = min(chunk, l)
    while l % chunk:  # static: largest divisor of l not above chunk
        chunk -= 1
    head = embed.T if transpose_head else embed   # (d, V)
    if mask is None:
        mask = jnp.ones((b, l), jnp.float32)

    from repro.sharding.rules import BATCH_AXES, shard_hint

    @jax.checkpoint  # never store (B, chunk, V) logits for backward
    def body(carry, xs):
        h, y, m = xs                                  # (B, chunk, d), (B, chunk), (B, chunk)
        h = shard_hint(h, BATCH_AXES, None, None)
        logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
        logits = shard_hint(logits, BATCH_AXES, None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - gold) * m)
        return carry + loss, None

    hs = hidden.reshape(b, l // chunk, chunk, d).swapaxes(0, 1)
    ys = labels.reshape(b, l // chunk, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, l // chunk, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.asarray(0.0, jnp.float32), (hs, ys, ms))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
