"""LM model stack."""
