"""SSM / recurrent blocks: xLSTM (mLSTM + sLSTM) and Mamba2.

All three expose (init, apply, cache_init, decode) with uniform signatures so
the stack machinery treats them like attention blocks. Recurrent state is the
"KV cache" of these blocks — O(1) in sequence length, which is what makes the
long_500k decode cells feasible.

Simplifications vs. the reference implementations (documented in DESIGN.md):
  - mLSTM: exp input gate / sigmoid forget gate without the running-max
    stabiliser (gates ≤ 1 keep the chunked form stable); denominator uses the
    ones-column trick (v is augmented with 1s so the normaliser n_t rides
    along in the same GLA state).
  - Mamba2: single B/C group (G=1), per-head scalar A.
  - sLSTM: exp forget-gate variant with the m_t stabiliser, block-diagonal
    recurrent weights per head.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.gla import gla_chunked, gla_step
from repro.models.layers import dense_init, rms_norm
from repro.sharding.rules import BATCH_AXES, shard_hint


# ======================================================================= mLSTM
def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.expand * d
    h = cfg.num_heads
    k_dim = di // h  # qk head dim
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, di), dtype),
        "w_z": dense_init(ks[1], (d, di), dtype),
        "w_q": dense_init(ks[2], (di, di), dtype, fan_in=di),
        "w_k": dense_init(ks[3], (di, di), dtype, fan_in=di),
        "w_g": dense_init(ks[4], (d, 2 * h), dtype),   # [ĩ | f̃] per head
        "g_bias": jnp.concatenate([jnp.full((h,), -3.0), jnp.full((h,), 3.0)]).astype(dtype),
        "o_scale": jnp.zeros((di,), dtype),
        "w_down": dense_init(ks[5], (di, d), dtype, fan_in=di),
    }


class MLSTMState(NamedTuple):
    s: jax.Array   # (B, H, K, V+1) matrix memory with normaliser column


def mlstm_cache_init(cfg, batch: int, dtype) -> MLSTMState:
    di = cfg.expand * cfg.d_model
    h = cfg.num_heads
    return MLSTMState(jnp.zeros((batch, h, di // h, di // h + 1), jnp.float32))


def _mlstm_qkvg(params, cfg, x):
    b, l, d = x.shape
    di = cfg.expand * d
    h = cfg.num_heads
    hd = di // h
    dt = x.dtype
    xm = shard_hint(x @ params["w_x"].astype(dt), BATCH_AXES, None, "model")
    z = shard_hint(x @ params["w_z"].astype(dt), BATCH_AXES, None, "model")
    q = (xm @ params["w_q"].astype(dt)).reshape(b, l, h, hd).swapaxes(1, 2) * (hd ** -0.5)
    k = (xm @ params["w_k"].astype(dt)).reshape(b, l, h, hd).swapaxes(1, 2) * (hd ** -0.5)
    v = xm.reshape(b, l, h, hd).swapaxes(1, 2)
    q = shard_hint(q, BATCH_AXES, "model", None, None)
    k = shard_hint(k, BATCH_AXES, "model", None, None)
    v = shard_hint(v, BATCH_AXES, "model", None, None)
    gates = x @ params["w_g"].astype(dt) + params["g_bias"].astype(dt)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)             # (B,L,H) each
    log_a = -jax.nn.softplus(-f_pre.astype(jnp.float32)).swapaxes(1, 2)   # log σ(f̃) ≤ 0
    gate_b = jnp.exp(jnp.minimum(i_pre.astype(jnp.float32), 0.0)).swapaxes(1, 2)  # ≤ 1
    # augment v with ones so the normaliser is carried in the state
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    return q, k, v_aug, log_a, gate_b, z


def _mlstm_out(params, cfg, y_aug, z, shape):
    b, l, d = shape
    di = cfg.expand * d
    y, n = y_aug[..., :-1], y_aug[..., -1:]
    h = (y / jnp.maximum(jnp.abs(n), 1.0)).swapaxes(1, 2).reshape(b, l, di)
    h = rms_norm(h, params["o_scale"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return h @ params["w_down"].astype(h.dtype)


def mlstm_apply(params, cfg, x, state: MLSTMState | None = None):
    """Train/prefill. x: (B, L, d). Returns (out, new_state)."""
    q, k, v_aug, log_a, gate_b, z = _mlstm_qkvg(params, cfg, x)
    s0 = state.s if state is not None else jnp.zeros(
        (x.shape[0], cfg.num_heads, q.shape[-1], v_aug.shape[-1]), jnp.float32)
    y, s = gla_chunked(q, k, v_aug, log_a, gate_b, s0, cfg.ssm_chunk)
    return _mlstm_out(params, cfg, y, z, x.shape), MLSTMState(s)


def mlstm_decode(params, cfg, x, state: MLSTMState):
    """x: (B, 1, d)."""
    q, k, v_aug, log_a, gate_b, z = _mlstm_qkvg(params, cfg, x)
    y, s = gla_step(q[:, :, 0], k[:, :, 0], v_aug[:, :, 0],
                    log_a[:, :, 0], gate_b[:, :, 0], state.s)
    return _mlstm_out(params, cfg, y[:, :, None], z, x.shape), MLSTMState(s)


# ======================================================================= sLSTM
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ff = max(4 * d // 3, 8)
    ks = jax.random.split(key, 4)
    return {
        "w": dense_init(ks[0], (d, 4 * d), dtype),           # x -> [i f z o]
        "r": dense_init(ks[1], (h, hd, 4 * hd), dtype, fan_in=hd),  # recurrent, block-diag
        "bias": jnp.concatenate([
            jnp.full((d,), -3.0), jnp.full((d,), 3.0), jnp.zeros((2 * d,))
        ]).astype(dtype),
        # post-MLP (projection factor 4/3, GeLU)
        "mlp_in": dense_init(ks[2], (d, ff), dtype),
        "mlp_out": dense_init(ks[3], (ff, d), dtype, fan_in=ff),
        "mlp_scale": jnp.zeros((d,), dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd)
    n: jax.Array
    m: jax.Array   # (B, H, 1) stabiliser
    h: jax.Array   # (B, H, hd) previous hidden


def slstm_cache_init(cfg, batch: int, dtype) -> SLSTMState:
    h, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return SLSTMState(z, z, jnp.full((batch, h, 1), -1e30, jnp.float32), z)


def _slstm_cell(params, cfg, xt, state: SLSTMState):
    """xt: (B, d) one timestep. Stabilised exp-gate sLSTM."""
    b, d = xt.shape
    hh, hd = cfg.num_heads, d // cfg.num_heads
    pre = (xt @ params["w"].astype(xt.dtype) + params["bias"].astype(xt.dtype)).astype(jnp.float32)
    pre = pre.reshape(b, 4, hh, hd).swapaxes(1, 2)          # (B, H, 4, hd)
    rec = jnp.einsum("bhk,hkj->bhj", state.h, params["r"].astype(jnp.float32))
    pre = pre + rec.reshape(b, hh, 4, hd)
    i_pre, f_pre, z_pre, o_pre = pre[:, :, 0], pre[:, :, 1], pre[:, :, 2], pre[:, :, 3]
    # stabiliser over per-head max (scalar per head keeps gates coupled)
    i_max = jnp.max(i_pre, axis=-1, keepdims=True)
    f_max = jnp.max(f_pre, axis=-1, keepdims=True)
    m_new = jnp.maximum(f_max + state.m, i_max)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_pre + state.m - m_new)
    c = f_g * state.c + i_g * jnp.tanh(z_pre)
    n = f_g * state.n + i_g
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(jnp.abs(n), 1e-6)
    return h, SLSTMState(c, n, m_new, h)


def _slstm_mlp(params, cfg, y):
    yn = rms_norm(y, params["mlp_scale"], cfg.norm_eps)
    return y + jax.nn.gelu(yn @ params["mlp_in"].astype(y.dtype)) @ params["mlp_out"].astype(y.dtype)


def slstm_apply(params, cfg, x, state: SLSTMState | None = None):
    b, l, d = x.shape
    if state is None:
        state = slstm_cache_init(cfg, b, x.dtype)

    @jax.checkpoint  # recompute gate pre-activations in backward
    def body(st, xt):
        h, st = _slstm_cell(params, cfg, xt, st)
        return st, h

    state, hs = jax.lax.scan(body, state, jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, l, d).astype(x.dtype)
    return _slstm_mlp(params, cfg, y), state


def slstm_decode(params, cfg, x, state: SLSTMState):
    b, _, d = x.shape
    h, state = _slstm_cell(params, cfg, x[:, 0], state)
    y = h.reshape(b, 1, d).astype(x.dtype)
    return _slstm_mlp(params, cfg, y), state


# ====================================================================== Mamba2
def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.expand * d
    h = cfg.num_heads
    n = cfg.ssm_state
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),  # [z | x | B | C | dt]
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)).astype(dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "o_scale": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[3], (di, d), dtype, fan_in=di),
    }


class Mamba2State(NamedTuple):
    s: jax.Array      # (B, H, N, P) SSD state
    conv: jax.Array   # (B, W-1, di+2N) conv tail


def mamba2_cache_init(cfg, batch: int, dtype) -> Mamba2State:
    di = cfg.expand * cfg.d_model
    h, n = cfg.num_heads, cfg.ssm_state
    return Mamba2State(
        jnp.zeros((batch, h, n, di // h), jnp.float32),
        jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    )


def _mamba2_proj(params, cfg, x):
    di = cfg.expand * cfg.d_model
    n, h = cfg.ssm_state, cfg.num_heads
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * n]
    dt_pre = zxbcdt[..., -h:]
    return z, xbc, dt_pre


def _mamba2_ssd_inputs(params, cfg, xbc, dt_pre, b, l):
    di = cfg.expand * cfg.d_model
    n, h = cfg.ssm_state, cfg.num_heads
    p = di // h
    xs = xbc[..., :di]
    bs = xbc[..., di: di + n]
    cs = xbc[..., di + n:]
    xs = jax.nn.silu(xs)
    bs = jax.nn.silu(bs)
    cs = jax.nn.silu(cs)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,L,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # (H,)
    log_a = (a[None, None] * dt).swapaxes(1, 2)                # (B,H,L) <= 0
    gate_b = dt.swapaxes(1, 2)                                 # (B,H,L)
    v = xs.reshape(b, l, h, p).swapaxes(1, 2)                  # (B,H,L,P)
    k = jnp.broadcast_to(bs[:, None], (b, h, l, n))            # shared across heads (G=1)
    q = jnp.broadcast_to(cs[:, None], (b, h, l, n))
    v = shard_hint(v, BATCH_AXES, "model", None, None)
    k = shard_hint(k, BATCH_AXES, "model", None, None)
    q = shard_hint(q, BATCH_AXES, "model", None, None)
    return q, k, v, log_a, gate_b, xs


def _mamba2_out(params, cfg, y, xs, z, shape):
    b, l, d = shape
    di = cfg.expand * d
    h = cfg.num_heads
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None, None] * \
        xs.reshape(b, l, h, di // h).swapaxes(1, 2)
    y = y.swapaxes(1, 2).reshape(b, l, di).astype(z.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["o_scale"], cfg.norm_eps)
    return y @ params["w_out"].astype(y.dtype)


def mamba2_apply(params, cfg, x, state: Mamba2State | None = None):
    b, l, d = x.shape
    z, xbc, dt_pre = _mamba2_proj(params, cfg, x)
    # causal depthwise conv (width W); prepend cached tail when decoding chunks
    w = cfg.conv_width
    tail = state.conv if state is not None else jnp.zeros((b, w - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([tail, xbc], axis=1)
    idx = jnp.arange(l)[:, None] + jnp.arange(w)[None, :]      # (L, W)
    windows = padded[:, idx]                                    # (B, L, W, C)
    xbc_conv = jnp.einsum("blwc,wc->blc", windows, params["conv_w"].astype(xbc.dtype)) \
        + params["conv_b"].astype(xbc.dtype)
    new_tail = padded[:, l:]                                    # last W-1 entries

    q, k, v, log_a, gate_b, xs = _mamba2_ssd_inputs(params, cfg, xbc_conv, dt_pre, b, l)
    s0 = state.s if state is not None else jnp.zeros(
        (b, cfg.num_heads, cfg.ssm_state, v.shape[-1]), jnp.float32)
    y, s = gla_chunked(q, k, v, log_a, gate_b, s0, cfg.ssm_chunk)
    out = _mamba2_out(params, cfg, y, xs, z, x.shape)
    return out, Mamba2State(s, new_tail)


def mamba2_decode(params, cfg, x, state: Mamba2State):
    b, _, d = x.shape
    z, xbc, dt_pre = _mamba2_proj(params, cfg, x)
    w = cfg.conv_width
    window = jnp.concatenate([state.conv, xbc], axis=1)        # (B, W, C)
    xbc_conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"].astype(xbc.dtype))[:, None] \
        + params["conv_b"].astype(xbc.dtype)
    new_tail = window[:, 1:]
    q, k, v, log_a, gate_b, xs = _mamba2_ssd_inputs(params, cfg, xbc_conv, dt_pre, b, 1)
    y, s = gla_step(q[:, :, 0], k[:, :, 0], v[:, :, 0], log_a[:, :, 0], gate_b[:, :, 0], state.s)
    out = _mamba2_out(params, cfg, y[:, :, None], xs, z, x.shape)
    return out, Mamba2State(s, new_tail)
