"""Segment-scan stack machinery: block dispatch + scan-over-layers.

A model is a sequence of segments ((block_types, repeat), ...). Parameters
for a segment are stacked along a leading `repeat` axis and consumed by
`lax.scan`, so compile time and HLO size are O(pattern), not O(depth) —
a hard requirement for the 62-layer dry-run cells on this 1-core host and
for real-world compile latency at scale.

Caches mirror the parameter stacking: each segment carries a pytree whose
leaves have leading dim `repeat`; prefill/decode scan over (params, cache)
jointly and emit the updated cache as scan outputs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    KVCache,
    cross_apply,
    cross_init,
    cross_kv,
    gqa_apply,
    gqa_cache_init,
    gqa_init,
    mla_apply,
    mla_cache_init,
    mla_init,
)
from repro.models.layers import rms_norm, swiglu_apply, swiglu_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import (
    mamba2_apply,
    mamba2_cache_init,
    mamba2_decode,
    mamba2_init,
    mlstm_apply,
    mlstm_cache_init,
    mlstm_decode,
    mlstm_init,
    slstm_apply,
    slstm_cache_init,
    slstm_decode,
    slstm_init,
)

ATTN_KINDS = ("full", "swa", "enc", "full_moe", "attn_shared")
SSM_KINDS = ("mlstm", "slstm", "mamba2")


# ------------------------------------------------------------------ block init
def block_init(key, cfg, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    ln1 = jnp.zeros((d,), dtype)
    if kind in ("full", "swa", "enc"):
        return {"ln1": ln1, "attn": gqa_init(ks[0], cfg, dtype),
                "ln2": jnp.zeros((d,), dtype), "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype)}
    if kind == "full_moe":
        return {"ln1": ln1, "attn": gqa_init(ks[0], cfg, dtype),
                "ln2": jnp.zeros((d,), dtype), "moe": moe_init(ks[1], cfg, dtype)}
    if kind == "mla":
        return {"ln1": ln1, "attn": mla_init(ks[0], cfg, dtype),
                "ln2": jnp.zeros((d,), dtype), "mlp": swiglu_init(ks[1], d, cfg.d_ff, dtype)}
    if kind == "dec":
        return {"ln1": ln1, "attn": gqa_init(ks[0], cfg, dtype),
                "ln_x": jnp.zeros((d,), dtype), "cross": cross_init(ks[1], cfg, dtype),
                "ln2": jnp.zeros((d,), dtype), "mlp": swiglu_init(ks[2], d, cfg.d_ff, dtype)}
    if kind == "attn_shared":
        # weights live once at top level (params["shared"]); per-site norms only
        return {"ln1": ln1, "ln2": jnp.zeros((d,), dtype)}
    if kind == "mlstm":
        return {"ln1": ln1, "cell": mlstm_init(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": ln1, "cell": slstm_init(ks[0], cfg, dtype)}
    if kind == "mamba2":
        return {"ln1": ln1, "cell": mamba2_init(ks[0], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def shared_block_init(key, cfg, dtype):
    """zamba2-style shared attention+FFN weights (applied at every site)."""
    k1, k2 = jax.random.split(key)
    return {"attn": gqa_init(k1, cfg, dtype), "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)}


# ----------------------------------------------------------------- block apply
def block_apply(params, cfg, kind: str, x, *, positions, shared=None, enc_out=None,
                cache=None, cache_pos=None):
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.asarray(0.0, jnp.float32)
    new_cache = None
    if kind in ("full", "swa", "full_moe", "attn_shared", "enc"):
        attn_params = shared["attn"] if kind == "attn_shared" else params["attn"]
        window = cfg.window if kind == "swa" else 0
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        o, new_cache = gqa_apply(
            attn_params, cfg, h, window=window, positions=positions,
            cache=cache, cache_pos=cache_pos, causal=(kind != "enc"))
        x = x + o
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if kind == "full_moe":
            o, aux = moe_apply(params["moe"], cfg, h)
        elif kind == "attn_shared":
            o = swiglu_apply(shared["mlp"], h)
        else:
            o = swiglu_apply(params["mlp"], h)
        x = x + o
        return x, aux, new_cache
    if kind == "mla":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        o, new_cache = mla_apply(params["attn"], cfg, h, positions=positions,
                                 cache=cache, cache_pos=cache_pos)
        x = x + o
        x = x + swiglu_apply(params["mlp"], rms_norm(x, params["ln2"], cfg.norm_eps))
        return x, aux, new_cache
    if kind == "dec":
        self_cache = cache["self"] if cache is not None else None
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        o, new_self = gqa_apply(params["attn"], cfg, h, positions=positions,
                                cache=self_cache, cache_pos=cache_pos, causal=True)
        x = x + o
        h = rms_norm(x, params["ln_x"], cfg.norm_eps)
        if cache is not None and "cross_k" in cache:
            kv = (cache["cross_k"], cache["cross_v"])
        else:
            kv = cross_kv(params["cross"], cfg, enc_out)
        x = x + cross_apply(params["cross"], cfg, h, kv)
        x = x + swiglu_apply(params["mlp"], rms_norm(x, params["ln2"], cfg.norm_eps))
        if cache is not None:
            new_cache = dict(cache, self=new_self)
        return x, aux, new_cache
    if kind in SSM_KINDS:
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        fns = {"mlstm": (mlstm_apply, mlstm_decode),
               "slstm": (slstm_apply, slstm_decode),
               "mamba2": (mamba2_apply, mamba2_decode)}[kind]
        is_decode = cache is not None and x.shape[1] == 1
        o, new_cache = (fns[1] if is_decode else fns[0])(params["cell"], cfg, h, cache)
        return x + o, aux, new_cache
    raise ValueError(f"unknown block kind {kind!r}")


# ----------------------------------------------------------------- block cache
def block_cache_init(cfg, kind: str, batch: int, max_seq: int, dtype, enc_len: int = 0):
    if kind in ("full", "full_moe", "attn_shared", "enc"):
        return gqa_cache_init(cfg, batch, max_seq, 0, dtype)
    if kind == "swa":
        return gqa_cache_init(cfg, batch, max_seq, cfg.window, dtype)
    if kind == "mla":
        return mla_cache_init(cfg, batch, max_seq, dtype)
    if kind == "dec":
        hd = cfg.hd
        return {
            "self": gqa_cache_init(cfg, batch, max_seq, 0, dtype),
            "cross_k": jnp.zeros((batch, cfg.num_heads, enc_len, hd), dtype),
            "cross_v": jnp.zeros((batch, cfg.num_heads, enc_len, hd), dtype),
        }
    if kind == "mlstm":
        return mlstm_cache_init(cfg, batch, dtype)
    if kind == "slstm":
        return slstm_cache_init(cfg, batch, dtype)
    if kind == "mamba2":
        return mamba2_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------- segment init
def stack_init(key, cfg, segments, dtype):
    seg_params = []
    for blocks, rep in segments:
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, rep)

        def init_one(k, blocks=blocks):
            ks = jax.random.split(k, len(blocks))
            return {f"b{i}": block_init(ks[i], cfg, kind, dtype)
                    for i, kind in enumerate(blocks)}

        seg_params.append(jax.vmap(init_one)(keys))
    return seg_params


def stack_cache_init(cfg, segments, batch: int, max_seq: int, dtype, enc_len: int = 0):
    caches = []
    for blocks, rep in segments:
        one = {f"b{i}": block_cache_init(cfg, kind, batch, max_seq, dtype, enc_len)
               for i, kind in enumerate(blocks)}
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (rep,) + x.shape).copy(), one))
    return caches


# -------------------------------------------------------------- forward passes
def stack_apply(seg_params, cfg, segments, x, *, positions, shared=None, enc_out=None,
                remat: str = "none"):
    """Train forward (no cache). Returns (x, total aux loss)."""
    aux_total = jnp.asarray(0.0, jnp.float32)
    for (blocks, rep), params in zip(segments, seg_params):

        def body(carry, layer_params, blocks=blocks):
            h, aux = carry
            from repro.sharding.rules import BATCH_AXES, shard_hint

            h = shard_hint(h, BATCH_AXES, None, None)
            for i, kind in enumerate(blocks):
                h, a, _ = block_apply(layer_params[f"b{i}"], cfg, kind, h,
                                      positions=positions, shared=shared, enc_out=enc_out)
                aux = aux + a
            return (h, aux), None

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params)
    return x, aux_total


def stack_prefill(seg_params, caches, cfg, segments, x, *, positions, shared=None,
                  enc_out=None):
    """Prefill: forward while writing caches at positions [0, L)."""
    new_caches = []
    for (blocks, rep), params, cache in zip(segments, seg_params, caches):

        def body(h, xs, blocks=blocks):
            layer_params, layer_cache = xs
            new_layer = {}
            for i, kind in enumerate(blocks):
                h, _, c = block_apply(layer_params[f"b{i}"], cfg, kind, h,
                                      positions=positions, shared=shared, enc_out=enc_out,
                                      cache=layer_cache[f"b{i}"], cache_pos=0)
                new_layer[f"b{i}"] = c
            return h, new_layer

        x, new_cache = jax.lax.scan(body, x, (params, cache))
        new_caches.append(new_cache)
    return x, new_caches


def stack_decode(seg_params, caches, cfg, segments, x, pos, *, shared=None):
    """One-token decode. x: (B, 1, d); pos: scalar absolute position."""
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    new_caches = []
    for (blocks, rep), params, cache in zip(segments, seg_params, caches):

        def body(h, xs, blocks=blocks):
            layer_params, layer_cache = xs
            new_layer = {}
            for i, kind in enumerate(blocks):
                h, _, c = block_apply(layer_params[f"b{i}"], cfg, kind, h,
                                      positions=positions, shared=shared,
                                      cache=layer_cache[f"b{i}"], cache_pos=pos)
                new_layer[f"b{i}"] = c
            return h, new_layer

        x, new_cache = jax.lax.scan(body, x, (params, cache))
        new_caches.append(new_cache)
    return x, new_caches
