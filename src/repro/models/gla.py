"""Chunked gated linear attention (GLA) — the shared engine for mLSTM & Mamba2.

Both xLSTM's matrix-memory cell and Mamba2's SSD are instances of the same
recurrence with per-head *scalar* gates:

    S_t = exp(a_t) · S_{t-1} + b_t · k_t v_tᵀ          S: (K, V) per head
    y_t = q_tᵀ · S_t

Training/prefill uses the chunkwise-parallel form (intra-chunk masked matmul
on the MXU + inter-chunk lax.scan over L/C steps); decode is the one-step
recurrence. a_t ≤ 0 guarantees all exponentials ≤ 1, so the chunked form is
numerically stable without a running-max stabiliser.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_NEG = -1e30


def gla_chunked(
    q: jax.Array,        # (B, H, L, K)
    k: jax.Array,        # (B, H, L, K)
    v: jax.Array,        # (B, H, L, V)
    log_a: jax.Array,    # (B, H, L)   log decay, <= 0
    gate_b: jax.Array,   # (B, H, L)   input gate, >= 0
    s0: jax.Array,       # (B, H, K, V) initial state
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,H,L,V), final state (B,H,K,V))."""
    b, h, l, kk = q.shape
    vv = v.shape[-1]
    c = min(chunk, l)
    while l % c:  # static: largest divisor of l not above chunk
        c -= 1
    nc = l // c

    def split(x):
        return jnp.moveaxis(x.reshape(b, h, nc, c, *x.shape[4:] or ()), 2, 0) \
            if x.ndim == 4 else jnp.moveaxis(x.reshape(b, h, nc, c), 2, 0)

    qs = jnp.moveaxis(q.reshape(b, h, nc, c, kk), 2, 0)
    ks = jnp.moveaxis(k.reshape(b, h, nc, c, kk), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, h, nc, c, vv), 2, 0)
    als = jnp.moveaxis(log_a.reshape(b, h, nc, c), 2, 0)
    bs = jnp.moveaxis(gate_b.reshape(b, h, nc, c), 2, 0)

    tril = jnp.tril(jnp.ones((c, c), bool))

    @jax.checkpoint  # recompute intra-chunk A in backward; never store it
    def body(s, xs):
        qc, kc, vc, ac, bc = xs
        qc32, kc32, vc32 = qc.astype(jnp.float32), kc.astype(jnp.float32), vc.astype(jnp.float32)
        cum = jnp.cumsum(ac.astype(jnp.float32), axis=-1)      # (B,H,C)
        total = cum[..., -1:]                                   # (B,H,1)
        # intra-chunk: A_ij = (q_i·k_j)·exp(cum_i−cum_j)·b_j for j<=i
        expnt = cum[..., :, None] - cum[..., None, :]           # (B,H,C,C)
        decay = jnp.exp(jnp.where(tril, expnt, _NEG))
        attn = jnp.einsum("bhik,bhjk->bhij", qc32, kc32)
        a_mat = attn * decay * bc.astype(jnp.float32)[..., None, :]
        y_intra = jnp.einsum("bhij,bhjv->bhiv", a_mat, vc32)
        # inter-chunk: carried state
        qd = qc32 * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bhik,bhkv->bhiv", qd, s)
        # state update
        kd = kc32 * (jnp.exp(total - cum) * bc.astype(jnp.float32))[..., None]
        s_new = jnp.exp(total)[..., None] * s + jnp.einsum("bhjk,bhjv->bhkv", kd, vc32)
        return s_new, (y_intra + y_inter).astype(q.dtype)

    s_final, ys = jax.lax.scan(body, s0.astype(jnp.float32), (qs, ks, vs, als, bs))
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, l, vv)
    return y, s_final


def gla_ref(q, k, v, log_a, gate_b, s0):
    """Sequential oracle (per-timestep scan) used by property tests."""
    def body(s, xs):
        qt, kt, vt, at, bt = xs      # (B,H,K), (B,H,K), (B,H,V), (B,H), (B,H)
        s = jnp.exp(at)[..., None, None] * s + bt[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        y = jnp.einsum("bhk,bhkv->bhv", qt, s)
        return s, y

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (q, k, v))
    xs = xs + tuple(jnp.moveaxis(x, 2, 0) for x in (log_a, gate_b))
    s, ys = jax.lax.scan(body, s0.astype(jnp.float32),
                         tuple(x.astype(jnp.float32) for x in xs))
    return jnp.moveaxis(ys, 0, 2).astype(q.dtype), s


def gla_step(q, k, v, log_a, gate_b, s):
    """One decode step. q/k: (B,H,K); v: (B,H,V); gates: (B,H); s: (B,H,K,V)."""
    s = jnp.exp(log_a.astype(jnp.float32))[..., None, None] * s + \
        gate_b.astype(jnp.float32)[..., None, None] * (
            k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), s)
    return y.astype(q.dtype), s
