"""Top-k MoE with sort-based capacity dispatch (FLOP-faithful, EP-shardable).

Tokens are routed top-k, sorted by expert id, and packed into an
(E, capacity, d) buffer so the expert FFNs are dense batched matmuls —
(E, cap, d) × (E, d, 2ff) — whose FLOPs equal the *active* compute only
(never the dense all-experts product). The expert dimension E is sharded
over the `model` mesh axis (expert parallelism); XLA lowers the pack/unpack
scatters to all-to-alls across the token-shard → expert-shard boundary.

Overflowing tokens (rank ≥ capacity) are dropped (standard capacity-factor
semantics); their gate mass is simply lost, which the load-balance auxiliary
loss discourages.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = ff ** -0.5
    return {
        "router": dense_init(ks[0], (d, e), dtype),
        "w_in": (jax.random.normal(ks[1], (e, d, 2 * ff)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e, ff, d)) * scale_out).astype(dtype),
    }


def moe_capacity(num_tokens: int, cfg) -> int:
    cap = int(num_tokens * cfg.num_experts_per_tok / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for tiling


def moe_apply(params, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out (B, L, d), load-balance aux loss (scalar)).

    Dispatch is GROUPED: tokens are split into G groups (cfg.moe_groups,
    aligned to the data-parallel sharding) and sorted/packed per group. With
    G ≥ #data-shards every sort, scatter and gather is shard-LOCAL — GSPMD
    never materialises a global dispatch buffer (the G=1 global-sort form
    costs a full-buffer all-reduce per layer; see EXPERIMENTS.md §Perf).
    Capacity is per-group, so drops are decided locally (standard EP
    semantics).
    """
    from repro.sharding.rules import BATCH_AXES, shard_hint

    b, l, d = x.shape
    t_all = b * l
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    g = max(getattr(cfg, "moe_groups", 0), 1)
    while t_all % g:
        g -= 1
    t = t_all // g                                                # tokens per group
    dt = x.dtype
    xt = x.reshape(g, t, d)
    xt = shard_hint(xt, BATCH_AXES, None, None)

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)    # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_logits, idx = jax.lax.top_k(logits, k)                         # (G, T, k)
    gates = jax.nn.softmax(gate_logits, axis=-1).astype(dt)

    cap = moe_capacity(t, cfg)
    expert_idx = idx.reshape(g, t * k)                                  # (G, T·k)
    token_idx = jnp.tile(jnp.repeat(jnp.arange(t), k)[None], (g, 1))
    order = jnp.argsort(expert_idx, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(expert_idx, order, axis=1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank = jnp.arange(t * k)[None] - first
    dest = sorted_e * cap + rank
    valid = rank < cap
    src_tok = jnp.take_along_axis(token_idx, order, axis=1)
    garr = jnp.arange(g)[:, None]

    # pack -> (G, E, cap, d). The scatter stays LOCAL: the buffer is sharded
    # on groups only (replicated over model), so no cross-shard writes; the
    # expert einsum against EP-sharded weights then slices the e dim locally.
    buf = jnp.zeros((g, e * cap, d), dt)
    buf = buf.at[garr, jnp.where(valid, dest, e * cap)].set(
        xt[garr, src_tok], mode="drop")
    buf = buf.reshape(g, e, cap, d)
    buf = shard_hint(buf, BATCH_AXES, None, None, None)

    # expert FFNs (SwiGLU) — dense batched matmuls on the MXU
    gu = jnp.einsum("gecd,edf->gecf", buf, params["w_in"].astype(dt))
    ff = params["w_out"].shape[1]
    gate, up = gu[..., :ff], gu[..., ff:]
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("gecf,efd->gecd", h, params["w_out"].astype(dt))
    # combine needs every expert's rows: replicate over model (this all-gather
    # IS the EP combine traffic), then gather/scatter locally per group.
    # (Gathering straight from the expert-sharded buffer measured 3.8× WORSE —
    #  GSPMD falls back to replicate-then-repartition; EXPERIMENTS.md §Perf.)
    out_e = shard_hint(out_e, BATCH_AXES, None, None, None).reshape(g, e * cap, d)

    # unpack + gate-weighted combine (per group; all shard-local)
    slot_out = out_e[garr, jnp.where(valid, dest, 0)] * valid[..., None].astype(dt)
    weighted = slot_out * jnp.take_along_axis(
        gates.reshape(g, t * k), order, axis=1)[..., None]
    out = jnp.zeros((g, t, d), dt).at[garr, src_tok].add(weighted)
    out = shard_hint(out, BATCH_AXES, None, None)

    # Switch-style load-balance loss: E · Σ_i f_i · p_i (global averages)
    counts = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    f = counts / (t_all * k)
    p = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f * p)
    return out.reshape(b, l, d), aux


def moe_ref(params, cfg, x: jax.Array) -> jax.Array:
    """Dense oracle: every token through its top-k experts via full compute.

    O(T·E) FLOPs — test-only. Capacity drops are NOT modelled, so compare
    with capacity_factor large enough that nothing overflows.
    """
    b, l, d = x.shape
    t = b * l
    dt = x.dtype
    xt = x.reshape(t, d)
    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)
    gate_logits, idx = jax.lax.top_k(logits, cfg.num_experts_per_tok)
    gates = jax.nn.softmax(gate_logits, axis=-1).astype(dt)

    def one_expert(eid):
        gu = xt @ params["w_in"][eid].astype(dt)
        gate, up = jnp.split(gu, 2, axis=-1)
        return (jax.nn.silu(gate) * up) @ params["w_out"][eid].astype(dt)

    all_out = jax.vmap(one_expert)(jnp.arange(cfg.num_experts))         # (E, T, d)
    picked = all_out[idx.T, jnp.arange(t)[None]]                        # (k, T, d)
    out = jnp.sum(picked * gates.T[..., None], axis=0)
    return out.reshape(b, l, d)
