"""runtime subsystem: elasticity, failure detection, supervised rollouts."""
from repro.runtime.elastic import build_mesh, propose_mesh, reshard_state
from repro.runtime.failures import (DeviceLossError, Fault, FaultInjector,
                                    HeartbeatMonitor, HostStatus,
                                    RecoveryPlan, plan_recovery)
from repro.runtime.straggler import StragglerTracker
from repro.runtime.supervisor import RolloutSupervisor

__all__ = [
    "build_mesh", "propose_mesh", "reshard_state",
    "DeviceLossError", "Fault", "FaultInjector", "HeartbeatMonitor",
    "HostStatus", "RecoveryPlan", "plan_recovery",
    "StragglerTracker", "RolloutSupervisor",
]
