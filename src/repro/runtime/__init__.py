"""runtime subsystem."""
