"""Straggler detection & mitigation (host-side telemetry).

SPMD training runs at the speed of the slowest participant, so persistent
stragglers are as costly as failures. Policy implemented here:
  1. per-host step-time EWMA; hosts persistently > `threshold`× the fleet
     median are flagged;
  2. flagged hosts get `advice`: first "profile" (transient), then "demote"
     (evict + re-mesh via runtime/elastic.py, cheaper than dragging the
     fleet — the same restore path as a failure, planned not reactive).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List


@dataclasses.dataclass
class StragglerReport:
    host_id: int
    ewma_s: float
    median_s: float
    ratio: float
    advice: str


class StragglerTracker:
    """Participants are hosts for SPMD training; the env service
    (serving/env_service.py) reuses the same policy over *client sessions* —
    a session whose action round-trip is persistently slower than the fleet
    median is the slow consumer the async pool exists to isolate, and gets
    the same profile->demote advice. Sessions come and go, so ids register
    lazily on first `record` (num_hosts=0) and `forget` drops departed ones.
    """

    def __init__(self, num_hosts: int = 0, threshold: float = 1.5,
                 alpha: float = 0.2, patience: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.patience = patience
        self.ewma: Dict[int, float] = {h: 0.0 for h in range(num_hosts)}
        self.strikes: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def record(self, host_id: int, step_time_s: float) -> None:
        prev = self.ewma.setdefault(host_id, 0.0)
        self.strikes.setdefault(host_id, 0)
        self.ewma[host_id] = step_time_s if prev == 0.0 else (
            self.alpha * step_time_s + (1 - self.alpha) * prev
        )

    def reports(self) -> List[StragglerReport]:
        vals = [v for v in self.ewma.values() if v > 0]
        if not vals:
            return []
        med = statistics.median(vals)
        out = []
        for h, v in self.ewma.items():
            if v <= 0:
                continue
            ratio = v / med if med > 0 else 1.0
            if ratio > self.threshold:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            advice = "ok"
            if self.strikes[h] >= self.patience:
                advice = "demote"
            elif self.strikes[h] > 0:
                advice = "profile"
            if advice != "ok":
                out.append(StragglerReport(h, v, med, ratio, advice))
        return out

    def forget(self, host_id: int) -> None:
        """Drop a departed participant (a released session) from the fleet."""
        self.ewma.pop(host_id, None)
        self.strikes.pop(host_id, None)

    def hosts_to_demote(self) -> List[int]:
        return [r.host_id for r in self.reports() if r.advice == "demote"]
