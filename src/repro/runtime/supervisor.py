"""RolloutSupervisor — fault-tolerant elastic rollouts over any pool.

A pool that serves heavy traffic is worthless if one device loss throws
away every in-flight episode. The supervisor wraps any pool backend
(EnvPool / ShardedEnvPool / AsyncEnvPool) and makes its stateful rollout
*survivable* without touching the compiled step path:

  step/recv ──► fault poll ──► pool step (unchanged compiled program)
                                   │
                        every `snapshot_every` steps
                                   ▼
                   pool.state_dict() + step counter ──► CheckpointManager
                   (host gather at the boundary;        (async atomic write,
                    the steady-state step stays          keep-k GC)
                    zero-host-transfer — HLO-checked)

On device loss (a scripted FaultInjector "device_loss" fault here; the XLA
runtime error on real hardware) the step path raises `DeviceLossError` and
the driver calls `recover()`:

  propose_mesh(survivors)  ──►  rebuild the pool on the smaller mesh
  (runtime/elastic.py)          (shardings re-derived by the pool)
          │                              │
          └────────► restore the latest snapshot ◄────────┘
                     (mesh-agnostic gathered arrays)

and the rollout resumes from the snapshot's step counter, bit-identically:
the snapshot carries the env state, the AutoReset key chains, the carry
key, the observation and — for async pools — the active-slot mask and both
host key chains, so replaying the deterministic action/key stream from
`supervisor.t` reproduces the exact uninterrupted trajectory
(tests/test_supervisor.py proves it against the committed golden traces).

Heartbeats: with a `HeartbeatMonitor` attached, every step relays beats for
the live hosts of the simulated fleet; a scripted "host_death" fault stops
one host's beats so the monitor times it out exactly like a real silence,
and `plan_recovery` then sizes the surviving mesh.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import build_mesh, propose_mesh
from repro.runtime.failures import (DeviceLossError, FaultInjector,
                                    HeartbeatMonitor, plan_recovery)


class RolloutSupervisor:
    """Wrap a pool so its rollout survives kills, preemptions and re-meshes.

    >>> pool = ShardedEnvPool("CartPole-v1", 256, mesh=mesh)
    >>> sup = RolloutSupervisor(pool, "/ckpt/run0", snapshot_every=64)
    >>> sup.reset(seed=0)
    >>> while t < total:
    ...     try:
    ...         obs, rew, done, info = sup.step(actions[t]); t += 1
    ...     except DeviceLossError:
    ...         sup.recover()          # smaller mesh + restore
    ...         t = sup.t              # replay the deterministic stream

    The wrapped pool's full surface stays reachable (attribute passthrough);
    `step`/`send`/`recv` are intercepted for fault polling, heartbeats and
    the snapshot cadence. Snapshots are asynchronous by default — the device
    -> host gather runs at the step boundary, the file write off-thread
    (CheckpointManager serializes and joins them).
    """

    def __init__(self, pool, manager, *, snapshot_every: int = 64,
                 blocking_snapshots: bool = False,
                 monitor: Optional[HeartbeatMonitor] = None,
                 injector: Optional[FaultInjector] = None,
                 devices_per_host: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.pool = pool
        self.manager = (manager if isinstance(manager, CheckpointManager)
                        else CheckpointManager(manager))
        self.snapshot_every = int(snapshot_every)
        self.blocking_snapshots = blocking_snapshots
        self.monitor = monitor
        self.injector = injector
        self.devices_per_host = devices_per_host
        self.clock = clock
        #: steps served since reset() — the data-stream position; restored
        #: from the snapshot so the driver knows where to resume the replay
        self.t = 0
        self.snapshots = 0
        self.recoveries = 0
        self._dead_hosts: set = set()

    # -- pool passthrough ------------------------------------------------------
    def __getattr__(self, name):
        return getattr(self.pool, name)

    def __len__(self) -> int:
        return len(self.pool)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"RolloutSupervisor({self.pool!r}, t={self.t}, "
                f"snapshots={self.snapshots}, recoveries={self.recoveries})")

    # -- supervised stateful surface ------------------------------------------
    def reset(self, seed: int = 0):
        obs = self.pool.reset(seed=seed)
        self.t = 0
        self._beat()
        return obs

    def step(self, actions, key=None):
        """One supervised pool step: poll faults, step, beat, maybe snapshot."""
        self.poll_faults()
        out = (self.pool.step(actions) if key is None
               else self.pool.step(actions, key=key))
        self._after_step()
        return out

    # async-pool surface: send stages (faults polled), recv is the step tick
    def send(self, actions, ids) -> None:
        self.poll_faults()
        self.pool.send(actions, ids)

    def recv(self, **kwargs):
        out = self.pool.recv(**kwargs)
        self._after_step()
        return out

    def _after_step(self) -> None:
        self.t += 1
        self._beat()
        if self.snapshot_every and self.t % self.snapshot_every == 0:
            self.snapshot()

    # -- heartbeats / faults ---------------------------------------------------
    def _beat(self) -> None:
        """Relay beats for the simulated fleet's live hosts (single-process
        stand-in for each host's own heartbeat loop)."""
        if self.monitor is None:
            return
        for h in self.monitor.hosts:
            if h not in self._dead_hosts:
                self.monitor.beat(h, self.t)

    def poll_faults(self) -> None:
        """Consume due scripted faults. "host_death" silences that host's
        beats (the monitor then times it out); "device_loss" raises out of
        the step path — the driver handles it with `recover()`."""
        if self.injector is None:
            return
        for f in self.injector.due(kinds=("host_death", "device_loss")):
            if f.kind == "host_death":
                self._dead_hosts.add(f.arg if f.arg is not None else 0)
            elif f.kind == "device_loss":
                raise DeviceLossError(int(f.arg) if f.arg is not None else 1)

    # -- snapshot / restore ----------------------------------------------------
    def snapshot(self, blocking: Optional[bool] = None) -> str:
        """Persist the pool carry + step counter as checkpoint step `t`."""
        tree = dict(self.pool.state_dict())
        assert "t" not in tree
        tree["t"] = np.asarray(self.t, np.int64)
        blocking = (self.blocking_snapshots if blocking is None else blocking)
        path = self.manager.save(self.t, tree, blocking=blocking)
        self.snapshots += 1
        return path

    def restore(self, step: Optional[int] = None, pool=None) -> int:
        """Restore a snapshot (latest by default) into `pool` (default: the
        current one); returns the restored step counter."""
        if pool is not None:
            self.pool = pool
        self.manager.wait()  # an in-flight write may BE the target snapshot
        if getattr(self.pool, "_carry", None) is None:
            self.pool.reset(seed=0)  # template structure only; overwritten
        template = dict(self.pool.state_dict())
        template["t"] = np.asarray(0, np.int64)
        tree = self.manager.restore(template, step=step)
        self.t = int(np.asarray(tree.pop("t")))
        self.pool.load_state_dict(tree)
        self._beat()
        return self.t

    # -- elastic recovery ------------------------------------------------------
    def recover(self, n_devices: Optional[int] = None,
                rebuild: Optional[Callable] = None,
                step: Optional[int] = None) -> Dict[str, Any]:
        """Device-loss recovery: size the surviving mesh, rebuild the pool on
        it, restore the latest snapshot.

        `n_devices` defaults to the monitor's surviving hosts ×
        devices_per_host (every visible device without a monitor).
        `rebuild(mesh) -> pool` builds the replacement; the default re-meshes
        a ShardedEnvPool and reconstructs EnvPool/AsyncEnvPool like-for-like.
        Returns a record of the plan (mesh shape, restored step, ...).
        """
        self.manager.wait()
        plan_notes = ""
        if n_devices is None:
            if self.monitor is not None:
                plan = plan_recovery(self.monitor, self.devices_per_host,
                                     self.manager.latest_step())
                n_devices, plan_notes = plan.new_device_count, plan.notes
            else:
                import jax

                n_devices = len(jax.devices())
        else:
            n_devices = int(n_devices)
        import jax

        # a simulated fleet can claim more hosts than this process has real
        # devices; the mesh can only be built from what XLA actually sees
        n_devices = max(1, min(n_devices, len(jax.devices())))
        # env pools are pure data-parallel: no model axis to preserve
        shape, axes = propose_mesh(n_devices, prefer_model=1)
        mesh = build_mesh(n_devices, prefer_model=1)
        new_pool = (rebuild or self._default_rebuild)(mesh)
        t = self.restore(step=step, pool=new_pool)
        self.recoveries += 1
        return {"mesh_shape": shape, "mesh_axes": axes,
                "n_devices": n_devices, "restored_step": t,
                "notes": plan_notes}

    def _default_rebuild(self, mesh):
        from repro.pool import AsyncEnvPool, EnvPool, ShardedEnvPool

        p = self.pool
        if isinstance(p, ShardedEnvPool):
            return ShardedEnvPool(p.env, p.num_envs, mesh=mesh,
                                  backend=p.backend, unroll=p.unroll)
        if isinstance(p, AsyncEnvPool):
            return AsyncEnvPool(p.env, p.num_slots, backend=p.backend)
        if isinstance(p, EnvPool):
            return EnvPool(p.env, p.num_envs, backend=p.backend,
                           unroll=p.unroll)
        raise TypeError(f"no default rebuild for {type(p).__name__}; "
                        "pass rebuild=")

    def close(self) -> None:
        """Join pending snapshot writes (and refuse further saves)."""
        self.manager.close()
