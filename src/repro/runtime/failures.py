"""Failure detection & recovery orchestration (host-side control plane).

At 1000+ nodes the control loop is: heartbeat → detect → checkpoint-restore
→ (possibly smaller) mesh → resume from the exact data step. Device code
stays pure; everything here is host logic, unit-testable on CPU with
simulated clocks and injected failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HostStatus:
    host_id: int
    last_heartbeat: float
    last_step: int


class HeartbeatMonitor:
    """Tracks per-host liveness; hosts missing > timeout are declared dead."""

    def __init__(self, num_hosts: int, timeout_s: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self.hosts: Dict[int, HostStatus] = {
            h: HostStatus(h, now, -1) for h in range(num_hosts)
        }

    def beat(self, host_id: int, step: int) -> None:
        st = self.hosts[host_id]
        st.last_heartbeat = self.clock()
        st.last_step = max(st.last_step, step)

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items() if now - st.last_heartbeat > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_hosts()

    def quorum_step(self) -> int:
        """Highest step every live host has definitely passed."""
        live = [st.last_step for h, st in self.hosts.items() if h not in self.dead_hosts()]
        return min(live) if live else -1


@dataclasses.dataclass
class RecoveryPlan:
    restart_step: int
    surviving_hosts: List[int]
    new_device_count: int
    mesh_shape: tuple
    notes: str


def plan_recovery(monitor: HeartbeatMonitor, devices_per_host: int,
                  checkpoint_step: Optional[int]) -> RecoveryPlan:
    """Derive the restart plan after failures: surviving mesh + restore step."""
    from repro.runtime.elastic import propose_mesh

    dead = set(monitor.dead_hosts())
    surviving = [h for h in monitor.hosts if h not in dead]
    n_dev = len(surviving) * devices_per_host
    shape, axes = propose_mesh(n_dev)
    restart = checkpoint_step if checkpoint_step is not None else 0
    return RecoveryPlan(
        restart_step=restart,
        surviving_hosts=surviving,
        new_device_count=n_dev,
        mesh_shape=shape,
        notes=f"lost hosts {sorted(dead)}; remesh to {shape} {axes}; "
              f"data stream resumes at step {restart} (deterministic pipeline)",
    )
