"""Failure detection & recovery orchestration (host-side control plane).

At 1000+ nodes the control loop is: heartbeat → detect → checkpoint-restore
→ (possibly smaller) mesh → resume from the exact data step. Device code
stays pure; everything here is host logic, unit-testable on CPU with
simulated clocks and injected failures.

`FaultInjector` is the scripted-failure half of that testability story: a
schedule of (time, kind) faults on an injectable clock, consumed by the
layers that simulate each failure mode —

  - "device_loss"  : runtime/supervisor.py raises DeviceLossError from the
                     step path, triggering the elastic recover() flow;
  - "host_death"   : the supervisor stops relaying that host's heartbeats,
                     so HeartbeatMonitor times it out like a real silence;
  - "stall"        : serving/env_service.py treats the named session's next
                     action collection as timed out (a dead/slow client);
  - "preempt_save" : wired to CheckpointManager._pre_replace_hook to kill a
                     write after the tmp dir exists but before the atomic
                     rename — the mid-save preemption window.

The injector only *schedules*; each consumer decides what the fault means,
which keeps the harness reusable across pool, supervisor and service tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional


@dataclasses.dataclass
class HostStatus:
    host_id: int
    last_heartbeat: float
    last_step: int


class HeartbeatMonitor:
    """Tracks per-host liveness; hosts missing > timeout are declared dead."""

    def __init__(self, num_hosts: int, timeout_s: float = 60.0, clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self.hosts: Dict[int, HostStatus] = {
            h: HostStatus(h, now, -1) for h in range(num_hosts)
        }

    def beat(self, host_id: int, step: int) -> None:
        st = self.hosts[host_id]
        st.last_heartbeat = self.clock()
        st.last_step = max(st.last_step, step)

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items() if now - st.last_heartbeat > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_hosts()

    def quorum_step(self) -> int:
        """Highest step every live host has definitely passed."""
        live = [st.last_step for h, st in self.hosts.items() if h not in self.dead_hosts()]
        return min(live) if live else -1


class DeviceLossError(RuntimeError):
    """An accelerator (or a host's worth of them) dropped out mid-rollout.

    Raised by the supervisor's step path when a scripted device-loss fault
    fires (on real hardware the analogous signal is the XLA runtime error);
    the handler is `RolloutSupervisor.recover()` — propose a smaller mesh,
    rebuild the pool, restore the last snapshot.
    """

    def __init__(self, n_lost: int = 1, message: Optional[str] = None):
        self.n_lost = n_lost
        super().__init__(message or f"lost {n_lost} device(s) mid-rollout")


@dataclasses.dataclass
class Fault:
    """One scripted failure: fires once when the clock passes `at`."""

    at: float
    kind: str          # "device_loss" | "host_death" | "stall" | "preempt_save"
    arg: Any = None    # kind-specific payload (n devices, host id, sid, ...)
    fired: bool = False


class FaultInjector:
    """A scripted schedule of faults on an injectable (usually simulated)
    clock. Consumers poll `due()` — each fault is delivered exactly once,
    in schedule order — and apply their own semantics (module docstring).

    >>> clk = [0.0]
    >>> inj = FaultInjector(clock=lambda: clk[0])
    >>> inj.schedule(5.0, "device_loss", 1)
    >>> inj.due()            # nothing yet
    []
    >>> clk[0] = 6.0
    >>> [f.kind for f in inj.due()]
    ['device_loss']
    """

    def __init__(self, faults: Iterable[Fault] = (),
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.faults: List[Fault] = sorted(faults, key=lambda f: f.at)

    def schedule(self, at: float, kind: str, arg: Any = None) -> Fault:
        f = Fault(at, kind, arg)
        self.faults.append(f)
        self.faults.sort(key=lambda x: x.at)
        return f

    def due(self, kinds: Optional[Iterable[str]] = None) -> List[Fault]:
        """Unfired faults whose time has come (marking them fired)."""
        now = self.clock()
        kindset = set(kinds) if kinds is not None else None
        out = []
        for f in self.faults:
            if f.fired or f.at > now:
                continue
            if kindset is not None and f.kind not in kindset:
                continue
            f.fired = True
            out.append(f)
        return out

    def fired(self) -> List[Fault]:
        return [f for f in self.faults if f.fired]

    def pending(self) -> List[Fault]:
        return [f for f in self.faults if not f.fired]


@dataclasses.dataclass
class RecoveryPlan:
    restart_step: int
    surviving_hosts: List[int]
    new_device_count: int
    mesh_shape: tuple
    notes: str


def plan_recovery(monitor: HeartbeatMonitor, devices_per_host: int,
                  checkpoint_step: Optional[int]) -> RecoveryPlan:
    """Derive the restart plan after failures: surviving mesh + restore step."""
    from repro.runtime.elastic import propose_mesh

    dead = set(monitor.dead_hosts())
    surviving = [h for h in monitor.hosts if h not in dead]
    n_dev = len(surviving) * devices_per_host
    shape, axes = propose_mesh(n_dev)
    restart = checkpoint_step if checkpoint_step is not None else 0
    return RecoveryPlan(
        restart_step=restart,
        surviving_hosts=surviving,
        new_device_count=n_dev,
        mesh_shape=shape,
        notes=f"lost hosts {sorted(dead)}; remesh to {shape} {axes}; "
              f"data stream resumes at step {restart} (deterministic pipeline)",
    )
