"""Elastic scaling: re-mesh to whatever devices survive.

Checkpoints are mesh-agnostic (checkpoint/manager.py stores gathered
arrays), and the sharding rules are pure functions of (pytree, mesh) — so
scaling from 512 → 384 → 256 chips is: propose a mesh, rebuild shardings,
restore. The data pipeline slices by (step, host) so the stream is exact.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh


def propose_mesh(n_devices: int, prefer_model: int = 16) -> Tuple[tuple, tuple]:
    """Largest (data, model) grid for n_devices; model axis capped/preferred.

    Keeps the model axis a power-of-two ≤ prefer_model that divides
    n_devices so TP sharding stays valid; leftover becomes data parallel.
    """
    if n_devices <= 0:
        raise ValueError("no devices")
    model = 1
    m = prefer_model
    while m > 1:
        if n_devices % m == 0:
            model = m
            break
        m //= 2
    data = n_devices // model
    return (data, model), ("data", "model")


def build_mesh(n_devices: int | None = None, prefer_model: int = 16) -> Mesh:
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    shape, axes = propose_mesh(n, prefer_model)
    return jax.make_mesh(shape, axes, devices=devs[:n])


def reshard_state(state, mesh: Mesh):
    """Re-place a (restored) state pytree onto a new mesh's shardings."""
    from repro.sharding.rules import param_shardings

    sh = param_shardings(state, mesh)
    return jax.tree.map(jax.device_put, state, sh)
