"""rl subsystem."""
