"""Device-resident ring replay buffer (pytree state, fully jittable).

The paper's DQN uses a 50 000-transition memory (Table I). Keeping it on
device means the sample→learn path never leaves the accelerator — the same
"stay in one memory space" principle as the renderer (§II-B).

Contract the fused trainer leans on (repro.train.fused): the ring is a
pure function of the transition STREAM, not of how the stream is chunked
into `replay_add_batch` calls — any regrouping of the same transitions
yields an identical ReplayState, so chunk boundaries in the donated train
scan can never lose or duplicate a transition. Pinned as a property in
tests/test_train_fused.py (`check_replay_chunking`) with hypothesis
drivers in tests/test_train_property.py.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ReplayState(NamedTuple):
    obs: jax.Array        # (cap, *obs_shape)
    action: jax.Array     # (cap, *act_shape)
    reward: jax.Array     # (cap,)
    next_obs: jax.Array   # (cap, *obs_shape)
    done: jax.Array       # (cap,)
    ptr: jax.Array        # ()
    size: jax.Array       # ()


def replay_init(capacity: int, obs_shape: Tuple[int, ...], act_shape: Tuple[int, ...] = (),
                act_dtype=jnp.int32) -> ReplayState:
    return ReplayState(
        obs=jnp.zeros((capacity,) + obs_shape, jnp.float32),
        action=jnp.zeros((capacity,) + act_shape, act_dtype),
        reward=jnp.zeros((capacity,), jnp.float32),
        next_obs=jnp.zeros((capacity,) + obs_shape, jnp.float32),
        done=jnp.zeros((capacity,), jnp.float32),
        ptr=jnp.asarray(0, jnp.int32),
        size=jnp.asarray(0, jnp.int32),
    )


def replay_add_batch(state: ReplayState, obs, action, reward, next_obs, done) -> ReplayState:
    """Insert a batch of B transitions at the ring pointer (wrapping).

    When B > capacity the ring lap would make `.at[idx].set` write the same
    slot from several batch elements, and XLA scatter order for duplicate
    indices is unspecified — so the batch is truncated to its last `cap`
    transitions up front (ring semantics: later writes win; the dropped
    head would have been overwritten within this same call anyway). `ptr`
    still advances by the full B, as if every transition had been written.
    """
    cap = state.obs.shape[0]
    b = obs.shape[0]
    start = state.ptr
    if b > cap:
        drop = b - cap  # static (shape-derived), so plain-Python control flow
        obs, action, reward, next_obs, done = (
            x[drop:] for x in (obs, action, reward, next_obs, done))
        start = state.ptr + drop
    idx = (start + jnp.arange(min(b, cap))) % cap
    return ReplayState(
        obs=state.obs.at[idx].set(obs),
        action=state.action.at[idx].set(action),
        reward=state.reward.at[idx].set(reward.astype(jnp.float32)),
        next_obs=state.next_obs.at[idx].set(next_obs),
        done=state.done.at[idx].set(done.astype(jnp.float32)),
        ptr=(state.ptr + b) % cap,
        size=jnp.minimum(state.size + b, cap),
    )


def replay_sample(state: ReplayState, key: jax.Array, batch: int):
    """Uniform sample of `batch` transitions from the valid region."""
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(state.size, 1))
    return (
        state.obs[idx],
        state.action[idx],
        state.reward[idx],
        state.next_obs[idx],
        state.done[idx],
    )
