"""PPO on vectorised compiled envs — the policy-gradient learner of the toolkit.

Rollout collection scans the XLA-resident EnvPool (repro.pool), so experience
generation is a single device program; the update (GAE + clipped surrogate,
K epochs of minibatches) is a second one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env
from repro.pool import PoolState, make_vec
from repro.rl.networks import mlp_apply, mlp_init
from repro.train.optim import Adam, AdamState


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    num_envs: int = 16
    rollout_len: int = 128
    epochs: int = 4
    minibatches: int = 4
    discount: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    max_grad_norm: float = 0.5
    units: Tuple[int, ...] = (64, 64)
    activation: str = "tanh"
    env_backend: str = "vmap"   # pool step engine; "pallas" = fused megastep


class ACParams(NamedTuple):
    torso: Any
    pi: Any
    vf: Any


def ac_init(key, obs_dim: int, n_actions: int, cfg: PPOConfig) -> ACParams:
    k1, k2, k3 = jax.random.split(key, 3)
    torso = mlp_init(k1, (obs_dim,) + tuple(cfg.units))
    pi = mlp_init(k2, (cfg.units[-1], n_actions))
    vf = mlp_init(k3, (cfg.units[-1], 1))
    return ACParams(torso, pi, vf)


def ac_apply(params: ACParams, obs, activation="tanh"):
    h = mlp_apply(params.torso, obs, activation)
    h = jnp.tanh(h) if activation == "tanh" else jax.nn.elu(h)
    logits = mlp_apply(params.pi, h, activation)
    value = mlp_apply(params.vf, h, activation)[..., 0]
    return logits, value


def _make_pool(env: Env, cfg: PPOConfig):
    """Pool handle on the configured step engine, via the unified `make_vec`
    frontend (see rl/dqn._make_pool): with env_backend="pallas" each
    collected transition is one fused megastep kernel launch instead of a
    chain of small vmap ops."""
    return make_vec(env, cfg.num_envs, backend=cfg.env_backend).xla()


class PPOState(NamedTuple):
    params: ACParams
    opt: AdamState
    pool: PoolState          # XLA-resident env pool carry (state + obs)
    key: jax.Array
    ep_return: jax.Array
    last_return: jax.Array


def ppo_init(env: Env, cfg: PPOConfig, key: jax.Array) -> PPOState:
    key, knet, kenv = jax.random.split(key, 3)
    obs_dim = int(np.prod(env.observation_space.shape))
    params = ac_init(knet, obs_dim, env.action_space.n, cfg)
    pool = _make_pool(env, cfg)
    opt = Adam(lr=cfg.lr, clip_norm=cfg.max_grad_norm).init(params)
    # ep_return/last_return must be distinct buffers: the fused trainer
    # donates the whole carry, and donating one buffer into two slots is a
    # runtime error (repro.train.fused also dedupes defensively).
    return PPOState(params, opt, pool.init(kenv), key,
                    jnp.zeros((cfg.num_envs,), jnp.float32),
                    jnp.zeros((cfg.num_envs,), jnp.float32))


def _gae(rewards, values, dones, last_value, discount, lam):
    def body(carry, xs):
        adv = carry
        r, v, d, v_next = xs
        delta = r + discount * v_next * (1 - d) - v
        adv = delta + discount * lam * (1 - d) * adv
        return adv, adv

    v_next = jnp.concatenate([values[1:], last_value[None]], axis=0)
    _, advs = jax.lax.scan(
        body, jnp.zeros_like(last_value), (rewards, values, dones, v_next), reverse=True
    )
    return advs


def make_update_body(env: Env, cfg: PPOConfig):
    """The pure (un-jitted) PPO update: collect rollout_len steps through
    the pool + K epochs of clipped-surrogate minibatches, as one
    carry → carry function.

    `make_update` wraps it in jit (the host-alternating loop);
    `repro.train.fused` scans it — U updates inside one donated jit — and
    threads the optional `lr` (traced ok) through the optimizer for fleet
    sweeps. lr=None keeps cfg.lr bit-exactly.
    """
    pool = _make_pool(env, cfg)

    def collect(state: PPOState):
        def step_fn(carry, _):
            ps, key, ep_ret, last_ret = carry
            key, k_act, k_env = jax.random.split(key, 3)
            obs = ps.obs
            logits, value = ac_apply(state.params, obs, cfg.activation)
            action = jax.random.categorical(k_act, logits)
            logp = jax.nn.log_softmax(logits)[jnp.arange(cfg.num_envs), action]
            ps, ts = pool.step(ps, action.astype(jnp.int32), k_env)
            # Bootstrap through time-limit cuts: a truncated step's value
            # target is r + γ·V(terminal_obs), not r alone — fold the
            # bootstrap into the stored reward so GAE's (1 - done) masking
            # still cuts the trace at the episode boundary (the next sample
            # belongs to a fresh auto-reset episode). The info structure is
            # static at trace time, so stacks without a TimeLimit skip the
            # extra value forward pass entirely.
            if "truncated" in ts.info:
                trunc = ts.info["truncated"].astype(jnp.float32)
                term_obs = ts.info.get("terminal_obs", ts.obs)
                _, v_term = ac_apply(state.params, term_obs, cfg.activation)
                rew = ts.reward + cfg.discount * trunc * v_term
            else:
                rew = ts.reward
            ep_ret = ep_ret + ts.reward
            last_ret = jnp.where(ts.done, ep_ret, last_ret)
            ep_ret = jnp.where(ts.done, 0.0, ep_ret)
            out = (obs, action, logp, value, rew, ts.done)
            return (ps, key, ep_ret, last_ret), out

        carry = (state.pool, state.key, state.ep_return, state.last_return)
        (ps, key, ep_ret, last_ret), traj = jax.lax.scan(
            step_fn, carry, None, length=cfg.rollout_len
        )
        return (ps, key, ep_ret, last_ret), traj

    def loss_fn(params, batch):
        obs, action, logp_old, adv, ret = batch
        logits, value = ac_apply(params, obs, cfg.activation)
        logp = jax.nn.log_softmax(logits)[jnp.arange(obs.shape[0]), action]
        ratio = jnp.exp(logp - logp_old)
        pg = -jnp.mean(jnp.minimum(
            ratio * adv, jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
        ))
        vf = jnp.mean((value - ret) ** 2)
        probs = jax.nn.softmax(logits)
        ent = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-10), axis=-1))
        return pg + cfg.vf_coef * vf - cfg.ent_coef * ent

    def update_body(state: PPOState, lr=None):
        optimizer = Adam(lr=cfg.lr if lr is None else lr,
                         clip_norm=cfg.max_grad_norm)
        (ps, key, ep_ret, last_ret), traj = collect(state)
        t_obs, t_act, t_logp, t_val, t_rew, t_done = traj
        _, last_value = ac_apply(state.params, ps.obs, cfg.activation)
        adv = _gae(t_rew, t_val, t_done.astype(jnp.float32), last_value, cfg.discount, cfg.gae_lambda)
        ret = adv + t_val
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        n = cfg.rollout_len * cfg.num_envs
        flat = lambda x: x.reshape((n,) + x.shape[2:])
        data = (flat(t_obs), flat(t_act), flat(t_logp), flat(adv), flat(ret))

        def epoch(carry, _):
            params, opt, key = carry
            key, kperm = jax.random.split(key)
            perm = jax.random.permutation(kperm, n)
            shuffled = tuple(x[perm] for x in data)
            mb = n // cfg.minibatches

            def mb_step(carry, i):
                params, opt = carry
                batch = tuple(jax.lax.dynamic_slice_in_dim(x, i * mb, mb) for x in shuffled)
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                params, opt = optimizer.update(grads, opt, params)
                return (params, opt), loss

            (params, opt), losses = jax.lax.scan(mb_step, (params, opt), jnp.arange(cfg.minibatches))
            return (params, opt, key), losses.mean()

        (params, opt, key), losses = jax.lax.scan(
            epoch, (state.params, state.opt, key), None, length=cfg.epochs
        )
        new_state = PPOState(params, opt, ps, key, ep_ret, last_ret)
        return new_state, {"loss": losses.mean(), "return": last_ret.mean()}

    return update_body


def make_update(env: Env, cfg: PPOConfig):
    return jax.jit(make_update_body(env, cfg))


def train(env: Env, cfg: PPOConfig, updates: int, key: jax.Array,
          fused: bool = False, chunk: int = 0):
    """PPO training. Returns (state, metrics dict of (updates,)).

    fused=True scans the update body through `repro.train.fused.run_fused`
    — U updates inside one donated jit per chunk instead of U host
    dispatches; the key chain rides the carry, so the trajectory matches
    the host-alternating loop (float rounding only: one program gives XLA
    different fusion freedom than U identical ones —
    tests/test_train_fused.py bounds it by the standard parity contract).
    """
    state = ppo_init(env, cfg, key)
    if fused:
        from repro.train.fused import run_fused

        body = make_update_body(env, cfg)
        return run_fused(lambda s, _: body(s), state, updates, chunk)
    update = make_update(env, cfg)
    history = []
    for _ in range(updates):
        state, metrics = update(state)
        history.append(metrics)
    metrics = jax.tree.map(lambda *xs: jnp.stack(xs), *history)
    return state, metrics
