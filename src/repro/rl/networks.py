"""Pure-JAX policy/value networks (init/apply pairs, pytree params)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

Activation = {
    "elu": jax.nn.elu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        fan_in = sizes[i]
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), dtype) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((sizes[i + 1],), dtype)
        params.append({"w": w, "b": b})
    return params


def mlp_apply(params, x, activation: str = "elu"):
    act = Activation[activation]
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = act(x)
    return x


def cnn_init(key, in_hw: Tuple[int, int], channels=(16, 32), dense=256, out=2, dtype=jnp.float32):
    """Nature-DQN-lite conv net for (H, W) grayscale frames."""
    h, w = in_hw
    specs = [  # (kh, kw, stride)
        (8, 8, 4),
        (4, 4, 2),
    ]
    params = {"convs": [], "dense": None, "out": None}
    cin = 1
    for (kh, kw, s), cout in zip(specs, channels):
        key, sub = jax.random.split(key)
        fan_in = kh * kw * cin
        params["convs"].append({
            "w": jax.random.normal(sub, (kh, kw, cin, cout), dtype) * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((cout,), dtype),
            "stride": s,
        })
        h = (h - kh) // s + 1
        w = (w - kw) // s + 1
        cin = cout
    flat = h * w * cin
    key, k1, k2 = jax.random.split(key, 3)
    params["dense"] = {
        "w": jax.random.normal(k1, (flat, dense), dtype) * jnp.sqrt(2.0 / flat),
        "b": jnp.zeros((dense,), dtype),
    }
    params["out"] = {
        "w": jax.random.normal(k2, (dense, out), dtype) * jnp.sqrt(2.0 / dense),
        "b": jnp.zeros((out,), dtype),
    }
    return params


def cnn_apply(params, x, activation: str = "elu"):
    """x: (..., H, W) grayscale in [0,1] -> (..., out)."""
    act = Activation[activation]
    batch_shape = x.shape[:-2]
    x = x.reshape((-1,) + x.shape[-2:])[..., None]  # (B, H, W, 1)
    for conv in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x, conv["w"], (conv["stride"], conv["stride"]), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + conv["b"]
        x = act(x)
    x = x.reshape(x.shape[0], -1)
    x = act(x @ params["dense"]["w"] + params["dense"]["b"])
    x = x @ params["out"]["w"] + params["out"]["b"]
    return x.reshape(batch_shape + (x.shape[-1],))
