"""Pure-JAX policy/value networks (init/apply pairs, pytree params).

Both init and apply must stay pure and shape-static: the fleet trainer
(repro.train.fused.fleet) vmaps the ENTIRE training loop — `*_init`
included, over a traced-key axis — so a fleet of F experiments owns one
(F, ...)-batched params pytree. Anything host-dependent here (python
randomness, data-dependent shapes) would break that batching.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

Activation = {
    "elu": jax.nn.elu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}

CONV_SPECS = [  # (kernel_h, kernel_w, stride) per conv layer
    (8, 8, 4),
    (4, 4, 2),
]


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        fan_in = sizes[i]
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]), dtype) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((sizes[i + 1],), dtype)
        params.append({"w": w, "b": b})
    return params


def mlp_apply(params, x, activation: str = "elu"):
    act = Activation[activation]
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = act(x)
    return x


def cnn_init(key, in_shape: Tuple[int, ...], channels=(16, 32), dense=256, out=2, dtype=jnp.float32):
    """Nature-DQN-lite conv net.

    in_shape: (H, W) single grayscale frames, or (N, H, W) for stacked
    frames (core.wrappers.FrameStack) — the stack axis becomes the N input
    channels, the classic Atari-DQN pipeline.
    """
    if len(in_shape) == 2:
        cin, (h, w) = 1, in_shape
    elif len(in_shape) == 3:
        cin, h, w = in_shape
        if cin == 1:
            # cnn_apply infers the layout from the conv fan-in, and cin == 1
            # is indistinguishable from unstacked (H, W) frames at apply
            # time — a 1-frame stack would silently fold into the batch.
            raise ValueError("1-frame stacks are ambiguous: use in_shape="
                             "(H, W) (drop the FrameStack) or >= 2 frames")
    else:
        raise ValueError(f"cnn obs must be (H, W) or (N, H, W); got {in_shape}")
    params = {"convs": [], "dense": None, "out": None}
    for (kh, kw, s), cout in zip(CONV_SPECS, channels):
        key, sub = jax.random.split(key)
        fan_in = kh * kw * cin
        # Strides stay in the static CONV_SPECS table, NOT in the params
        # pytree: a non-array leaf would be traced when the params ride a
        # scan carry (train_compiled) and conv strides must be static.
        params["convs"].append({
            "w": jax.random.normal(sub, (kh, kw, cin, cout), dtype) * jnp.sqrt(2.0 / fan_in),
            "b": jnp.zeros((cout,), dtype),
        })
        h = (h - kh) // s + 1
        w = (w - kw) // s + 1
        cin = cout
    flat = h * w * cin
    key, k1, k2 = jax.random.split(key, 3)
    params["dense"] = {
        "w": jax.random.normal(k1, (flat, dense), dtype) * jnp.sqrt(2.0 / flat),
        "b": jnp.zeros((dense,), dtype),
    }
    params["out"] = {
        "w": jax.random.normal(k2, (dense, out), dtype) * jnp.sqrt(2.0 / dense),
        "b": jnp.zeros((out,), dtype),
    }
    return params


def cnn_apply(params, x, activation: str = "elu"):
    """x: (..., H, W) grayscale or (..., N, H, W) stacked frames -> (..., out).

    The input layout is recovered from the first conv's fan-in: cin == 1
    means plain (H, W) frames, cin > 1 means an N-frame stack whose leading
    axis maps to input channels.
    """
    act = Activation[activation]
    cin = params["convs"][0]["w"].shape[2]
    nd = 2 if cin == 1 else 3
    batch_shape = x.shape[:-nd]
    if cin == 1:
        x = x.reshape((-1,) + x.shape[-2:])[..., None]        # (B, H, W, 1)
    else:
        x = x.reshape((-1,) + x.shape[-3:])
        x = jnp.moveaxis(x, 1, -1)                            # (B, H, W, N)
    for conv, (_, _, s) in zip(params["convs"], CONV_SPECS):
        x = jax.lax.conv_general_dilated(
            x, conv["w"], (s, s), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + conv["b"]
        x = act(x)
    x = x.reshape(x.shape[0], -1)
    x = act(x @ params["dense"]["w"] + params["dense"]["b"])
    x = x @ params["out"]["w"] + params["out"]["b"]
    return x.reshape(batch_shape + (x.shape[-1],))
