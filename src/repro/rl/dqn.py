"""DQN — the paper's evaluation algorithm (§V-B/§V-C, hyperparams Table I).

Two execution modes, matching the paper's comparison axis:
  - `train_compiled`: everything (env stepping via the XLA-resident EnvPool,
    replay, learning) inside one `lax.scan` device program — the CaiRL
    execution model.
  - `train_host`: identical learner, but the environment is an interpreted
    host object stepped one transition at a time — the AI-Gym execution
    model. Fig. 2 compares the wall-clock of the two.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env
from repro.pool import PoolState, make_vec
from repro.rl.networks import cnn_apply, cnn_init, mlp_apply, mlp_init
from repro.rl.replay import ReplayState, replay_add_batch, replay_init, replay_sample
from repro.train.optim import Adam, AdamState, huber_loss, linear_schedule


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    """Defaults = paper Table I."""

    discount: float = 0.99
    units: Tuple[int, ...] = (32, 32)
    activation: str = "elu"
    batch_size: int = 32
    lr: float = 3e-4
    target_update_freq: int = 150
    memory_size: int = 50_000
    exploration_start: float = 1.0
    exploration_final: float = 0.01
    exploration_steps: int = 5_000
    network: str = "mlp"           # "mlp" (memory obs) | "cnn" (pixel obs)
    num_envs: int = 1
    learn_start: int = 100
    env_backend: str = "vmap"      # pool step engine; "pallas" = fused megastep


class DQNState(NamedTuple):
    params: Any
    target: Any
    opt: AdamState
    replay: ReplayState
    pool: PoolState          # XLA-resident env pool carry (state + obs)
    key: jax.Array
    step: jax.Array
    ep_return: jax.Array     # (B,) running episodic return
    last_return: jax.Array   # (B,) most recent completed return


def _build_net(env: Env, cfg: DQNConfig, key):
    n_actions = env.action_space.n
    obs_shape = env.observation_space.shape
    if cfg.network == "cnn":
        params = cnn_init(key, obs_shape, out=n_actions)
        apply_fn = lambda p, x: cnn_apply(p, x, cfg.activation)
    else:
        sizes = (int(np.prod(obs_shape)),) + tuple(cfg.units) + (n_actions,)
        params = mlp_init(key, sizes)
        apply_fn = lambda p, x: mlp_apply(p, x.reshape(x.shape[: -len(obs_shape)] + (-1,)), cfg.activation)
    return params, apply_fn


def _make_pool(env: Env, cfg: DQNConfig):
    """The pool's pure xla() handle on the configured step engine.

    Built through the unified `make_vec` frontend. env_backend="pallas"
    routes every env transition through the fused megastep kernel (one
    launch per train step) instead of the chain of small vmap ops;
    trajectories — and therefore training — match "vmap" up to float
    rounding (tests/test_envstep_fused.py).
    """
    return make_vec(env, cfg.num_envs, backend=cfg.env_backend).xla()


def dqn_init(env: Env, cfg: DQNConfig, key: jax.Array) -> Tuple[DQNState, Callable]:
    key, knet, kenv = jax.random.split(key, 3)
    params, apply_fn = _build_net(env, cfg, knet)
    pool = _make_pool(env, cfg)
    opt = Adam(lr=cfg.lr).init(params)
    replay = replay_init(cfg.memory_size, env.observation_space.shape)
    state = DQNState(
        params=params, target=jax.tree.map(jnp.copy, params), opt=opt, replay=replay,
        pool=pool.init(kenv), key=key, step=jnp.asarray(0, jnp.int32),
        ep_return=jnp.zeros((cfg.num_envs,), jnp.float32),
        last_return=jnp.zeros((cfg.num_envs,), jnp.float32),
    )
    return state, apply_fn


def _epsilon(cfg: DQNConfig, step):
    return linear_schedule(cfg.exploration_start, cfg.exploration_final, cfg.exploration_steps)(step)


def _td_loss(apply_fn, params, target, batch, discount):
    """`terminal` is the stored env-termination flag — NOT folded `done`.

    A time-limit truncation is not a terminal state, so its transition is
    stored with terminal=0 and the target keeps bootstrapping from
    q(next_obs) (= q(terminal_obs), the pre-reset observation). Folding
    truncation into this flag zeroes the bootstrap at every time-limit cut
    and biases the values of any env that mostly ends by limit
    (Pendulum-v1, MountainCar-v0).
    """
    obs, action, reward, next_obs, terminal = batch
    q = apply_fn(params, obs)
    q_sa = jnp.take_along_axis(q, action[:, None], axis=-1)[:, 0]
    q_next = jnp.max(apply_fn(target, next_obs), axis=-1)
    tgt = reward + discount * (1.0 - terminal) * jax.lax.stop_gradient(q_next)
    return jnp.mean(huber_loss(q_sa, tgt))


def make_learn_step(apply_fn, cfg: DQNConfig):
    """The jitted learner update shared by both execution modes.

    `lr` (optional; traced values welcome) overrides cfg.lr at call time —
    the fleet trainer (repro.train.fused) vmaps one training loop over a
    whole learning-rate axis through this hook. float32(cfg.lr) and the
    python-float default produce bit-identical updates, so threading it
    does not perturb the solo path.
    """

    def learn(params, target, opt, batch, lr=None):
        optimizer = Adam(lr=cfg.lr if lr is None else lr)
        loss, grads = jax.value_and_grad(
            lambda p: _td_loss(apply_fn, p, target, batch, cfg.discount)
        )(params)
        params, opt = optimizer.update(grads, opt, params)
        return params, opt, loss

    return learn


def make_train_step(env: Env, apply_fn, cfg: DQNConfig):
    """One environment-interaction + learn step; scanned by train_compiled.

    The optional `lr` kwarg flows through to the learner (see
    make_learn_step) so `repro.train.fused.fleet` can thread a per-row
    learning rate through the otherwise-identical scan body.
    """
    pool = _make_pool(env, cfg)
    learn = make_learn_step(apply_fn, cfg)

    def step_fn(state: DQNState, _, lr=None):
        key, k_eps, k_act, k_env, k_sample = jax.random.split(state.key, 5)
        eps = _epsilon(cfg, state.step)
        obs = state.pool.obs
        q = apply_fn(state.params, obs)
        greedy = jnp.argmax(q, axis=-1).astype(jnp.int32)
        randa = jax.random.randint(k_act, (cfg.num_envs,), 0, env.action_space.n)
        explore = jax.random.uniform(k_eps, (cfg.num_envs,)) < eps
        action = jnp.where(explore, randa, greedy)

        new_pool, ts = pool.step(state.pool, action, k_env)
        terminal_obs = ts.info.get("terminal_obs", ts.obs)
        # Store the *termination* flag, not the folded done: truncated
        # episodes (info["truncated"], core/wrappers.TimeLimit) still
        # bootstrap through terminal_obs in _td_loss.
        truncated = ts.info.get("truncated", jnp.zeros_like(ts.done))
        terminal = ts.done & ~truncated
        replay = replay_add_batch(state.replay, obs, action, ts.reward, terminal_obs, terminal)

        # learn (skipped while the buffer warms up)
        batch = replay_sample(replay, k_sample, cfg.batch_size)
        can_learn = replay.size >= cfg.learn_start
        new_params, new_opt, loss = learn(state.params, state.target,
                                          state.opt, batch, lr=lr)
        params = jax.tree.map(lambda n, o: jnp.where(can_learn, n, o), new_params, state.params)
        opt = jax.tree.map(lambda n, o: jnp.where(can_learn, n, o), new_opt, state.opt)

        # periodic hard target sync (Table I: every 150 steps)
        sync = (state.step % cfg.target_update_freq) == 0
        target = jax.tree.map(lambda t, p: jnp.where(sync, p, t), state.target, params)

        ep_return = state.ep_return + ts.reward
        last_return = jnp.where(ts.done, ep_return, state.last_return)
        ep_return = jnp.where(ts.done, 0.0, ep_return)

        new_state = DQNState(params, target, opt, replay, new_pool, key,
                             state.step + 1, ep_return, last_return)
        metrics = {"loss": loss, "eps": eps, "return": jnp.mean(last_return)}
        return new_state, metrics

    return step_fn


def train_compiled(env: Env, cfg: DQNConfig, steps: int, key: jax.Array,
                   chunk: int = 0, fused: bool = False):
    """Full DQN training as compiled scan(s).

    Returns (state, apply_fn, metrics dict of (T,)).

    fused=True dispatches the SAME scan body through
    `repro.train.fused.run_fused`: one donated jit per chunk, so the carry
    (replay ring, optimizer state, pool state, key chain) is updated in
    place on device instead of being re-materialized per dispatch.
    Trajectories are bit-identical to fused=False — the RNG chain lives in
    the carry either way, so neither `fused` nor `chunk` can shift it
    (tests/test_train_fused.py pins both against committed goldens).
    """
    state, apply_fn = dqn_init(env, cfg, key)
    step_fn = make_train_step(env, apply_fn, cfg)
    if fused:
        from repro.train.fused import run_fused

        state, metrics = run_fused(step_fn, state, steps, chunk)
        return state, apply_fn, metrics
    chunk = min(chunk or steps, steps)

    @functools.partial(jax.jit, static_argnums=(1,))
    def run_chunk(state, n):
        return jax.lax.scan(step_fn, state, None, length=n)

    all_metrics = []
    done = 0
    while done < steps:  # full chunks + one remainder chunk — exactly `steps`
        n = min(chunk, steps - done)
        state, metrics = run_chunk(state, n)
        all_metrics.append(metrics)
        done += n
    metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs), *all_metrics)
    return state, apply_fn, metrics


def train_host(make_env_host, env_spec_env: Env, cfg: DQNConfig, steps: int, key: jax.Array,
               seed: int = 0):
    """Same learner, interpreted host env (the AI-Gym execution model)."""
    host_env = make_env_host()
    host_env.seed(seed)
    key, knet = jax.random.split(key)
    params, apply_fn = _build_net(env_spec_env, cfg, knet)
    target = jax.tree.map(jnp.copy, params)
    opt = Adam(lr=cfg.lr).init(params)
    replay = replay_init(cfg.memory_size, env_spec_env.observation_space.shape)
    learn = jax.jit(make_learn_step(apply_fn, cfg))
    add = jax.jit(replay_add_batch)
    sample = jax.jit(replay_sample, static_argnums=2)
    act_jit = jax.jit(lambda p, o: jnp.argmax(apply_fn(p, o[None]), axis=-1)[0])

    rng = np.random.default_rng(seed)
    obs = np.asarray(host_env.reset(), np.float32)
    returns, ep_ret = [], 0.0
    for step in range(steps):
        eps = float(_epsilon(cfg, jnp.asarray(step)))
        if rng.random() < eps:
            action = host_env.action_space_sample()
        else:
            action = int(act_jit(params, jnp.asarray(obs)))
        next_obs, reward, done, info = host_env.step(action)
        next_obs = np.asarray(next_obs, np.float32)
        # Same termination/truncation split as the compiled path: the stored
        # flag blocks bootstrapping only at env-terminal states, so both
        # execution modes learn from identical TD targets.
        terminal = done and not info.get("truncated", False)
        replay = add(replay, jnp.asarray(obs)[None], jnp.asarray([action], jnp.int32),
                     jnp.asarray([reward], jnp.float32), jnp.asarray(next_obs)[None],
                     jnp.asarray([terminal], jnp.float32))
        ep_ret += reward
        if done:
            returns.append(ep_ret)
            ep_ret = 0.0
            next_obs = np.asarray(host_env.reset(), np.float32)
        obs = next_obs
        if int(replay.size) >= cfg.learn_start:
            key, k_s = jax.random.split(key)
            batch = sample(replay, k_s, cfg.batch_size)
            params, opt, _ = learn(params, target, opt, batch)
        if step % cfg.target_update_freq == 0:
            target = jax.tree.map(jnp.copy, params)
    return params, returns


def greedy_returns(env: Env, apply_fn, params, key: jax.Array, episodes: int = 8,
                   max_steps: int = 500) -> jax.Array:
    """Greedy evaluation over a batch of episodes (compiled, via the pool)."""
    pool = make_vec(env, episodes, backend="vmap").xla()

    @jax.jit
    def run(key):
        key, rkey = jax.random.split(key)
        ps = pool.init(rkey)
        finished = jnp.zeros((episodes,), bool)
        rets = jnp.zeros((episodes,), jnp.float32)

        def body(carry, _):
            ps, key, finished, rets = carry
            key, skey = jax.random.split(key)
            action = jnp.argmax(apply_fn(params, ps.obs), axis=-1).astype(jnp.int32)
            ps, ts = pool.step(ps, action, skey)
            rets = rets + ts.reward * (~finished)
            finished = finished | ts.done
            return (ps, key, finished, rets), None

        (_, _, _, rets), _ = jax.lax.scan(body, (ps, key, finished, rets), None, length=max_steps)
        return rets

    return run(key)
