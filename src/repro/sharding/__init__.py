"""sharding subsystem."""
