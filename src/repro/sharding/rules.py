"""Logical-axis sharding rules: parameter/batch/cache PartitionSpecs.

Strategy (MaxText-style TP×FSDP):
  - tensor-parallel axis  = "model": attention heads, MLP hidden, vocab,
    MoE experts.
  - FSDP axis             = "data": the non-TP dim of each weight is sharded
    over data so optimizer+param memory scales down with the data axis
    (ZeRO-3); XLA inserts per-layer all-gathers that overlap with compute.
  - multi-pod axis        = "pod": pure data parallelism — parameters are
    replicated across pods and only gradient all-reduce crosses the
    inter-pod links (the slowest links get the smallest, most compressible
    traffic; see train/compression.py for the int8 path).
  - batch dims shard over ("pod", "data"); the long_500k cells (batch=1)
    shard the KV-cache *sequence* dim over ("pod", "data") instead
    (sequence-parallel cache) and GSPMD turns the softmax reductions into
    cross-shard collectives.

Rules are name/path based so they apply to every architecture's pytree
uniformly; leaves match by their innermost names with stacked scan dims
padded with None on the left.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: Mesh):
    return "data" if "data" in mesh.axis_names else None


def tp_axis(mesh: Mesh):
    return "model" if "model" in mesh.axis_names else None


# Perf knob: shard MoE experts over model ONLY (replicate over data). Trades
# per-device expert param/optimizer memory for zero per-layer FSDP gathers of
# the expert bank — the §Perf collective fix for MoE train cells.
_MOE_EP_ONLY = [False]


def set_moe_ep_only(value: bool) -> None:
    _MOE_EP_ONLY[0] = bool(value)


# (path substring, leaf name) -> spec for the LAST len(spec) dims.
# First match wins; missing leading dims are padded with None.
_RULES = (
    # embeddings / head
    ("", "embed", ("model", "data")),          # (V, d): TP on vocab, FSDP on d
    ("", "lm_head", ("data", "model")),
    # MoE (match before generic w_in/w_out)
    ("moe", "router", (None, None)),
    ("moe", "w_in", ("model", "data", None)),   # (E, d, 2ff): EP + FSDP
    ("moe", "w_out", ("model", None, "data")),
    # attention
    ("", "wq", ("data", "model")),
    ("", "wk", ("data", "model")),
    ("", "wv", ("data", "model")),
    ("", "wo", ("model", "data")),
    # MLA
    ("", "w_dq", ("data", None)),
    ("", "w_uq", (None, "model")),
    ("", "w_dkv", ("data", None)),
    ("", "w_ukv", (None, "model")),
    # dense FFN
    ("", "w_in", ("data", "model")),
    ("", "w_out", ("model", "data")),
    ("", "mlp_in", ("data", "model")),
    ("", "mlp_out", ("model", "data")),
    # ssm cells
    ("cell", "w_x", ("data", "model")),
    ("cell", "w_z", ("data", "model")),
    ("cell", "w_q", (None, "model")),
    ("cell", "w_k", (None, "model")),
    ("cell", "w_g", ("data", None)),
    ("cell", "w_down", ("model", "data")),
    ("cell", "conv_w", (None, None)),
    ("cell", "r", (None, None, None)),
    ("cell", "o_scale", ("model",)),
    ("cell", "w", ("data", None)),              # slstm input proj
)


def _spec_for(path: str, name: str, ndim: int, shape, mesh: Mesh) -> P:
    axes_avail = set(mesh.axis_names)
    rules = _RULES
    if _MOE_EP_ONLY[0]:
        rules = (("moe", "w_in", ("model", None, None)),
                 ("moe", "w_out", ("model", None, None))) + _RULES
    for substr, leaf, spec in rules:
        if substr in path and name == leaf and ndim >= len(spec):
            spec = tuple(a if (a in axes_avail) else None for a in spec)
            # drop axes that do not divide the dim evenly
            dims = shape[ndim - len(spec):]
            cleaned = tuple(
                a if (a is not None and dims[i] % mesh.shape[a] == 0) else None
                for i, a in enumerate(spec)
            )
            return P(*((None,) * (ndim - len(cleaned)) + cleaned))
    return P(*((None,) * ndim))  # replicate (norm scales, biases, gates)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: Pytree, mesh: Mesh) -> Pytree:
    def spec(path, leaf):
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        return _spec_for(ps, name, leaf.ndim, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


def opt_specs(opt_state: Pytree, params: Pytree, mesh: Mesh) -> Pytree:
    """Adam mu/nu shard exactly like params; scalars replicate."""
    pspecs = param_specs(params, mesh)

    def match(leaf_spec):
        return leaf_spec

    mu = jax.tree.map(match, pspecs)
    nu = jax.tree.map(match, pspecs)
    from repro.train.optim import AdamState

    return AdamState(step=P(), mu=mu, nu=nu)


# -- batch / activation specs --------------------------------------------------
def batch_specs(mesh: Mesh, batch_example: Pytree, batch_divisible: bool = True) -> Pytree:
    """Shard dim0 (batch) of every array over (pod, data) when divisible."""
    da = data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in da])) if da else 1

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if batch_divisible and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return P(da, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec, batch_example)


def cache_specs(mesh: Mesh, caches: Pytree, batch: int, seq_sharded: bool) -> Pytree:
    """KV caches: (rep, B, H, S, hd) → heads on model; B or S on (pod,data).

    seq_sharded=True is the long_500k mode: batch=1, so the sequence dim of
    attention caches carries the data-parallel axes instead. SSM states have
    no sequence dim and shard heads over model only.
    """
    da = data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in da])) if da else 1
    tp = tp_axis(mesh)
    tp_n = mesh.shape[tp] if tp else 1

    def spec(leaf):
        nd = leaf.ndim
        if nd == 0:
            return P()
        dims = [None] * nd
        # find a heads-like dim to TP-shard: any dim (not 0/batch) divisible by tp_n
        # canonical layouts: (rep,B,H,S,hd) attn | (rep,B,H,K,V) ssm |
        # (rep,B,S,r) mla | (rep,B,W,C) conv
        if nd >= 4:
            # dim2 is heads for attn/ssm caches (<=512) but seq for the MLA
            # latent cache (>=1k) — only TP-shard genuine head dims.
            if tp and leaf.shape[2] % tp_n == 0 and leaf.shape[2] <= 512:
                dims[2] = tp
            if seq_sharded and nd >= 5 and leaf.shape[3] % n == 0 and leaf.shape[3] > 1:
                dims[3] = da
            elif not seq_sharded and leaf.shape[1] % n == 0 and leaf.shape[1] >= n:
                dims[1] = da
        elif nd >= 2:
            if not seq_sharded and leaf.shape[1] % n == 0 and leaf.shape[1] >= n:
                dims[1] = da
        return P(*dims)

    return jax.tree.map(spec, caches)


def shard_hint(x, *axes):
    """with_sharding_constraint that degrades to a no-op off-mesh.

    `axes` entries are mesh-axis names (or tuples of them) per dim; axes not
    present in the ambient mesh, or not dividing the dim, are dropped. This
    is how the model code pins activation layouts (batch on (pod, data),
    heads/hidden on model) so GSPMD never falls into batch-replicated
    layouts — without the model depending on any particular mesh.
    """
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover  # repro: allow[silent-except] jax-version probe (get_abstract_mesh is new); fallback path below handles it
        mesh = None
    if mesh is None or not mesh.axis_names:
        try:  # legacy `with mesh:` context
            from jax._src import mesh as _mesh_lib

            mesh = _mesh_lib.thread_resources.env.physical_mesh
        except Exception:  # pragma: no cover  # repro: allow[silent-except] private-API probe across jax versions; no mesh context = nothing to constrain
            return x
    if mesh is None or not mesh.axis_names or getattr(mesh, "empty", False):
        return x
    names = set(mesh.axis_names)

    def clean(dim, a):
        if a is None:
            return None
        cand = tuple(ax for ax in ((a,) if isinstance(a, str) else a) if ax in names)
        if not cand:
            return None
        size = int(np.prod([mesh.shape[ax] for ax in cand]))
        if size == 0 or dim % size:
            return None
        return cand if len(cand) > 1 else cand[0]

    spec = [clean(x.shape[i], axes[i]) if i < len(axes) else None
            for i in range(x.ndim)]
    return jax.lax.with_sharding_constraint(x, P(*spec))


BATCH_AXES = ("pod", "data")


def to_shardings(specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, specs,
        is_leaf=lambda x: isinstance(x, P),
    )
