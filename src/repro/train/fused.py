"""Fused on-device training (Anakin-style) + multi-seed fleets.

The pool/megastep layers removed the environment from the wall-clock
critical path; this module removes the *learner round-trip*. A train step
(`rl/dqn.make_train_step`, `rl/ppo.make_update_body`) is already a pure
carry → carry function whose env interaction runs through the XLA-resident
pool — so K of them scan into ONE compiled program whose carry (network
params, optimizer state, the replay ring, the pool state and the threefry
key chain) is **donated**: XLA writes each step's new carry into the old
carry's buffers, the 50k-transition replay ring included, and nothing
crosses the host boundary between chunk dispatches
(`analysis/audit.py` lowers this exact program and gates zero
host-transfer ops + full carry donation for the golden ids).

With env_backend="pallas"/"jnp" the env transition inside the scanned body
is the fused megastep kernel (kernels/envstep) — megastep rollout feeding
the learner in the same compiled program, the architecture Jumanji trains
with (PAPERS.md).

Key-chain pinning (the chunk seam): every RNG consumed by a fused chunk is
split from the key *inside the donated carry* — never re-derived host-side
per chunk (the `_rollout_fused` fold_in(key, step) trick would make the
trajectory a function of the chunk size). Consequently `run_fused(chunk=7)`
and `run_fused(chunk=64)` produce bit-identical trajectories, and both
match the undonated host-alternating dispatch loop bit for bit
(tests/test_train_fused.py pins this against committed goldens).

Fleets: because the whole training loop is one pure function of
(initial carry, lr), an entire seeds×lr sweep vmaps into a single compiled
batch — `fleet(env, Fleet(seed, lr), steps)` — whose wall-clock is
sublinear in fleet width (benchmarks/fig2) and whose row f is
bit-identical to the solo run with that row's seed and lr (the Adam update
threads lr as a traced scalar; float32(lr) == the solo path's weak-typed
python float).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.env import Env
from repro.core.registry import make as registry_make

#: the training configurations pinned by committed goldens
#: (tests/golden/train_<algo>_<env>.json), audited by analysis/audit.py and
#: benchmarked by benchmarks/fig2 — "<algo>/<env_id>"
GOLDEN_TRAIN_IDS = ("dqn/CartPole-v1", "dqn/FrozenLake-v0", "ppo/CartPole-v1")

_KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


# -- the fused chunk runner ---------------------------------------------------

def fused_train_chunk(step_fn: Callable) -> Callable:
    """Compile `n` train steps into ONE donated device program.

    `step_fn(carry, _) -> (carry, metrics)` is a scan body (the exact one
    the host-alternating path scans); the returned `run_chunk(carry, n)`
    jits `lax.scan(step_fn, carry, length=n)` with the carry donated, so
    the replay ring / optimizer state / pool state are updated in place
    instead of being re-materialized per dispatch. `n` is static — one
    compile per distinct chunk length, as in `dqn.train_compiled`.

    The input carry is consumed (donated): keep using the *returned* carry.
    """

    @functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
    def run_chunk(carry, n: int):
        return jax.lax.scan(step_fn, carry, None, length=n)

    return run_chunk


def _donate_safe(carry):
    """Copy carry leaves that alias one another. Init paths may
    legitimately reuse one array for several carry slots (ppo_init's
    shared zeros did, until fusion surfaced it), but donation requires
    distinct buffers — `f(donate(a), donate(a))` is a runtime error."""
    seen = set()

    def dedupe(x):
        if isinstance(x, jax.Array) and id(x) in seen:
            return jnp.array(x, copy=True)
        seen.add(id(x))
        return x

    return jax.tree.map(dedupe, carry)


def run_fused(step_fn: Callable, state, steps: int, chunk: int = 0):
    """Drive `steps` train steps through donated fused chunks.

    Full chunks plus one remainder chunk — exactly `steps` steps. The RNG
    chain lives in the carry (see module docstring), so the trajectory is
    invariant to `chunk`; metrics come back stacked (T, ...) like the
    host-alternating loop's.
    """
    chunk = min(chunk or steps, steps)
    run_chunk = fused_train_chunk(step_fn)
    state = _donate_safe(state)
    all_metrics = []
    done = 0
    while done < steps:
        n = min(chunk, steps - done)
        state, metrics = run_chunk(state, n)
        all_metrics.append(metrics)
        done += n
    metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs), *all_metrics)
    return state, metrics


# -- multi-seed / multi-hparam fleets -----------------------------------------

class Fleet(NamedTuple):
    """One row per experiment; arrays aligned on the fleet axis (F,)."""

    seed: jax.Array   # (F,) int32 — PRNGKey(seed[f]) seeds row f end to end
    lr: jax.Array     # (F,) float32 — row f's Adam learning rate

    @property
    def width(self) -> int:
        return self.seed.shape[0]


def fleet_grid(seeds, lrs) -> Fleet:
    """Cartesian product seeds × lrs as aligned Fleet rows (row-major)."""
    s = jnp.asarray(seeds, jnp.int32)
    l = jnp.asarray(lrs, jnp.float32)
    ss, ll = jnp.meshgrid(s, l, indexing="ij")
    return Fleet(ss.reshape(-1), ll.reshape(-1))


def _as_fleet(grid, default_lr: float) -> Fleet:
    """Normalize a grid spec: a Fleet, a {"seeds": .., "lrs": ..} dict
    (cartesian product; lrs defaults to the config's lr), or a seed list."""
    if isinstance(grid, Fleet):
        return Fleet(jnp.asarray(grid.seed, jnp.int32),
                     jnp.asarray(grid.lr, jnp.float32))
    if isinstance(grid, dict):
        unknown = set(grid) - {"seeds", "lrs"}
        if unknown:
            raise TypeError(f"unknown fleet grid keys {sorted(unknown)}; "
                            "expected 'seeds' and/or 'lrs'")
        return fleet_grid(grid.get("seeds", [0]), grid.get("lrs", [default_lr]))
    seeds = jnp.asarray(grid, jnp.int32)
    return Fleet(seeds, jnp.full(seeds.shape, default_lr, jnp.float32))


def _algo_parts(env: Env, algo: str, cfg):
    """(cfg, init_row(seed)->state, step_fn(state, _, lr=)->.. ) per algo."""
    if algo == "dqn":
        from repro.rl import dqn as _dqn

        cfg = cfg or _dqn.DQNConfig()
        _, apply_fn = _dqn._build_net(env, cfg, jax.random.PRNGKey(0))
        step_fn = _dqn.make_train_step(env, apply_fn, cfg)
        init_row = lambda key: _dqn.dqn_init(env, cfg, key)[0]
        return cfg, init_row, step_fn
    if algo == "ppo":
        from repro.rl import ppo as _ppo

        cfg = cfg or _ppo.PPOConfig()
        body = _ppo.make_update_body(env, cfg)
        step_fn = lambda state, _, lr=None: body(state, lr=lr)
        init_row = lambda key: _ppo.ppo_init(env, cfg, key)
        return cfg, init_row, step_fn
    raise ValueError(f"unknown fleet algo {algo!r}; expected 'dqn' or 'ppo'")


def fleet(env: Union[Env, str], grid, steps: int, *, algo: str = "dqn",
          cfg=None, chunk: int = 0):
    """Train a whole seeds×lr fleet as ONE compiled, donated batch.

    `grid` is a `Fleet`, a `{"seeds": [...], "lrs": [...]}` dict (cartesian
    product) or a plain seed list. The entire training loop — init included
    — is vmapped over the fleet axis, so an F-row sweep is one device
    program per chunk rather than F sequential runs; wall-clock is
    sublinear in F (benchmarks/fig2 fleet-scaling rows).

    Determinism contract: row f is bit-identical to the solo
    `train_compiled(env, replace(cfg, lr=lr[f]), steps, PRNGKey(seed[f]))`
    run (tests/test_train_fused.py::test_fleet_rows_match_solo).

    Returns `(states, metrics)` pytrees with a leading (F,) fleet axis;
    DQN metrics are (F, steps), PPO metrics (F, updates).
    """
    if isinstance(env, str):
        env = registry_make(env)
    cfg, init_row, step_fn = _algo_parts(env, algo, cfg)
    fl = _as_fleet(grid, cfg.lr)

    def row_body(carry, _):
        state, lr = carry
        state, metrics = step_fn(state, None, lr=lr)
        return (state, lr), metrics

    @functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(0,))
    def run_chunk(carry, n: int):
        return jax.vmap(lambda c: jax.lax.scan(row_body, c, None, length=n))(
            carry)

    states = jax.vmap(lambda s: init_row(jax.random.PRNGKey(s)))(fl.seed)
    # copy lr into the carry: the chunk donates its whole carry, and the
    # caller's grid.lr must survive the call (states are freshly built here)
    carry = _donate_safe((states, jnp.array(fl.lr, copy=True)))
    chunk = min(chunk or steps, steps)
    all_metrics, done = [], 0
    while done < steps:
        n = min(chunk, steps - done)
        carry, metrics = run_chunk(carry, n)
        all_metrics.append(metrics)
        done += n
    states, _ = carry
    metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                           *all_metrics)
    return states, metrics


# -- golden training configurations (tests / audit / fig2 share these) --------

def golden_train_setup(gid: str):
    """(algo, env_id, cfg, steps) for a committed training-golden id.

    Small but adversarial configs: the DQN ring (96) wraps inside the
    64-step run (128 transitions), learning starts mid-run, epsilon decays
    across it and the target net re-syncs on a non-divisor period — so the
    goldens pin replay wrap-around, the warmup gate, the schedule and the
    sync boundary, not just the happy path.
    """
    if gid not in GOLDEN_TRAIN_IDS:
        raise KeyError(f"unknown golden train id {gid!r}; expected one of "
                       f"{GOLDEN_TRAIN_IDS}")
    algo, env_id = gid.split("/")
    if algo == "dqn":
        from repro.rl.dqn import DQNConfig

        cfg = DQNConfig(num_envs=2, memory_size=96, learn_start=16,
                        batch_size=8, exploration_steps=48,
                        target_update_freq=13)
        return algo, env_id, cfg, 64
    from repro.rl.ppo import PPOConfig

    # 4 updates × 16-step rollouts = 64 env steps per env.
    cfg = PPOConfig(num_envs=4, rollout_len=16, epochs=2, minibatches=2)
    return algo, env_id, cfg, 4


def lower_train_chunk(algo: str, env_id: str, cfg=None, chunk: int = 8):
    """Lower (don't run) the donated fused-train chunk for HLO inspection.

    The audit (`analysis/audit.py` train cells) gates this exact artifact —
    the program `run_fused` dispatches — for zero host-transfer ops and
    full carry donation (replay ring and optimizer state included). Carry
    shapes come from `jax.eval_shape` over the real init path, so nothing
    is allocated. Returns (lowered, abstract_carry).
    """
    env = registry_make(env_id)
    if cfg is None:
        _, _, cfg, _ = golden_train_setup(f"{algo}/{env_id}")
    _, init_row, step_fn = _algo_parts(env, algo, cfg)
    carry = jax.eval_shape(init_row, _KEY_SDS)
    run_chunk = fused_train_chunk(step_fn)
    return run_chunk.lower(carry, chunk), carry
