"""train subsystem.

`repro.train.fleet` / `repro.train.fused` is the fused on-device trainer:
K train steps scanned inside one donated jit, and whole seeds×lr fleets
vmapped into a single compiled batch. Exports resolve lazily (PEP 562) so
`import repro.train` stays cheap (same policy as the `repro` root).
"""

#: public surface (tests/test_api_surface.py)
__all__ = ["Fleet", "GOLDEN_TRAIN_IDS", "fleet", "fleet_grid",
           "fused_train_chunk", "golden_train_setup", "lower_train_chunk",
           "run_fused"]

_LAZY = {name: ("repro.train.fused", name) for name in __all__}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
