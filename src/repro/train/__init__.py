"""train subsystem."""
