"""Train-step factory: loss → grads → optimizer, with the scale knobs.

Knobs (all static; each is a §Perf hillclimb lever):
  - remat        : "none" | "dots" | "full" activation checkpointing
  - accum_steps  : gradient accumulation via lax.scan over microbatches
                   (batch dim reshaped to (A, B/A, ...)); the FSDP/TP
                   collectives happen once per micro-step, the cross-pod
                   gradient all-reduce once per step — the standard
                   compute/comm overlap shape.
  - compress     : int8 error-feedback gradient compression for the
                   cross-pod all-reduce (train/compression.py)

Everything is pure-jit + GSPMD: in_shardings/out_shardings pin params,
optimizer state and batch; XLA inserts and schedules the collectives.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.train.optim import Adam, AdamState, global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    remat: str = "dots"
    accum_steps: int = 1
    compress_pod_grads: bool = False


def make_optimizer(tc: TrainConfig) -> Adam:
    from repro.train.optim import cosine_schedule

    return Adam(
        lr=cosine_schedule(tc.lr, tc.warmup, tc.total_steps),
        weight_decay=tc.weight_decay,
        clip_norm=tc.clip_norm,
    )


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    optimizer = make_optimizer(tc)

    def loss(params, batch):
        return lm.loss_fn(cfg, params, batch, remat=tc.remat)

    def grads_of(params, batch):
        if tc.accum_steps <= 1:
            return jax.value_and_grad(loss)(params, batch)

        a = tc.accum_steps

        def micro(carry, mb):
            acc, total = carry
            l, g = jax.value_and_grad(loss)(params, mb)
            acc = jax.tree.map(jnp.add, acc, g)
            return (acc, total + l), None

        def split(x):
            return x.reshape((a, x.shape[0] // a) + x.shape[1:])

        micro_batches = jax.tree.map(split, batch)
        zeros = jax.tree.map(jnp.zeros_like, params)
        (g, total), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batches)
        inv = 1.0 / a
        return total * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(params, opt_state: AdamState, batch):
        l, grads = grads_of(params, batch)
        if tc.compress_pod_grads:
            from repro.train.compression import compress_decompress

            grads = compress_decompress(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {
            "loss": l,
            "grad_norm": global_norm(grads),
            "lr": optimizer._lr(opt_state.step + 1),
        }
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tc: TrainConfig, key: jax.Array):
    params = lm.init_params(cfg, key)
    opt_state = make_optimizer(tc).init(params)
    return params, opt_state
