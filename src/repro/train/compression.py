"""int8 error-feedback gradient compression (cross-pod all-reduce payload).

At multi-pod scale the inter-pod links are the scarcest bandwidth; gradients
are the only traffic that must cross them (sharding/rules.py replicates
params across pods). Quantising that payload to int8 with error feedback
cuts inter-pod bytes 4× (fp32) / 2× (bf16) with negligible quality impact
(the residual is replayed into the next step, so the quantisation error is
unbiased over time — Seide et al. 2014, Karimireddy et al. 2019).

`compress_decompress` is the in-graph functional form: under GSPMD, inserting
it right before the optimizer means the all-reduce XLA generates for the
cross-pod gradient sum operates on the int8-scaled values' dequantised
output; on clusters with explicit shard_map pipelines, `psum_compressed`
performs the quantised psum explicitly over the named "pod" axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Pytree) -> Pytree:
    """Round-trip int8 quantisation (error NOT fed back — stateless form)."""
    def rt(g):
        q, s = _quantize(g.astype(jnp.float32))
        return _dequantize(q, s).astype(g.dtype)

    return jax.tree.map(rt, grads)


def compress_with_feedback(grads: Pytree, residual: Pytree) -> Tuple[Pytree, Pytree]:
    """Error-feedback form: returns (dequantised grads, new residual)."""
    def rt(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), x - deq

    flat = jax.tree.map(rt, grads, residual)
    out = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return out, res


def psum_compressed(grads: Pytree, axis_name: str) -> Pytree:
    """Explicit quantised psum over a named axis (for shard_map pipelines)."""
    def one(g):
        q, s = _quantize(g.astype(jnp.float32))
        # sum int32 accumulations of int8 payloads; scales averaged
        total = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)
        scale = jax.lax.pmean(s, axis_name)
        return (total.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one, grads)


def residual_init(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
