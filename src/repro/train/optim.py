"""Optimizers, schedules and gradient transforms — pure-JAX (no optax on box).

Shared by the RL learners (DQN/PPO, Table I uses Adam) and the LM trainer
(AdamW + cosine + global-norm clipping). Everything is a pytree-in/pytree-out
pure function so optimizer state shards exactly like parameters (ZeRO-style:
sharding/rules.py assigns optimizer-state PartitionSpecs from the param specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


@dataclasses.dataclass(frozen=True)
class Adam:
    """Adam/AdamW. lr may be a float or a schedule fn step->lr."""

    lr: Any = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params: Pytree) -> AdamState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamState(
            step=jnp.asarray(0, jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads: Pytree, state: AdamState, params: Pytree) -> Tuple[Pytree, AdamState]:
        if self.clip_norm is not None:
            grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1**t)
        nu_hat_scale = 1.0 / (1 - b2**t)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return (p - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step, mu, nu)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Any = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return AdamState(jnp.asarray(0, jnp.int32), None, None)
        return AdamState(jnp.asarray(0, jnp.int32), jax.tree.map(jnp.zeros_like, params), None)

    def update(self, grads, state, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new, AdamState(step, None, None)
        mu = jax.tree.map(lambda m, g: self.momentum * m + g, state.mu, grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, mu)
        return new, AdamState(step, mu, None)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree)


# -- schedules ---------------------------------------------------------------
def linear_schedule(start: float, end: float, steps: int) -> Callable:
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(steps, 1), 0.0, 1.0)
        return start + frac * (end - start)

    return fn


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return fn


# -- losses shared by learners ----------------------------------------------
def huber_loss(pred: jax.Array, target: jax.Array, delta: float = 1.0) -> jax.Array:
    err = pred - target
    abs_err = jnp.abs(err)
    quad = jnp.minimum(abs_err, delta)
    return 0.5 * quad**2 + delta * (abs_err - quad)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., V), integer labels (...). Returns per-position loss."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold
