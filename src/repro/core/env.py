"""Functional environment protocol — CaiRL `Environments` module (paper §III-A.3).

The paper's `Env` interface is `step(action)`, `reset()`, `render()`. CaiRL's
C++ templates resolve environment logic at compile time; the JAX analogue is a
*functional* core: an `Env` object holds only static configuration, and all
dynamics are pure functions of an explicit state pytree, so `jax.jit` stages
the whole environment into the XLA program (and `vmap`/`scan` batch and
amortise it — see core/runner.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.spaces import Space


class Timestep(NamedTuple):
    """One transition. `done` folds terminal+truncation like classic Gym;
    wrappers keep the two distinguishable through `info`: `TimeLimit` sets
    `info["truncated"]` (True only on a time-limit cut of a non-terminal
    state), so learners can bootstrap through truncation (rl/dqn.py,
    rl/ppo.py) while still treating `done` as the episode boundary."""

    state: Any
    obs: jax.Array
    reward: jax.Array
    done: jax.Array
    info: Dict[str, jax.Array]


class Env:
    """Base environment. Subclasses are static config + pure functions.

    Contract (everything traceable):
      reset(key)            -> (state, obs)
      step(state, act, key) -> Timestep
      render(state)         -> (H, W) float32 framebuffer in [0, 1]
    """

    observation_space: Space
    action_space: Space
    #: the declarative `EnvSpec` this env was built from, when it came out of
    #: the registry (`repro.core.registry.make` sets it on the outermost
    #: layer; `registry.spec_of` walks wrapper stacks to find it).
    spec = None

    # -- core API ------------------------------------------------------------
    def reset(self, key: jax.Array) -> Tuple[Any, jax.Array]:
        raise NotImplementedError

    def step(self, state: Any, action: jax.Array, key: jax.Array) -> Timestep:
        raise NotImplementedError

    def render(self, state: Any) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} has no renderer")

    # -- optional fused fast path --------------------------------------------
    def fused_step(self, state: Any, actions: jax.Array,
                   keys: jax.Array = None, num_steps: int = None, *,
                   backend: str = "auto", batch_block: int = 128,
                   active: jax.Array = None):
        """Optional protocol hook: advance a *batched autoreset* state by
        `num_steps` fused env steps in one kernel launch.

        `state` is the env_state `Vec(AutoReset(self))` carries (batched
        (B, ...) leaves); `actions` is a (num_steps, B[, A]) block; `keys`
        is an optional per-step key array (ignored by action-deterministic
        envs). Returns `(new_state, Timestep)` with a leading step axis on
        the Timestep leaves — the stack `lax.scan` of the vmap step would
        produce, bit-compatible with it. `active` is an optional (B,) bool
        lane mask (the async pool's masked chunk step): inactive lanes keep
        their state and key chain and report zero outputs.

        The default implementation delegates to the Pallas megastep
        subsystem (repro.kernels.envstep) when this env has a registered
        fused spec and raises NotImplementedError otherwise; subclasses
        with bespoke fused kernels may override directly. Use
        `supports_fused_step(env)` to probe before calling.
        """
        from repro.kernels.envstep import fused_step as _fused_step

        return _fused_step(self, state, actions, keys=keys,
                           num_steps=num_steps, backend=backend,
                           batch_block=batch_block, active=active)

    # -- metadata ------------------------------------------------------------
    @property
    def name(self) -> str:
        return type(self).__name__

    @property
    def unwrapped(self) -> "Env":
        return self

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}()"


def supports_fused_step(env: Env) -> bool:
    """True if `env.fused_step` will run (overridden, or a megastep spec
    exists for the stack — see repro.kernels.envstep)."""
    if type(env).fused_step is not Env.fused_step:
        return True
    from repro.kernels.envstep import supports

    return supports(env)


def zeros_info() -> Dict[str, jax.Array]:
    """Envs must return a *fixed-structure* info dict so scan carriers match."""
    return {}


def terminal_timestep(env: Env, state, obs) -> Timestep:
    return Timestep(
        state=state,
        obs=obs,
        reward=jnp.asarray(0.0, jnp.float32),
        done=jnp.asarray(True),
        info=zeros_info(),
    )
