"""Functional environment protocol — CaiRL `Environments` module (paper §III-A.3).

The paper's `Env` interface is `step(action)`, `reset()`, `render()`. CaiRL's
C++ templates resolve environment logic at compile time; the JAX analogue is a
*functional* core: an `Env` object holds only static configuration, and all
dynamics are pure functions of an explicit state pytree, so `jax.jit` stages
the whole environment into the XLA program (and `vmap`/`scan` batch and
amortise it — see core/runner.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.spaces import Space


class Timestep(NamedTuple):
    """One transition. `done` folds terminal+truncation like classic Gym."""

    state: Any
    obs: jax.Array
    reward: jax.Array
    done: jax.Array
    info: Dict[str, jax.Array]


class Env:
    """Base environment. Subclasses are static config + pure functions.

    Contract (everything traceable):
      reset(key)            -> (state, obs)
      step(state, act, key) -> Timestep
      render(state)         -> (H, W) float32 framebuffer in [0, 1]
    """

    observation_space: Space
    action_space: Space

    # -- core API ------------------------------------------------------------
    def reset(self, key: jax.Array) -> Tuple[Any, jax.Array]:
        raise NotImplementedError

    def step(self, state: Any, action: jax.Array, key: jax.Array) -> Timestep:
        raise NotImplementedError

    def render(self, state: Any) -> jax.Array:
        raise NotImplementedError(f"{type(self).__name__} has no renderer")

    # -- metadata ------------------------------------------------------------
    @property
    def name(self) -> str:
        return type(self).__name__

    @property
    def unwrapped(self) -> "Env":
        return self

    def __repr__(self) -> str:  # pragma: no cover
        return f"{type(self).__name__}()"


def zeros_info() -> Dict[str, jax.Array]:
    """Envs must return a *fixed-structure* info dict so scan carriers match."""
    return {}


def terminal_timestep(env: Env, state, obs) -> Timestep:
    return Timestep(
        state=state,
        obs=obs,
        reward=jnp.asarray(0.0, jnp.float32),
        done=jnp.asarray(True),
        info=zeros_info(),
    )
