"""CaiRL core: the paper's contribution as a composable JAX module."""
from repro.core.env import Env, Timestep
from repro.core.registry import make, make_compat, register, registered
from repro.core.runner import PythonRunner, Trajectory, episode_return, rollout, rollout_random
from repro.core.spaces import Box, Discrete, MultiDiscrete, Space
from repro.core.wrappers import (
    AutoReset,
    FlattenObs,
    FrameStack,
    ObsToPixels,
    RewardScale,
    TimeLimit,
    Vec,
    Wrapper,
)

__all__ = [
    "Env", "Timestep", "make", "make_compat", "register", "registered",
    "PythonRunner", "Trajectory", "episode_return", "rollout", "rollout_random",
    "Box", "Discrete", "MultiDiscrete", "Space",
    "AutoReset", "FlattenObs", "FrameStack", "ObsToPixels", "RewardScale",
    "TimeLimit", "Vec", "Wrapper",
]
