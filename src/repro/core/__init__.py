"""CaiRL core: the paper's contribution as a composable JAX module."""
from repro.core import pipeline
from repro.core.env import Env, Timestep
from repro.core.pipeline import Transform, build_pipeline, declared_pipeline
from repro.core.registry import (EnvSpec, make, make_compat, register,
                                 register_family, register_spec, registered,
                                 spec, spec_of, specs)
from repro.core.runner import PythonRunner, Trajectory, episode_return, rollout, rollout_random
from repro.core.spaces import Box, Discrete, MultiDiscrete, Space
from repro.core.wrappers import (
    AutoReset,
    FlattenObs,
    FrameStack,
    ObsToPixels,
    RewardScale,
    TimeLimit,
    Vec,
    Wrapper,
)

__all__ = [
    "Env", "EnvSpec", "Timestep", "Transform", "build_pipeline",
    "declared_pipeline", "make", "make_compat", "pipeline", "register",
    "register_family", "register_spec", "registered", "spec", "spec_of",
    "specs",
    "PythonRunner", "Trajectory", "episode_return", "rollout", "rollout_random",
    "Box", "Discrete", "MultiDiscrete", "Space",
    "AutoReset", "FlattenObs", "FrameStack", "ObsToPixels", "RewardScale",
    "TimeLimit", "Vec", "Wrapper",
]
