"""Stateful Gym-API shim — the paper's drop-in compatibility claim (Listing 2).

Wraps the functional core in an object with classic Gym semantics so existing
codebases migrate by swapping `gym.make` for `cairl.make` (repro.cairl.make).
Step/reset/render are jit-compiled once per env type; the interpreter only
pays one dispatch per call — and codebases that adopt the `run()` fast path
(core/runner.py) pay zero.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env


class _SpaceShim:
    """Gym-style stateful `space.sample()`."""

    def __init__(self, space, rng: np.random.Generator):
        self._space = space
        self._rng = rng

    def __getattr__(self, item):
        return getattr(self._space, item)

    def sample(self):
        seed = int(self._rng.integers(0, 2**31 - 1))
        return np.asarray(self._space.sample(jax.random.PRNGKey(seed)))


class GymCompat:
    """`e = cairl.make("CartPole-v1"); e.reset(); e.step(a); e.render()`."""

    def __init__(self, env: Env, seed: int = 0):
        self._env = env
        self._key = jax.random.PRNGKey(seed)
        self._state: Any = None
        self._rng = np.random.default_rng(seed)
        self.observation_space = _SpaceShim(env.observation_space, self._rng)
        self.action_space = _SpaceShim(env.action_space, self._rng)
        # Compile once; all subsequent calls are cached executable dispatches.
        self._reset = jax.jit(env.reset)
        self._step = jax.jit(env.step)
        try:
            self._render = jax.jit(env.render)
        except Exception:  # env without renderer
            self._render = None

    # -- Gym API ---------------------------------------------------------
    def seed(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)
        self._rng = np.random.default_rng(seed)

    def reset(self) -> np.ndarray:
        self._key, sub = jax.random.split(self._key)
        self._state, obs = self._reset(sub)
        return np.asarray(obs)

    def step(self, action):
        if self._state is None:
            raise RuntimeError("call reset() before step()")
        self._key, sub = jax.random.split(self._key)
        ts = self._step(self._state, jnp.asarray(action), sub)
        self._state = ts.state
        return np.asarray(ts.obs), float(ts.reward), bool(ts.done), {}

    def render(self):
        if self._render is None:
            raise NotImplementedError("env has no renderer")
        return np.asarray(self._render(self._state))

    def action_space_sample(self):
        return self.action_space.sample()

    @property
    def unwrapped(self) -> Env:
        return self._env.unwrapped

    def close(self) -> None:
        self._state = None
