"""Stateful Gym-API shim — the paper's drop-in compatibility claim (Listing 2).

Wraps the functional core in an object with classic Gym semantics so existing
codebases migrate by swapping `gym.make` for `cairl.make` (repro.cairl.make).
Step/reset/render are jit-compiled once per env type; the interpreter only
pays one dispatch per call — and codebases that adopt the `run()` fast path
(core/runner.py) pay zero.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env


class _SpaceShim:
    """Gym-style stateful `space.sample()`."""

    def __init__(self, space, rng: np.random.Generator):
        self._space = space
        self._rng = rng

    def __getattr__(self, item):
        # copy/pickle probe dunders (__deepcopy__, __reduce_ex__, ...) before
        # __init__ has populated __dict__; dereferencing self._space here
        # would re-enter __getattr__ forever. Refuse underscore lookups and
        # fetch _space without re-triggering attribute fallback.
        if item.startswith("_"):
            raise AttributeError(item)
        try:
            space = object.__getattribute__(self, "_space")
        except AttributeError:
            raise AttributeError(item) from None
        return getattr(space, item)

    def sample(self):
        seed = int(self._rng.integers(0, 2**31 - 1))
        return np.asarray(self._space.sample(jax.random.PRNGKey(seed)))


class GymCompat:
    """`e = cairl.make("CartPole-v1"); e.reset(); e.step(a); e.render()`.

    `new_step_api=True` switches `step` to the 5-tuple Gym >= 0.26 API
    `(obs, reward, terminated, truncated, info)`, mapping the functional
    core's `info["truncated"]` signal (core/wrappers.TimeLimit); the default
    stays the classic 4-tuple with folded `done`.

    Modern-Gym parity: `.spec` exposes the declarative `EnvSpec` the env
    was built from (None for hand-composed stacks), and `render_mode` is
    accepted/stored for call-site compatibility — rendering is always the
    on-device `render()` -> frame path, whatever the mode says.
    """

    def __init__(self, env: Env, seed: int = 0, new_step_api: bool = False,
                 render_mode: Optional[str] = None):
        self._env = env
        self._key = jax.random.PRNGKey(seed)
        self._state: Any = None
        self.new_step_api = bool(new_step_api)
        self.render_mode = render_mode
        self._rng = np.random.default_rng(seed)
        self.observation_space = _SpaceShim(env.observation_space, self._rng)
        self.action_space = _SpaceShim(env.action_space, self._rng)
        # Compile once; all subsequent calls are cached executable dispatches.
        self._reset = jax.jit(env.reset)
        self._step = jax.jit(env.step)
        try:
            self._render = jax.jit(env.render)
        except Exception:  # repro: allow[silent-except] renderer probe: any failure here just means "no render support", surfaced as render() -> None
            self._render = None

    # -- Gym API ---------------------------------------------------------
    def seed(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)
        self._rng = np.random.default_rng(seed)
        # Drop any in-flight episode: its state was produced by the previous
        # seed's stream, so stepping it after reseeding would silently
        # continue the old episode. Force a fresh reset() instead.
        self._state = None

    def reset(self) -> np.ndarray:
        self._key, sub = jax.random.split(self._key)
        self._state, obs = self._reset(sub)
        return np.asarray(obs)

    def step(self, action):
        if self._state is None:
            raise RuntimeError("call reset() before step()")
        self._key, sub = jax.random.split(self._key)
        ts = self._step(self._state, jnp.asarray(action), sub)
        self._state = ts.state
        obs, reward, done = np.asarray(ts.obs), float(ts.reward), bool(ts.done)
        truncated = bool(np.asarray(ts.info["truncated"])) \
            if "truncated" in ts.info else False
        info = {k: np.asarray(v) for k, v in ts.info.items()
                if k != "truncated"}
        if self.new_step_api:
            return obs, reward, done and not truncated, truncated, info
        return obs, reward, done, info

    def render(self):
        if self._render is None:
            raise NotImplementedError("env has no renderer")
        return np.asarray(self._render(self._state))

    def action_space_sample(self):
        return self.action_space.sample()

    @property
    def spec(self):
        """The declarative `EnvSpec` behind this env (modern `gym.Env.spec`
        parity); None when the wrapped stack was composed by hand."""
        from repro.core.registry import spec_of

        return spec_of(self._env)

    @property
    def unwrapped(self) -> Env:
        return self._env.unwrapped

    def close(self) -> None:
        self._state = None
