"""Declarative env pipelines — wrapper composition as data.

An `EnvSpec` (core/registry.py) describes an environment id as
`core_factory` + a tuple of `Transform`s. A Transform is the *data* of one
wrapper application — `TimeLimit(500)` instead of the built
`TimeLimit(env, 500)` — so the same declaration can be

  - built into the wrapper stack (`build_pipeline`),
  - queried without building anything (`spec.max_steps`, docs generation),
  - and *walked* by the fused megastep engine (kernels/envstep/ops.py):
    each Transform carries its fusion role in `fusion`, so the kernel
    dispatcher reads the declared pipeline instead of reverse-engineering
    wrapper stacks with isinstance heuristics (the old `_peel`).

Built wrapper stacks stay reconstructible: `declared_pipeline(env)` maps a
stack back to `(core, transforms)` — exactly inverse to `build_pipeline` —
via the wrapper↔transform table below. Third-party wrappers opt in by
exposing a `transform` property returning their Transform (or `None` to
mark themselves opaque to fusion).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple, Type

from repro.core import wrappers as _w
from repro.core.env import Env

#: fusion roles the megastep planner understands (kernels/envstep/ops.py)
FUSION_TIME_LIMIT = "time_limit"
FUSION_PIXELS = "pixels"
FUSION_FRAME_STACK = "frame_stack"


@dataclasses.dataclass(frozen=True)
class Transform:
    """One declarative wrapper application. Frozen, hashable, reconstructible.

    Subclasses declare the wrapper class they build and (optionally) the
    fusion role the megastep planner should read; dataclass fields must
    match the wrapper's constructor kwargs so `build` is pure data->code.
    """

    wrapper: ClassVar[Type[_w.Wrapper]]
    fusion: ClassVar[Optional[str]] = None

    def build(self, env: Env) -> Env:
        return self.wrapper(env, **{f.name: getattr(self, f.name)
                                    for f in dataclasses.fields(self)})

    def __repr__(self) -> str:
        args = ", ".join(f"{f.name}={getattr(self, f.name)!r}"
                         for f in dataclasses.fields(self))
        return f"{type(self).__name__}({args})"


@dataclasses.dataclass(frozen=True, repr=False)
class TimeLimit(Transform):
    """Truncate episodes at `max_steps` (wrappers.TimeLimit)."""

    max_steps: int
    wrapper = _w.TimeLimit
    fusion = FUSION_TIME_LIMIT


@dataclasses.dataclass(frozen=True, repr=False)
class ObsToPixels(Transform):
    """Observe the rendered framebuffer (wrappers.ObsToPixels)."""

    wrapper = _w.ObsToPixels
    fusion = FUSION_PIXELS


@dataclasses.dataclass(frozen=True, repr=False)
class FrameStack(Transform):
    """Stack the last `num_frames` observations (wrappers.FrameStack)."""

    num_frames: int = 4
    wrapper = _w.FrameStack
    fusion = FUSION_FRAME_STACK


@dataclasses.dataclass(frozen=True, repr=False)
class FlattenObs(Transform):
    """Flatten observations to a 1-D Box (wrappers.FlattenObs)."""

    wrapper = _w.FlattenObs


@dataclasses.dataclass(frozen=True, repr=False)
class RewardScale(Transform):
    """Scale rewards by a static factor (wrappers.RewardScale)."""

    scale: float
    wrapper = _w.RewardScale


def build_pipeline(env: Env, transforms: Tuple[Transform, ...]) -> Env:
    """Apply transforms innermost-first: `(TimeLimit(500), ObsToPixels(),
    FrameStack(4))` builds `FrameStack(ObsToPixels(TimeLimit(env, 500)), 4)`.
    """
    for t in transforms:
        env = t.build(env)
    return env


#: built wrapper -> its Transform (the reconstructible-from-data contract)
_FROM_WRAPPER = {
    _w.TimeLimit: lambda w: TimeLimit(w.max_steps),
    _w.ObsToPixels: lambda w: ObsToPixels(),
    _w.FrameStack: lambda w: FrameStack(w.num_frames),
    _w.FlattenObs: lambda w: FlattenObs(),
    _w.RewardScale: lambda w: RewardScale(w.scale),
}


def transform_of(wrapper: _w.Wrapper) -> Optional[Transform]:
    """The Transform that rebuilds `wrapper`, or None if it is opaque.

    A wrapper class outside core/wrappers.py participates by exposing a
    `transform` property returning its Transform.
    """
    custom = getattr(wrapper, "transform", None)
    if custom is not None:
        return custom
    fn = _FROM_WRAPPER.get(type(wrapper))
    return fn(wrapper) if fn is not None else None


def declared_pipeline(env: Env):
    """Walk a built stack back to `(core_env, transforms)` (innermost-first).

    Inverse of `build_pipeline` for stacks made of reconstructible wrappers;
    returns `(None, None)` when any wrapper in the stack is opaque (the
    fused planner then treats the whole stack as unfusable). Execution-layer
    wrappers (`AutoReset`, `Vec`) are not pipeline transforms and also mark
    the stack opaque — they are applied by pools, not declared by specs.
    """
    transforms = []
    while isinstance(env, _w.Wrapper):
        t = transform_of(env)
        if t is None:
            return None, None
        transforms.append(t)
        env = env.env
    return env, tuple(reversed(transforms))
