"""Compiled rollout runners — the paper's `run()` fast path (§III-B).

The paper: "The interpreter overhead can be reduced by ... implementing a run
function, notably eliminating the need for interpreted loop code in Python."
On JAX the equivalent is strictly stronger: `lax.scan` compiles the *entire*
N-step × B-env rollout into one device program, so per-step host dispatch is
exactly zero (vs. merely cheaper in C++).

Runners provided (the paper's `Runners` module, §III-A.1, re-interpreted as
execution backends rather than foreign VMs):
  - `rollout`        : policy-driven scan rollout (autoreset inside the scan)
  - `rollout_random` : action_space.sample-driven (Listing 1/2 benchmark loop)
  - `rollout_render` : same, but renders every frame inside the program
  - `PythonRunner`   : host-callback bridge for foreign/interpreted envs —
                       the structural stand-in for the JVM/Flash runners,
                       and the harness for the AI-Gym-style baselines.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.env import Env, Timestep
from repro.core.wrappers import AutoReset, Vec


class Trajectory(NamedTuple):
    obs: jax.Array          # (T, B, ...) observation seen *before* acting
    action: jax.Array       # (T, B, ...)
    reward: jax.Array       # (T, B)
    done: jax.Array         # (T, B)
    next_obs: jax.Array     # (T, B, ...) post-step obs (pre-autoreset terminal obs)


def _batched(env: Env, batch_size: int) -> Env:
    return Vec(AutoReset(env), batch_size)


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4))
def rollout(
    env: Env,
    policy: Callable[[Any, jax.Array, jax.Array], jax.Array],
    policy_params: Any,
    num_steps: int,
    batch_size: int,
    key: jax.Array,
) -> Trajectory:
    """Scan `num_steps` of `batch_size` autoresetting envs under `policy`.

    policy(params, obs, key) -> action, vmapped over the batch internally.
    """
    venv = _batched(env, batch_size)
    key, rkey = jax.random.split(key)
    state, obs = venv.reset(rkey)

    def step_fn(carry, _):
        state, obs, key = carry
        key, akey, skey = jax.random.split(key, 3)
        akeys = jax.random.split(akey, batch_size)
        action = jax.vmap(policy, in_axes=(None, 0, 0))(policy_params, obs, akeys)
        ts = venv.step(state, action, skey)
        terminal_obs = ts.info.get("terminal_obs", ts.obs)
        out = (obs, action, ts.reward, ts.done, terminal_obs)
        return (ts.state, ts.obs, key), out

    (_, _, _), (o, a, r, d, no) = jax.lax.scan(
        step_fn, (state, obs, key), None, length=num_steps
    )
    return Trajectory(o, a, r, d, no)


@functools.partial(jax.jit, static_argnums=(0, 2, 3, 4))
def rollout_random(
    env: Env,
    key: jax.Array,
    num_steps: int,
    batch_size: int = 1,
    render: bool = False,
):
    """The paper's benchmark loop (Listing 1/2): random actions, optional render.

    Returns (sum_reward (B,), episodes (B,), last_frame or None) so the whole
    computation is kept live without materialising trajectories.
    """
    venv = _batched(env, batch_size)
    key, rkey = jax.random.split(key)
    state, obs = venv.reset(rkey)
    frame0 = venv.render(state) if render else jnp.zeros((batch_size,), jnp.float32)

    def step_fn(carry, _):
        state, key, rew, eps, frame = carry
        key, akey, skey = jax.random.split(key, 3)
        action = venv.sample_actions(akey)
        ts = venv.step(state, action, skey)
        frame = venv.render(ts.state) if render else frame
        return (ts.state, key, rew + ts.reward, eps + ts.done.astype(jnp.int32), frame), None

    init = (state, key, jnp.zeros((batch_size,), jnp.float32), jnp.zeros((batch_size,), jnp.int32), frame0)
    (state, _, rew, eps, frame), _ = jax.lax.scan(step_fn, init, None, length=num_steps)
    return rew, eps, frame


@functools.partial(jax.jit, static_argnums=(0, 2, 3, 4))
def rollout_random_fast(
    env: Env,
    key: jax.Array,
    num_steps: int,
    batch_size: int = 1,
    render: bool = False,
):
    """§Perf env-plane fast path: same semantics as rollout_random, less RNG.

    Changes vs. the baseline (hypothesis→measured in EXPERIMENTS.md §Perf):
      1. one `fold_in` per step instead of a 3-way `split` chain (threefry
         is a real cost at classic-control physics sizes);
      2. actions sampled as ONE batched randint/uniform instead of a vmapped
         per-env `space.sample` (B threefry streams → 1);
      3. AutoReset keys derived from the step key (no per-env key carry).
    """
    from repro.core.spaces import sample_batch

    venv = Vec(AutoReset(env), batch_size)
    state, obs = venv.reset(jax.random.fold_in(key, 0x5EED))
    space = env.action_space

    frame0 = venv.render(state) if render else jnp.zeros((batch_size,), jnp.float32)

    def step_fn(carry, i):
        state, rew, eps, frame = carry
        k = jax.random.fold_in(key, i)
        action = sample_batch(space, k, batch_size)
        # repro: allow[key-reuse] same chain as EnvPool._rollout: action-sample and step share the per-step key so runner/pool rollouts stay bit-comparable
        ts = venv.step(state, action, k)
        frame = venv.render(ts.state) if render else frame
        return (ts.state, rew + ts.reward, eps + ts.done.astype(jnp.int32), frame), None

    init = (state, jnp.zeros((batch_size,), jnp.float32),
            jnp.zeros((batch_size,), jnp.int32), frame0)
    (state, rew, eps, frame), _ = jax.lax.scan(step_fn, init, jnp.arange(1, num_steps + 1))
    return rew, eps, frame


class PythonRunner:
    """Host-side runner for interpreted envs (the paper's foreign runtimes).

    Drives any object with Gym semantics (`reset() -> obs`,
    `step(a) -> (obs, r, done, info)`, optional `render()`). Used to run the
    pure-Python baselines under the same harness for Fig. 1/2 comparisons.
    """

    def __init__(self, env_factory: Callable[[], Any]):
        self.env_factory = env_factory

    def run(self, num_steps: int, render: bool = False, seed: int = 0):
        env = self.env_factory()
        env.seed(seed)
        obs = env.reset()
        total_r, episodes = 0.0, 0
        for _ in range(num_steps):
            a = env.action_space_sample()
            obs, r, done, _ = env.step(a)
            if render:
                env.render()
            total_r += r
            if done:
                episodes += 1
                obs = env.reset()
        return total_r, episodes


def episode_return(env: Env, policy, policy_params, key: jax.Array, max_steps: int = 1000):
    """Single-episode evaluation, compiled (while_loop so it exits early)."""

    def body(carry):
        state, obs, key, ret, done, t = carry
        key, akey, skey = jax.random.split(key, 3)
        action = policy(policy_params, obs, akey)
        ts = env.step(state, action, skey)
        ret = ret + ts.reward * (1.0 - done)
        done = jnp.maximum(done, ts.done.astype(jnp.float32))
        return (ts.state, ts.obs, key, ret, done, t + 1)

    def cond(carry):
        *_, done, t = carry
        return (done < 1.0) & (t < max_steps)

    key, rkey = jax.random.split(key)
    state, obs = env.reset(rkey)
    init = (state, obs, key, jnp.asarray(0.0, jnp.float32), jnp.asarray(0.0, jnp.float32), jnp.asarray(0, jnp.int32))
    *_, ret, _, steps = jax.lax.while_loop(cond, body, init)
    return ret, steps
