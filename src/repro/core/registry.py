"""Environment registry — the `cairl.make("CartPole-v1")` entry point.

Paper Listing 2: switching a Gym experiment to CaiRL is a one-line change
(`gym.make` -> `cairl.make`). `make()` returns the *functional* env;
`make_compat()` returns the stateful Gym-API shim (core/gym_compat.py) for
literal drop-in use.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.env import Env

_REGISTRY: Dict[str, Callable[..., Env]] = {}


def register(name: str, factory: Callable[..., Env]) -> None:
    if name in _REGISTRY:
        raise ValueError(f"environment {name!r} already registered")
    _REGISTRY[name] = factory


def registered() -> list:
    _ensure_builtins()  # so `cairl.registered()` is complete before any make()
    return sorted(_REGISTRY)


def make(name: str, **kwargs) -> Env:
    """Build a functional env by registry id (e.g. "CartPole-v1")."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(f"unknown environment {name!r}; known: {registered()}")
    return _REGISTRY[name](**kwargs)


def make_compat(name: str, seed: int = 0, new_step_api: bool = False, **kwargs):
    """Gym drop-in: stateful reset()/step()/render() object (Listing 2).

    `new_step_api=True` returns the Gym >= 0.26 5-tuple
    `(obs, reward, terminated, truncated, info)` from `step`.
    """
    from repro.core.gym_compat import GymCompat

    return GymCompat(make(name, **kwargs), seed=seed, new_step_api=new_step_api)


_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.envs  # noqa: F401  (registers on import)
