"""Environment registry — declarative `EnvSpec` pipelines behind `make()`.

Paper Listing 2: switching a Gym experiment to CaiRL is a one-line change
(`gym.make` -> `cairl.make`). Every registered id is an `EnvSpec`: a core
env factory plus a declarative transform pipeline (core/pipeline.py), so
one entry describes what used to be a hand-built wrapper-stack lambda —
and the same declaration feeds `make()`, the fused megastep planner
(kernels/envstep), the conformance matrix (tests/test_conformance.py) and
the generated docs. `register_family` emits the conventional
`-v<N>`/`-px`/`-raw` id trio from one call.

`make()` returns the *functional* env; `make_compat()` returns the stateful
Gym-API shim (core/gym_compat.py) for literal drop-in use. `spec(id)` is
the queryable metadata API; the built env also carries its spec
(`env.spec`, reachable through wrappers with `spec_of`).

Back-compat: `register(name, factory)` with an opaque zero-to-kwargs
factory still works — it becomes a single-id `EnvSpec` with an empty
declared pipeline (such ids build and run everywhere, but the fused engine
falls back to walking their built wrapper stack).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, FrozenSet, Optional, Tuple

from repro.core import pipeline as P
from repro.core.env import Env


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Declarative recipe for one registry id: core factory + pipeline."""

    id: str
    core_factory: Callable[..., Env]
    transforms: Tuple[P.Transform, ...] = ()
    tags: FrozenSet[str] = frozenset()
    #: default kwargs for `core_factory`, overridable per `make()` call
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    @property
    def max_steps(self) -> Optional[int]:
        """The declared TimeLimit, if any — no building required."""
        for t in self.transforms:
            if isinstance(t, P.TimeLimit):
                return t.max_steps
        return None

    @property
    def pixels(self) -> bool:
        """True when the declared observation is the rendered framebuffer."""
        return any(isinstance(t, P.ObsToPixels) for t in self.transforms)

    def make(self, **kwargs) -> Env:
        merged = dict(self.kwargs)
        merged.update(kwargs)
        _check_kwargs(self.id, self.core_factory, merged)
        try:
            env = self.core_factory(**merged)
        except TypeError as e:
            # Opaque factories (**kw lambdas) dodge the signature check;
            # still name the id and offending kwargs instead of a bare
            # TypeError from deep inside the stack.
            raise TypeError(
                f"cannot build {self.id!r} with kwargs {sorted(merged)}: {e}"
            ) from e
        env = P.build_pipeline(env, self.transforms)
        env.spec = self
        return env

    def __repr__(self) -> str:  # pragma: no cover
        tf = ", ".join(repr(t) for t in self.transforms)
        return f"EnvSpec({self.id!r}, {_factory_name(self.core_factory)}, ({tf}))"


def _factory_name(factory) -> str:
    return getattr(factory, "__name__", repr(factory))


def _check_kwargs(env_id: str, factory, kwargs: Dict[str, Any]) -> None:
    """Reject unknown construction kwargs with a message naming them."""
    if not kwargs:
        return
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):  # builtins / exotic callables: best effort
        return
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return  # **kwargs factories accept anything statically
    accepted = [n for n, p in params.items()
                if p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                              inspect.Parameter.KEYWORD_ONLY)]
    unknown = sorted(set(kwargs) - set(accepted))
    if unknown:
        raise TypeError(
            f"unknown kwargs {unknown} for environment {env_id!r} "
            f"({_factory_name(factory)} accepts: {accepted or 'no kwargs'})")


_REGISTRY: Dict[str, EnvSpec] = {}


def register_spec(spec: EnvSpec) -> EnvSpec:
    if spec.id in _REGISTRY:
        raise ValueError(f"environment {spec.id!r} already registered")
    _REGISTRY[spec.id] = spec
    return spec


def register(name: str, factory: Callable[..., Env], *,
             transforms: Tuple[P.Transform, ...] = (),
             tags: FrozenSet[str] = frozenset()) -> EnvSpec:
    """Register one id. With only `(name, factory)` this is the legacy
    third-party API — the factory may build any wrapper stack itself."""
    return register_spec(EnvSpec(name, factory, tuple(transforms),
                                 frozenset(tags)))


def register_family(name: str, core_factory: Callable[..., Env], *,
                    max_steps: int, version: int = 0, obs: str = "state",
                    pixel_variant: bool = False, num_frames: int = 4,
                    tags=(), kwargs: Dict[str, Any] = None) -> Tuple[EnvSpec, ...]:
    """One entry per family: derive the conventional id trio.

      - `{name}-v{version}`: TimeLimit(max_steps); with `obs="pixels"` the
        arcade pipeline TimeLimit -> ObsToPixels -> FrameStack(num_frames).
      - `{name}-px` (when `pixel_variant`): the pixel pipeline over the
        same core (the gridworld `-px` mode).
      - `{name}-raw`: the bare core env for custom composition (CaiRL's
        `Flatten<TimeLimit<200, CartPoleEnv>>()` template style).
    """
    if obs not in ("state", "pixels"):
        raise ValueError(f"obs must be 'state' or 'pixels', got {obs!r}")
    base = frozenset(tags)
    kw = tuple(sorted((kwargs or {}).items()))
    pixel_tf = (P.TimeLimit(max_steps), P.ObsToPixels(),
                P.FrameStack(num_frames))
    main_tf = pixel_tf if obs == "pixels" else (P.TimeLimit(max_steps),)
    main_tags = base | ({"pixels"} if obs == "pixels" else set())
    out = [register_spec(EnvSpec(f"{name}-v{version}", core_factory, main_tf,
                                 main_tags, kw))]
    if pixel_variant:
        out.append(register_spec(EnvSpec(f"{name}-px", core_factory, pixel_tf,
                                         base | {"pixels"}, kw)))
    out.append(register_spec(EnvSpec(f"{name}-raw", core_factory, (),
                                     base | {"raw"}, kw)))
    return tuple(out)


def registered() -> list:
    _ensure_builtins()  # so `cairl.registered()` is complete before any make()
    return sorted(_REGISTRY)


def spec(name: str) -> EnvSpec:
    """The declarative `EnvSpec` behind a registered id (queryable API)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(f"unknown environment {name!r}; known: {registered()}")
    return _REGISTRY[name]


def specs() -> Tuple[EnvSpec, ...]:
    """Every registered `EnvSpec`, id-sorted — the registry as a test matrix."""
    return tuple(_REGISTRY[n] for n in registered())


def make(name: str, **kwargs) -> Env:
    """Build a functional env by registry id (e.g. "CartPole-v1")."""
    return spec(name).make(**kwargs)


def spec_of(env) -> Optional[EnvSpec]:
    """Find the `EnvSpec` an env was built from, walking wrapper layers
    (e.g. through the `Vec(AutoReset(...))` stacks pools add)."""
    while env is not None:
        s = getattr(env, "spec", None)
        if s is not None:
            return s
        env = getattr(env, "env", None)
    return None


def make_compat(name: str, seed: int = 0, new_step_api: bool = False,
                render_mode: Optional[str] = None, **kwargs):
    """Gym drop-in: stateful reset()/step()/render() object (Listing 2).

    `new_step_api=True` returns the Gym >= 0.26 5-tuple
    `(obs, reward, terminated, truncated, info)` from `step`.
    `render_mode` is accepted for modern-Gym call-site compatibility; all
    rendering here is on-device `render()` -> frame, so it is ignored.
    """
    from repro.core.gym_compat import GymCompat

    return GymCompat(make(name, **kwargs), seed=seed, new_step_api=new_step_api,
                     render_mode=render_mode)


_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    import repro.envs  # noqa: F401  (registers on import)
