"""Observation/action spaces — CaiRL `Spaces` module (paper §III-A.5).

The paper's Box/Discrete types are "highly optimized code, which efficiently
increases populating data matrices"; here every space is a static dataclass
whose `sample` is pure-JAX (traceable, vmappable) so sampling can run inside
compiled rollouts — the XLA analogue of the paper's compile-time evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Space:
    """Abstract space. Static (hashable) so envs can be jit-static args."""

    shape: Tuple[int, ...]
    dtype: jnp.dtype

    def sample(self, key: jax.Array) -> jax.Array:
        raise NotImplementedError

    def contains(self, x) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Discrete(Space):
    """One-dimensional set of integers {0..n-1} (paper §III-A.5)."""

    n: int
    dtype: jnp.dtype = jnp.int32

    @property
    def shape(self) -> Tuple[int, ...]:
        return ()

    def sample(self, key: jax.Array) -> jax.Array:
        return jax.random.randint(key, (), 0, self.n, dtype=self.dtype)

    def contains(self, x) -> jax.Array:
        x = jnp.asarray(x)
        ok = (x >= 0) & (x < self.n)
        # Float inputs must still be *integers*: 2.5 is not in Discrete(4).
        # (The fused megastep kernel computes int observations in f32 rows —
        # kernels/envstep — so a missing round-trip cast shows up here.)
        if not jnp.issubdtype(x.dtype, jnp.integer):
            ok = ok & (x == jnp.floor(x))
        return ok


@dataclasses.dataclass(frozen=True)
class Box(Space):
    """n-dimensional real-valued matrix with per-element bounds."""

    low: Tuple[float, ...] | float
    high: Tuple[float, ...] | float
    shape: Tuple[int, ...]
    dtype: jnp.dtype = jnp.float32

    def _bounds(self):
        low = jnp.broadcast_to(jnp.asarray(self.low, self.dtype), self.shape)
        high = jnp.broadcast_to(jnp.asarray(self.high, self.dtype), self.shape)
        return low, high

    def sample(self, key: jax.Array) -> jax.Array:
        low, high = self._bounds()
        # Unbounded dims sample from a unit normal (Gym semantics).
        finite = jnp.isfinite(low) & jnp.isfinite(high)
        u = jax.random.uniform(key, self.shape, self.dtype)
        n = jax.random.normal(key, self.shape, self.dtype)
        return jnp.where(finite, low + u * (high - low), n)

    def contains(self, x) -> jax.Array:
        low, high = self._bounds()
        x = jnp.asarray(x)
        return jnp.all((x >= low) & (x <= high))


@dataclasses.dataclass(frozen=True)
class MultiDiscrete(Space):
    """Vector of independent Discrete axes (e.g. Multitask's per-minigame action)."""

    nvec: Tuple[int, ...]
    dtype: jnp.dtype = jnp.int32

    @property
    def shape(self) -> Tuple[int, ...]:
        return (len(self.nvec),)

    def sample(self, key: jax.Array) -> jax.Array:
        # One randint with a per-axis maxval vector (not len(nvec) split
        # streams + a stack): one threefry call however many axes, and the
        # dtype is the space's own — a 64-cell grid space was previously 64
        # unrolled randint ops.
        nv = jnp.asarray(self.nvec, self.dtype)
        return jax.random.randint(key, (len(self.nvec),), 0, nv,
                                  dtype=self.dtype)

    def contains(self, x) -> jax.Array:
        x = jnp.asarray(x)
        nv = jnp.asarray(self.nvec, self.dtype)
        ok = (x >= 0) & (x < nv)
        if not jnp.issubdtype(x.dtype, jnp.integer):  # see Discrete.contains
            ok = ok & (x == jnp.floor(x))
        return jnp.all(ok)


def sample_batch(space: Space, key: jax.Array, batch_size: int) -> jax.Array:
    """Sample a whole batch from ONE key (1 threefry stream, not B).

    The hot-path sampler shared by runner.rollout_random_fast and
    pool.EnvPool: Discrete/Box draw the batch in a single primitive; exotic
    spaces fall back to a vmapped per-env `space.sample`.
    """
    if isinstance(space, Discrete):
        return jax.random.randint(key, (batch_size,), 0, space.n, dtype=space.dtype)
    if isinstance(space, Box):
        low, high = space._bounds()
        u = jax.random.uniform(key, (batch_size,) + space.shape, space.dtype)
        return low + u * (high - low)
    if isinstance(space, MultiDiscrete):
        # Broadcast maxval across the batch; keeps the space dtype (the old
        # vmap fallback unrolled len(nvec) randints per batch element).
        nv = jnp.asarray(space.nvec, space.dtype)
        return jax.random.randint(key, (batch_size, len(space.nvec)), 0, nv,
                                  dtype=space.dtype)
    keys = jax.random.split(key, batch_size)
    return jax.vmap(space.sample)(keys)


def flatten_space(space: Space) -> Box:
    """The Flatten wrapper's target space (paper §III-A.4)."""
    if isinstance(space, Box):
        size = int(np.prod(space.shape)) if space.shape else 1
        return Box(low=-np.inf, high=np.inf, shape=(size,), dtype=space.dtype)
    if isinstance(space, Discrete):
        return Box(low=0.0, high=1.0, shape=(space.n,), dtype=jnp.float32)
    if isinstance(space, MultiDiscrete):
        return Box(low=0.0, high=1.0, shape=(int(sum(space.nvec)),), dtype=jnp.float32)
    raise TypeError(f"cannot flatten {type(space)}")


def flatten_obs(space: Space, obs: jax.Array) -> jax.Array:
    if isinstance(space, Box):
        return obs.reshape((-1,)).astype(space.dtype)
    if isinstance(space, Discrete):
        return jax.nn.one_hot(obs, space.n, dtype=jnp.float32)
    if isinstance(space, MultiDiscrete):
        parts = [jax.nn.one_hot(obs[i], n, dtype=jnp.float32) for i, n in enumerate(space.nvec)]
        return jnp.concatenate(parts)
    raise TypeError(f"cannot flatten {type(space)}")
