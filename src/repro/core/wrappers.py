"""Wrappers — CaiRL `wrappers` module (paper §III-A.4).

The paper ships Flatten + TimeLimit ("max timestamp restrictions") as static
template compositions: `Flatten<TimeLimit<200, CartPoleEnv>>()`. Here wrapper
composition happens at trace time, so the composed program is a single fused
XLA computation — the same zero-runtime-cost layering the templates buy in C++.

AutoReset and Vec are the two wrappers compiled rollouts need (runner.py):
AutoReset re-enters `reset` inside the device program on `done`, Vec `vmap`s
the whole stack across a batch axis (the SIMD analogue, paper §II-B).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import Env, Timestep
from repro.core.spaces import Box, Space, flatten_obs, flatten_space


class Wrapper(Env):
    """Delegating base wrapper."""

    def __init__(self, env: Env):
        self.env = env

    @property
    def observation_space(self) -> Space:  # type: ignore[override]
        return self.env.observation_space

    @property
    def action_space(self) -> Space:  # type: ignore[override]
        return self.env.action_space

    @property
    def unwrapped(self) -> Env:
        return self.env.unwrapped

    @property
    def name(self) -> str:
        return self.env.name

    def reset(self, key):
        return self.env.reset(key)

    def step(self, state, action, key):
        return self.env.step(state, action, key)

    def render(self, state):
        return self.env.render(state)

    def __repr__(self):  # pragma: no cover
        return f"{type(self).__name__}({self.env!r})"


class TimeLimitState(NamedTuple):
    inner: Any
    t: jax.Array


class TimeLimit(Wrapper):
    """Truncate episodes at `max_steps` (paper's TimeLimit / Listing 1).

    `done` still folds terminal | truncation (the autoreset/episode boundary),
    but the truncation bit is surfaced separately as `info["truncated"]` —
    True only when the cut is the time limit and the state is *not*
    env-terminal. Value-based learners must bootstrap through truncated
    transitions (they are not terminal states); conflating the two biases
    the targets of every env that mostly ends by time limit (Pendulum,
    MountainCar).
    """

    def __init__(self, env: Env, max_steps: int):
        super().__init__(env)
        self.max_steps = max_steps

    def reset(self, key):
        inner, obs = self.env.reset(key)
        return TimeLimitState(inner, jnp.asarray(0, jnp.int32)), obs

    def step(self, state: TimeLimitState, action, key):
        ts = self.env.step(state.inner, action, key)
        t = state.t + 1
        truncated = (t >= self.max_steps) & ~ts.done
        info = dict(ts.info)
        info["truncated"] = truncated
        return ts._replace(state=TimeLimitState(ts.state, t),
                           done=ts.done | truncated, info=info)

    def render(self, state: TimeLimitState):
        return self.env.render(state.inner)


class FlattenObs(Wrapper):
    """Flatten observations to a 1-D Box (paper's Flatten wrapper)."""

    @property
    def observation_space(self) -> Box:  # type: ignore[override]
        return flatten_space(self.env.observation_space)

    def _flat(self, obs):
        return flatten_obs(self.env.observation_space, obs)

    def reset(self, key):
        state, obs = self.env.reset(key)
        return state, self._flat(obs)

    def step(self, state, action, key):
        ts = self.env.step(state, action, key)
        return ts._replace(obs=self._flat(ts.obs))


class AutoResetState(NamedTuple):
    inner: Any
    key: jax.Array


class AutoReset(Wrapper):
    """Reset inside the compiled program when an episode ends.

    This is what lets the paper-style `run()` fast path (runner.py) execute
    arbitrarily many episodes without ever returning to the host. The
    pre-reset terminal obs is surfaced in `info["terminal_obs"]`.
    """

    def reset(self, key):
        key, sub = jax.random.split(key)
        inner, obs = self.env.reset(sub)
        return AutoResetState(inner, key), obs

    def step(self, state: AutoResetState, action, key):
        ts = self.env.step(state.inner, action, key)
        next_key, reset_key = jax.random.split(state.key)
        fresh_state, fresh_obs = self.env.reset(reset_key)
        new_inner = jax.tree.map(
            lambda a, b: jnp.where(ts.done, a, b), fresh_state, ts.state
        )
        new_obs = jnp.where(ts.done, fresh_obs, ts.obs)
        info = dict(ts.info)
        info["terminal_obs"] = ts.obs
        return ts._replace(state=AutoResetState(new_inner, next_key), obs=new_obs, info=info)

    def render(self, state: AutoResetState):
        return self.env.render(state.inner)


class Vec(Wrapper):
    """Batch `num_envs` copies with vmap — one instruction steps them all.

    The SIMD claim of the paper (§II-B/§III): vectorised arithmetic across the
    env batch maps to VPU lanes / MXU tiles on TPU instead of CPU SIMD.
    """

    def __init__(self, env: Env, num_envs: int):
        super().__init__(env)
        self.num_envs = num_envs

    def reset(self, key):
        keys = jax.random.split(key, self.num_envs)
        return jax.vmap(self.env.reset)(keys)

    def step(self, state, action, key):
        keys = jax.random.split(key, self.num_envs)
        return jax.vmap(self.env.step)(state, action, keys)

    def render(self, state):
        return jax.vmap(self.env.render)(state)

    def sample_actions(self, key):
        keys = jax.random.split(key, self.num_envs)
        return jax.vmap(self.env.action_space.sample)(keys)


class RewardScale(Wrapper):
    """Scale rewards by a static factor."""

    def __init__(self, env: Env, scale: float):
        super().__init__(env)
        self.scale = float(scale)

    def step(self, state, action, key):
        ts = self.env.step(state, action, key)
        return ts._replace(reward=ts.reward * self.scale)


class ObsToPixels(Wrapper):
    """Replace the observation with the rendered framebuffer.

    Paper §IV-C: "game observations are either raw pixels or the virtual
    Flash memory". This wrapper is the raw-pixels mode for any env with a
    renderer; DQN's CNN consumes it directly on device (no readback — the
    software-rendering point of §II-B).
    """

    @property
    def observation_space(self) -> Box:  # type: ignore[override]
        h, w = self._hw()
        return Box(low=0.0, high=1.0, shape=(h, w), dtype=jnp.float32)

    def _hw(self):
        env = self.env.unwrapped
        return env.frame_shape  # envs with renderers expose (H, W)

    def reset(self, key):
        state, _ = self.env.reset(key)
        return state, self.env.render(state)

    def step(self, state, action, key):
        ts = self.env.step(state, action, key)
        return ts._replace(obs=self.env.render(ts.state))


class FrameStackState(NamedTuple):
    inner: Any
    frames: jax.Array  # (num_frames, ...) most-recent-last ring of observations


class FrameStack(Wrapper):
    """Stack the last `num_frames` observations along a new leading axis.

    The classic pixel-RL pipeline (DQN on Atari) over any env: reset fills
    the stack with the initial observation, each step shifts the oldest
    frame out and appends the newest. `FrameStack(ObsToPixels(env), 4)` is
    the arcade observation mode DQN's CNN consumes (rl/networks.cnn_apply
    treats the stack axis as input channels).
    """

    def __init__(self, env: Env, num_frames: int = 4):
        super().__init__(env)
        self.num_frames = int(num_frames)

    @property
    def observation_space(self) -> Box:  # type: ignore[override]
        inner = self.env.observation_space
        return Box(low=float(np.min(np.asarray(inner.low))),
                   high=float(np.max(np.asarray(inner.high))),
                   shape=(self.num_frames,) + tuple(inner.shape),
                   dtype=inner.dtype)

    def reset(self, key):
        inner, obs = self.env.reset(key)
        frames = jnp.broadcast_to(obs, (self.num_frames,) + obs.shape)
        return FrameStackState(inner, frames), frames

    def step(self, state: FrameStackState, action, key):
        ts = self.env.step(state.inner, action, key)
        frames = jnp.concatenate([state.frames[1:], ts.obs[None]], axis=0)
        return ts._replace(state=FrameStackState(ts.state, frames), obs=frames)

    def render(self, state: FrameStackState):
        return self.env.render(state.inner)
