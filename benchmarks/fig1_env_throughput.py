"""Fig. 1 reproduction: env execution throughput, CaiRL vs interpreted Gym.

Paper setup: 100 000 steps averaged over trials, console and render modes,
four classic-control envs. Here both execution models run behind the same
pool API (repro.pool): `EnvPool` compiles the whole batched rollout into one
device program; `HostPool` drives the pure-Python baselines (same dynamics,
same machine). Reported: steps/s both ways and the ratio (paper: ~5×
console, ~80× render).
"""
from __future__ import annotations

import time
from typing import Dict

import jax

from repro.pool import EnvPool, HostPool

ENVS = ["CartPole-v1", "Acrobot-v1", "MountainCar-v0", "Pendulum-v1"]


def bench_compiled(name: str, steps: int, batch: int, render: bool, trials: int = 3) -> float:
    pool = EnvPool(name, batch)
    jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(0), render)[0])  # compile
    best = 0.0
    for t in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(t), render)[0])
        sps = steps * batch / (time.perf_counter() - t0)
        best = max(best, sps)
    return best


def bench_python(name: str, steps: int, render: bool, trials: int = 2) -> float:
    pool = HostPool(name, num_envs=1)
    best = 0.0
    for t in range(trials):
        t0 = time.perf_counter()
        pool.run_random(steps, seed=t, render=render)
        sps = steps / (time.perf_counter() - t0)
        best = max(best, sps)
    return best


def run(console_steps: int = 2000, render_steps: int = 200, batch: int = 64) -> Dict:
    rows = {}
    for name in ENVS:
        c_sps = bench_compiled(name, console_steps, batch, render=False)
        p_sps = bench_python(name, console_steps, render=False)
        cr_sps = bench_compiled(name, render_steps, batch, render=True)
        pr_sps = bench_python(name, max(render_steps // 4, 25), render=True)
        rows[name] = {
            "cairl_console_sps": c_sps,
            "gym_console_sps": p_sps,
            "console_speedup": c_sps / p_sps,
            "cairl_render_sps": cr_sps,
            "gym_render_sps": pr_sps,
            "render_speedup": cr_sps / pr_sps,
        }
    return rows


def main(emit):
    rows = run()
    for name, r in rows.items():
        emit(f"fig1/{name}/console", 1e6 / r["cairl_console_sps"],
             f"speedup={r['console_speedup']:.1f}x (cairl {r['cairl_console_sps']:.0f} vs gym {r['gym_console_sps']:.0f} steps/s)")
        emit(f"fig1/{name}/render", 1e6 / r["cairl_render_sps"],
             f"speedup={r['render_speedup']:.1f}x (cairl {r['cairl_render_sps']:.0f} vs gym {r['gym_render_sps']:.0f} steps/s)")
