"""Fig. 1 reproduction: env execution throughput, CaiRL vs interpreted Gym.

Paper setup: 100 000 steps averaged over trials, console and render modes,
four classic-control envs. Here both execution models run behind the same
pool API (repro.pool): `EnvPool` compiles the whole batched rollout into one
device program; `HostPool` drives the pure-Python baselines (same dynamics,
same machine). Reported: steps/s both ways and the ratio (paper: ~5×
console, ~80× render).
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax

from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import EnvPool, make_vec

ENVS = ["CartPole-v1", "Acrobot-v1", "MountainCar-v0", "Pendulum-v1"]
# Arcade pixel games: every step renders 84×84 observations on device, the
# paper's software-rendering workload (§II-B) — console mode is render mode.
ARCADE = ["Pong-v0"]
# Procedural gridworlds (envs/grid): the level regenerates every episode on
# the autoreset key chain, so console throughput includes on-device level
# generation; the interpreted comparator regenerates with python RNG.
GRID = ["FrozenLake-v0", "CliffWalk-v0", "Snake-v0", "Maze-v0"]


def bench_compiled(name: str, steps: int, batch: int, render: bool,
                   trials: int = 3, backend: str = "vmap",
                   unroll: int = 32) -> float:
    pool = make_vec(name, batch, backend=backend, unroll=unroll)
    jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(0), render)[0])  # compile
    best = 0.0
    for t in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(t), render)[0])
        sps = steps * batch / (time.perf_counter() - t0)
        best = max(best, sps)
    return best


def bench_python(name: str, steps: int, render: bool, trials: int = 2) -> float:
    pool = make_vec(name, 1, host=True)
    best = 0.0
    for t in range(trials):
        t0 = time.perf_counter()
        pool.run_random(steps, seed=t, render=render)
        sps = steps / (time.perf_counter() - t0)
        best = max(best, sps)
    return best


def run(console_steps: int = 2000, render_steps: int = 200, batch: int = 64) -> Dict:
    rows = {}
    for name in ENVS + ARCADE + GRID:
        # Arcade ids observe rendered frames, so their compiled "console"
        # mode rasterises every step — the interpreted comparator must
        # render too or the ratio measures rendering-vs-nothing.
        pixel = name in ARCADE
        p_steps = max(console_steps // 4, 25) if pixel else console_steps
        c_sps = bench_compiled(name, console_steps, batch, render=False)
        p_sps = bench_python(name, p_steps, render=pixel)
        cr_sps = bench_compiled(name, render_steps, batch, render=True)
        pr_sps = bench_python(name, max(render_steps // 4, 25), render=True)
        rows[name] = {
            "cairl_console_sps": c_sps,
            "gym_console_sps": p_sps,
            "console_speedup": c_sps / p_sps,
            "cairl_render_sps": cr_sps,
            "gym_render_sps": pr_sps,
            "render_speedup": cr_sps / pr_sps,
        }
    return rows


def run_backends(steps: int = 2000, batch: int = 64, unroll: int = 32,
                 include_host: bool = True, envs=None,
                 backends=("vmap", "pallas")) -> Dict:
    """Per-backend console throughput: vmap pool vs fused pallas megastep.

    The pallas pool's compiled rollout is also HLO-checked for host
    transfers (must be 0 — device residency survives the fused path).
    Arcade pixel envs run with a capped unroll: every fused chunk
    materialises K·B rendered frames, so deep unrolls trade throughput for
    framebuffer memory.
    """
    from repro.core.registry import make

    rows: Dict[str, Dict] = {}
    for name in (envs or ENVS + ARCADE + GRID):
        r: Dict = {}
        pixel = len(make(name).observation_space.shape) >= 2
        u = min(unroll, 8) if pixel else unroll
        if "vmap" in backends:
            r["vmap_sps"] = bench_compiled(name, steps, batch, render=False)
        if "pallas" in backends:
            pool = make_vec(name, batch, backend="pallas", unroll=u)
            transfers = host_transfer_ops(
                pool.rollout_lowered(min(steps, 256)).compile().as_text())
            r["host_transfers"] = len(transfers)
            r["pallas_sps"] = bench_compiled(name, steps, batch, render=False,
                                             backend="pallas", unroll=u)
        if "vmap_sps" in r and "pallas_sps" in r:
            r["pallas_vs_vmap"] = r["pallas_sps"] / r["vmap_sps"]
        if include_host:
            # Pixel envs: the interpreted side renders too (see run()).
            h_steps = min(steps, 500) if pixel else min(steps, 2000)
            r["gym_sps"] = bench_python(name, h_steps, render=pixel)
        rows[name] = r
    return rows


def bench_frontend(name: str = "CartPole-v1", batch: int = 64,
                   steps: int = 500, trials: int = 3) -> Dict:
    """Frontend-overhead row: `make_vec` vs raw `EnvPool` construction.

    Measures (a) constructor + first-step compile wall-clock and (b)
    steady-state steps/s through each constructor, on the same vmap step
    engine — the evidence that the declarative `EnvSpec`/`make_vec` frontend
    is construction-time-only and adds no steady-state cost.
    """
    import numpy as np

    def once(ctor):
        t0 = time.perf_counter()
        pool = ctor()
        pool.reset(seed=0)
        jax.block_until_ready(pool.step(pool.sample_actions(0))[0])
        startup_s = time.perf_counter() - t0
        jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(0))[0])
        best = 0.0
        for t in range(trials):
            t0 = time.perf_counter()
            jax.block_until_ready(
                pool.rollout(steps, jax.random.PRNGKey(t + 1))[0])
            best = max(best, steps * batch / (time.perf_counter() - t0))
        return startup_s, best

    mv_start, mv_sps = once(lambda: make_vec(name, batch, backend="vmap"))
    raw_start, raw_sps = once(lambda: EnvPool(name, batch, backend="vmap"))
    return {
        "env": name, "batch": batch, "steps": steps,
        "make_vec_startup_s": mv_start, "envpool_startup_s": raw_start,
        "make_vec_sps": mv_sps, "envpool_sps": raw_sps,
        "steady_state_ratio": mv_sps / raw_sps if raw_sps else float(np.nan),
    }


def main(emit):
    rows = run()
    for name, r in rows.items():
        emit(f"fig1/{name}/console", 1e6 / r["cairl_console_sps"],
             f"speedup={r['console_speedup']:.1f}x (cairl {r['cairl_console_sps']:.0f} vs gym {r['gym_console_sps']:.0f} steps/s)")
        emit(f"fig1/{name}/render", 1e6 / r["cairl_render_sps"],
             f"speedup={r['render_speedup']:.1f}x (cairl {r['cairl_render_sps']:.0f} vs gym {r['gym_render_sps']:.0f} steps/s)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="both",
                    choices=["vmap", "pallas", "both"],
                    help="pool step engine(s) to benchmark")
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--unroll", type=int, default=32,
                    help="env steps fused per megastep kernel launch")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write steps/sec per backend as JSON (bench-json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small step counts for CI smoke / perf trajectory")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 300)

    # --backend pallas still measures vmap: the deliverable is the ratio.
    backends = ("vmap",) if args.backend == "vmap" else ("vmap", "pallas")
    print(f"devices: {len(jax.devices())} ({jax.default_backend()})  "
          f"steps={args.steps} batch={args.batch} unroll={args.unroll}")
    rows = run_backends(args.steps, args.batch, args.unroll,
                        include_host=not args.smoke, backends=backends)
    frontend = bench_frontend(batch=args.batch, steps=min(args.steps, 500))
    print(f"{'frontend':>16}: make_vec {frontend['make_vec_sps']:>12,.0f} "
          f"steps/s vs EnvPool {frontend['envpool_sps']:>12,.0f} "
          f"({frontend['steady_state_ratio']:.2f}x steady-state; startup "
          f"{frontend['make_vec_startup_s']:.2f}s vs "
          f"{frontend['envpool_startup_s']:.2f}s)")
    for name, r in rows.items():
        line = f"{name:>16}: vmap {r['vmap_sps']:>12,.0f} steps/s"
        if "pallas_sps" in r:
            resident = ("device-resident" if r["host_transfers"] == 0
                        else f"HOST TRANSFERS: {r['host_transfers']}")
            line += (f" | pallas {r['pallas_sps']:>12,.0f} steps/s "
                     f"({r['pallas_vs_vmap']:.2f}x) [{resident}]")
        if "gym_sps" in r:
            line += f" | gym {r['gym_sps']:,.0f}"
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"steps": args.steps, "batch": args.batch,
                       "unroll": args.unroll,
                       "backend_filter": args.backend, "envs": rows,
                       "frontend_overhead": frontend}, f, indent=2)
        print(f"wrote {args.json}")
