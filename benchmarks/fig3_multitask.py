"""Fig. 3 reproduction (scaled): DQN learns the Multitask environment.

Paper: DQN solves Multitask after ~1.5–3M frames over 10 trials (60 h).
Scaled to this host: a short run must show the learning signal — mean
episode return clearly above the random policy baseline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import make
from repro.pool import make_vec
from repro.rl.dqn import DQNConfig, greedy_returns, train_compiled


def run(steps: int = 12000, name: str = "Multitask-v0",
        exploration_steps: int = 6000, eval_max_steps: int = 1000):
    env = make(name)
    # random-policy baseline return, via the pool's compiled rollout
    rew, eps, _ = make_vec(env, 16).rollout(2000, jax.random.PRNGKey(1))
    random_return = float(rew.sum() / jax.numpy.maximum(eps.sum(), 1))

    cfg = DQNConfig(num_envs=4, exploration_steps=exploration_steps,
                    learn_start=500, lr=1e-3, batch_size=64,
                    target_update_freq=400, units=(64, 64))
    t0 = time.perf_counter()
    state, apply_fn, metrics = train_compiled(env, cfg, steps, jax.random.PRNGKey(0))
    train_s = time.perf_counter() - t0
    greedy = float(np.mean(np.asarray(
        greedy_returns(env, apply_fn, state.params, jax.random.PRNGKey(7),
                       max_steps=eval_max_steps))))
    return {"random_return": random_return, "dqn_return": greedy,
            "frames": steps * cfg.num_envs, "train_s": train_s}


def run_procedural(name: str = "FrozenLake-v0", steps: int = 8000):
    """The multitask mix, procedural flavour: every episode of a grid env is
    a brand-new level (envs/grid), so DQN must learn a policy that
    generalises across levels rather than memorise one map. Reported the
    same way as the Multitask row: greedy return vs the random baseline."""
    return run(steps, name=name, exploration_steps=4000, eval_max_steps=200)


def main(emit):
    r = run()
    emit("fig3/multitask_dqn", r["train_s"] * 1e6 / r["frames"],
         f"dqn_return={r['dqn_return']:.0f} vs random={r['random_return']:.0f} "
         f"after {r['frames']} frames")
    g = run_procedural()
    emit("fig3/procedural_grid_dqn", g["train_s"] * 1e6 / g["frames"],
         f"dqn_return={g['dqn_return']:.2f} vs random={g['random_return']:.2f} "
         f"after {g['frames']} frames (new level every episode)")
