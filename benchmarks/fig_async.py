"""Async env serving: continuous slot refill vs lock-step wave serving.

EnvPool's async mode exists for the serving workload: thousands of client
sessions with *heterogeneous* episode budgets multiplexed onto one
accelerator batch. A lock-step pool must serve them in waves — admit
`num_slots` sessions, step every lane until the LONGEST budget in the wave
finishes, repeat — so short sessions burn dead lane-steps waiting for the
stragglers. The async pool (repro.pool.AsyncEnvPool + serving.EnvService)
retires each session the tick its budget is spent and splices the next
queued session's reset state into the freed slot, keeping occupancy high.

This benchmark replays the SAME synthetic traffic (sessions with budgets
drawn from a long-tailed mixture) through both schedulers and reports:

  - useful steps/s  (session steps actually served, not lane-steps burned)
  - p50/p99 recv latency per scheduler tick
  - occupancy       (served steps / (ticks * slots))

Device residency is verified, not assumed: the async pool's compiled
masked-step core must contain zero host-transfer instructions
(repro.launch.hlo_analysis.host_transfer_ops).

Run: PYTHONPATH=src python benchmarks/fig_async.py [--smoke]
     [--sessions 2000] [--slots 256] [--json BENCH_fig_async.json]
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import make_vec
from repro.serving.env_service import EnvService, Session
from repro.serving.slots import percentile


def session_budgets(num_sessions: int, seed: int = 0,
                    short: int = 8, long: int = 128) -> List[int]:
    """Long-tailed budget mixture: mostly short sessions, a slow tail.

    This is the shape that hurts lock-step serving most — one `long` session
    per wave pins every lane for `long` ticks.
    """
    rng = np.random.default_rng(seed)
    budgets = rng.integers(1, short + 1, size=num_sessions)
    tail = rng.random(num_sessions) < 0.1
    budgets[tail] = rng.integers(short, long + 1, size=int(tail.sum()))
    return [int(b) for b in budgets]


def run_async(env: str, slots: int, budgets: List[int]) -> Dict:
    svc = EnvService(env, slots, backend="auto")
    # warm the compiled cores (init / admit / masked step) before timing
    svc.submit(Session(sid=-1, seed=0, num_steps=1))
    svc.run()
    svc.ticks = svc.steps_served = 0
    svc.recv_latencies.clear()

    for i, b in enumerate(budgets):
        svc.submit(Session(sid=i, seed=i, num_steps=b))
    t0 = time.perf_counter()
    svc.run()
    wall = time.perf_counter() - t0
    st = svc.stats()
    assert st["running"] == 0 and st["queued"] == 0
    assert svc.steps_served == sum(budgets)
    return {
        "scheduler": "async-refill",
        "steps_per_s": svc.steps_served / wall,
        "recv_p50_ms": 1e3 * st["recv_p50_s"],
        "recv_p99_ms": 1e3 * st["recv_p99_s"],
        "ticks": st["ticks"],
        "occupancy": svc.steps_served / (st["ticks"] * slots),
        "wall_s": wall,
    }


def run_lockstep(env: str, slots: int, budgets: List[int]) -> Dict:
    """Wave serving on the lock-step pool: the whole batch steps together,
    so each wave runs for max(budgets-in-wave) ticks and a lane whose
    session finished early burns dead steps until the wave ends."""
    pool = make_vec(env, slots, backend="auto")
    rng = np.random.default_rng(0)
    pool.reset(seed=0)
    pool.step(np.asarray(pool.sample_actions(0)))  # warm the compiled step

    served = ticks = 0
    recv_lat: List[float] = []
    t0 = time.perf_counter()
    for wave_start in range(0, len(budgets), slots):
        wave = budgets[wave_start:wave_start + slots]
        pool.reset(seed=wave_start)
        for t in range(max(wave)):
            acts = np.asarray(pool.sample_actions(rng.integers(1 << 31)))
            s0 = time.perf_counter()
            pool.step(acts)
            recv_lat.append(time.perf_counter() - s0)
            ticks += 1
            served += sum(1 for b in wave if t < b)
    wall = time.perf_counter() - t0
    assert served == sum(budgets)
    return {
        "scheduler": "lock-step-waves",
        "steps_per_s": served / wall,
        "recv_p50_ms": 1e3 * percentile(recv_lat, 50),
        "recv_p99_ms": 1e3 * percentile(recv_lat, 99),
        "ticks": ticks,
        "occupancy": served / (ticks * slots),
        "wall_s": wall,
    }


def check_device_resident(env: str, slots: int) -> List[str]:
    """Host-transfer instructions in the async pool's compiled masked-step
    core (must be empty: send/recv bookkeeping is host-side, the env step
    itself never leaves the device)."""
    pool = make_vec(env, slots, backend="async")
    return host_transfer_ops(pool.step_lowered().compile().as_text())


def run(env: str = "CartPole-v1", sessions: int = 2000, slots: int = 256,
        seed: int = 0) -> Dict:
    budgets = session_budgets(sessions, seed=seed)
    transfers = check_device_resident(env, slots)
    rows = {
        "async": run_async(env, slots, budgets),
        "lockstep": run_lockstep(env, slots, budgets),
    }
    for r in rows.values():
        r["host_transfers"] = len(transfers)
        r["transfer_ops"] = transfers
    rows["async"]["speedup_vs_lockstep"] = (
        rows["async"]["steps_per_s"] / rows["lockstep"]["steps_per_s"])
    return {"env": env, "sessions": sessions, "slots": slots,
            "total_steps": sum(budgets), "rows": rows}


def main(emit):
    out = run(sessions=200, slots=32)
    for name, r in out["rows"].items():
        assert r["host_transfers"] == 0, (name, r)
        emit(f"fig_async/{name}", 1e6 / r["steps_per_s"],
             f"steps_per_s={r['steps_per_s']:.0f};"
             f"recv_p99_ms={r['recv_p99_ms']:.2f};"
             f"occupancy={r['occupancy']:.2f}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="CartPole-v1")
    ap.add_argument("--sessions", type=int, default=2000)
    ap.add_argument("--slots", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="small traffic (200 sessions / 32 slots)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-scheduler rows as JSON (bench-json)")
    args = ap.parse_args()
    if args.smoke:
        args.sessions, args.slots = 200, 32

    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    out = run(args.env, args.sessions, args.slots)
    for name, r in out["rows"].items():
        resident = "device-resident" if r["host_transfers"] == 0 else \
            f"HOST TRANSFERS: {r['transfer_ops']}"
        print(f"{r['scheduler']:>16}: {r['steps_per_s']:>10,.0f} steps/s  "
              f"p50 {r['recv_p50_ms']:6.2f}ms  p99 {r['recv_p99_ms']:6.2f}ms  "
              f"occupancy {r['occupancy']:.2f}  [{resident}]")
    print(f"async speedup vs lock-step waves: "
          f"{out['rows']['async']['speedup_vs_lockstep']:.2f}x")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
