"""Benchmark orchestrator. One module per paper table/figure.

Prints `name,us_per_call,derived` CSV rows. Roofline rows (from the dry-run
artifacts, if present) are appended at the end.
"""
from __future__ import annotations

import sys
import traceback


def _emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def main() -> None:
    from benchmarks import (fig1_env_throughput, fig2_dqn_training, fig3_multitask,
                            fig4_pool_scaling, table2_carbon)

    print("name,us_per_call,derived")
    for mod in (fig1_env_throughput, fig2_dqn_training, fig3_multitask,
                fig4_pool_scaling, table2_carbon):
        try:
            mod.main(_emit)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            _emit(f"{mod.__name__}/ERROR", 0.0, repr(e))

    # roofline summary (requires results/dryrun from launch.dryrun)
    try:
        from benchmarks import roofline

        rows = roofline.table(mesh="pod16x16")
        for r in rows:
            _emit(f"roofline/{r['arch']}/{r['shape']}", r["bound_s"] * 1e6,
                  f"dominant={r['dominant']};roofline_frac={r['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001
        _emit("roofline/SKIPPED", 0.0, repr(e))


if __name__ == "__main__":
    main()
