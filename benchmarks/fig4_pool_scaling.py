"""Fig. 4 (new): EnvPool batch-size / device-count scaling sweep.

EnvPool's headline result is that throughput scales with the env batch until
the accelerator saturates; Jumanji's is that pure-functional envs extend the
curve across a device mesh. This sweep measures both axes for the compiled
pool:

  - batch axis   : EnvPool steps/s for batch sizes {1, 64, 1024} (default)
  - device axis  : ShardedEnvPool steps/s for device counts {1, ..., N}
                   (only the counts this host exposes; set
                   REPRO_FORCE_DEVICES=8 to fake an 8-device CPU mesh)

Device residency is *verified*, not assumed: the scanned step loop's
optimized HLO must contain zero host-transfer instructions
(repro.launch.hlo_analysis.host_transfer_ops). Every pool — plain, fused
and sharded — is built through the unified `repro.make_vec` frontend.

Run: PYTHONPATH=src python benchmarks/fig4_pool_scaling.py
     [--steps 2000] [--batches 1,64,1024] [--env CartPole-v1]
"""
from __future__ import annotations

import os

# Must precede the first jax import to take effect (benchmark-only knob).
_FORCE = os.environ.get("REPRO_FORCE_DEVICES")
if _FORCE and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={_FORCE}")

import time
from typing import Dict, List

import jax

from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import default_pool_mesh, make_vec


def bench_pool(pool, steps: int, trials: int = 3) -> float:
    jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(0))[0])  # compile
    best = 0.0
    for t in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(pool.rollout(steps, jax.random.PRNGKey(t + 1))[0])
        best = max(best, steps * pool.num_envs / (time.perf_counter() - t0))
    return best


def check_device_resident(pool, steps: int = 64) -> List[str]:
    """Host-transfer instructions in the compiled rollout (must be empty)."""
    compiled = pool.rollout_lowered(steps).compile()
    return host_transfer_ops(compiled.as_text())


def run(env_name: str = "CartPole-v1", steps: int = 2000,
        batches=(1, 64, 1024), unroll: int = 32) -> Dict:
    rows: Dict[str, Dict] = {}
    for batch in batches:
        pool = make_vec(env_name, batch, backend="vmap")
        transfers = check_device_resident(pool)
        rows[f"batch{batch}"] = {
            "steps_per_s": bench_pool(pool, steps),
            "host_transfers": len(transfers),
            "transfer_ops": transfers,
        }
    # Fused megastep engine over the same batch axis (kernels/envstep):
    # one kernel launch per `unroll` steps instead of a scanned vmap step.
    # Envs without a fused spec (e.g. Multitask) just skip these rows.
    from repro.core.env import supports_fused_step
    from repro.core.registry import make

    if supports_fused_step(make(env_name)):
        for batch in batches:
            pool = make_vec(env_name, batch, backend="pallas",
                            unroll=unroll)
            transfers = check_device_resident(pool)
            rows[f"pallas_batch{batch}"] = {
                "steps_per_s": bench_pool(pool, steps),
                "host_transfers": len(transfers),
                "transfer_ops": transfers,
                "unroll": unroll,
            }

    # Arcade pixel workload: fused megastep game logic + per-chunk on-device
    # rendering — the heavy-env case where pooled execution pays off most.
    if env_name == "CartPole-v1":
        pixel_batch = min(64, max(batches))
        pool = make_vec("Pong-v0", pixel_batch, backend="pallas", unroll=8)
        rows[f"pixel_pong_batch{pixel_batch}"] = {
            "steps_per_s": bench_pool(pool, min(steps, 500)),
            "batch": pixel_batch,
            "host_transfers": len(check_device_resident(pool, steps=32)),
            "unroll": 8,
        }

    n_dev = len(jax.devices())
    dev_counts = sorted({1, n_dev} | ({2} if n_dev >= 2 else set()))
    base = max(batches)
    for d in dev_counts:
        dev_batch = base - base % d or d  # round down to divide d; min d
        pool = make_vec(env_name, dev_batch, backend="vmap",
                        mesh=default_pool_mesh(d))
        rows[f"devices{d}"] = {
            "steps_per_s": bench_pool(pool, steps),
            "batch": dev_batch,
            "host_transfers": len(check_device_resident(pool)),
        }
    return rows


def main(emit):
    rows = run(steps=500, batches=(1, 64, 1024))
    for name, r in rows.items():
        assert r["host_transfers"] == 0, (name, r)
        extra = f";batch={r['batch']}" if "batch" in r else ""
        emit(f"fig4/{name}", 1e6 / r["steps_per_s"],
             f"steps_per_s={r['steps_per_s']:.0f};host_transfers=0{extra}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="CartPole-v1")
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--batches", default="1,64,1024")
    ap.add_argument("--unroll", type=int, default=32,
                    help="env steps fused per megastep launch (pallas rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write steps/sec per config as JSON (bench-json)")
    args = ap.parse_args()
    batches = tuple(int(b) for b in args.batches.split(","))

    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    rows = run(args.env, args.steps, batches, unroll=args.unroll)
    for name, r in rows.items():
        resident = "device-resident" if r["host_transfers"] == 0 else \
            f"HOST TRANSFERS: {r['transfer_ops']}"
        print(f"{name:>16}: {r['steps_per_s']:>12,.0f} steps/s  [{resident}]")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"env": args.env, "steps": args.steps,
                       "unroll": args.unroll, "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")
