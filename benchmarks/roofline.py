"""Roofline derivation from the dry-run JSONs (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s per link

Terms (seconds; cost_analysis / HLO collective bytes are PER-DEVICE, so
dividing by per-chip rates directly gives the per-step time bound — equal to
the global-quantity formulas in the task statement divided through by chips):
  compute    = flops_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link


def load_cells(directory: str = "results/dryrun") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def model_flops(cell: Dict) -> float:
    """6·N·D for training, 2·N_active·D for one forward token-batch."""
    n_act = cell.get("active_params", cell.get("params", 0))
    if cell["kind"] == "train":
        tokens = cell["seq_len"] * cell["global_batch"]
        return 6.0 * n_act * tokens
    if cell["kind"] == "prefill":
        tokens = cell["seq_len"] * cell["global_batch"]
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * cell["global_batch"]


def roofline_terms(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    chips = cell["chips"]
    compute_s = cell["flops_per_device"] / PEAK_FLOPS
    memory_s = cell["bytes_per_device"] / HBM_BW
    coll_s = cell["collective_bytes_per_device"]["total"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(cell)
    hlo_global = cell["flops_per_device"] * chips
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "bound_s": max(compute_s, memory_s, coll_s),
        # fraction of roofline-limited time that is useful model compute
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / max(compute_s, memory_s, coll_s)
        if max(compute_s, memory_s, coll_s) > 0 else 0.0,
        "temp_gib": cell.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 2**30,
    }


def table(directory: str = "results/dryrun", mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for cell in load_cells(directory):
        if cell.get("mesh") != mesh:
            continue
        t = roofline_terms(cell)
        if t:
            rows.append(t)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
           "| MODEL_FLOPS | useful | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                 f"| {r['collective_s']:.3e} | {r['dominant']} | {r['model_flops']:.2e} "
                 f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
                 f"| {r['temp_gib']:.1f} |\n")
    return hdr + body


def main() -> None:
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table(mesh=mesh)
        if not rows:
            continue
        print(f"\n== roofline ({mesh}) ==")
        print(render_markdown(rows))


if __name__ == "__main__":
    main()
