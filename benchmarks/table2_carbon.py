"""Table II reproduction: energy & CO₂, CaiRL vs interpreted Gym.

Paper methodology (§V-C): run DQN + env, track energy/emissions with the
impact tracker, isolate the environment's share by subtracting learner-only
cost. Console variant (1e6 steps in the paper) and graphical variant
(1e4 steps), both scaled to this host's budget.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.cairl_dqn import PAPER_TABLE_I
from repro.core import PythonRunner, make, rollout_random
from repro.envs.baseline_python import BASELINES
from repro.sustainability.impact import ImpactTracker


def _measure(fn):
    with ImpactTracker() as t:
        fn()
    return t.impact


def run(console_steps: int = 160_000, render_steps: int = 1600):
    env = make("CartPole-v1")
    batch = 64
    # warm-up compiles excluded from the measurement, as the paper excludes
    # C++ compile time (it is paid once per binary, not per experiment).
    # Must use the SAME static shapes as the measured calls (jit cache key).
    jax.block_until_ready(rollout_random(
        env, jax.random.PRNGKey(0), console_steps // batch, batch, False)[0])
    jax.block_until_ready(rollout_random(
        env, jax.random.PRNGKey(0), render_steps // batch, batch, True)[0])
    runner = PythonRunner(BASELINES["CartPole-v1"])

    out = {}
    for mode, steps in (("console", console_steps), ("graphical", render_steps)):
        render = mode == "graphical"
        cairl = _measure(lambda: jax.block_until_ready(
            rollout_random(env, jax.random.PRNGKey(1), steps // batch, batch, render)[0]))
        gym_steps = min(steps, 20_000 if not render else 400)
        gym = _measure(lambda: runner.run(gym_steps, render=render))
        gym = type(gym)(wall_s=gym.wall_s * steps / gym_steps,
                        cpu_s=gym.cpu_s * steps / gym_steps)  # scale to equal steps
        out[mode] = {
            "cairl_co2_kg": cairl.co2_kg, "gym_co2_kg": gym.co2_kg,
            "cairl_mwh": cairl.energy_mwh, "gym_mwh": gym.energy_mwh,
            "ratio": gym.co2_kg / max(cairl.co2_kg, 1e-12),
        }
    return out


def main(emit):
    r = run()
    for mode, row in r.items():
        emit(f"table2/{mode}/co2", row["cairl_co2_kg"] * 1e9,
             f"cairl={row['cairl_co2_kg']:.2e}kg gym={row['gym_co2_kg']:.2e}kg "
             f"ratio={row['ratio']:.1f}x (paper: {'20.9x' if mode == 'console' else '1.5e5x'})")


def static_rows(cost_report: dict) -> dict:
    """Per-id static joules/gCO₂ rows from a `repro.analysis.cost` report.

    One row per registry id (plus the fused-train cells, keyed by their
    "<algo>/<env>" id): the pallas cell where hosted, else vmap — the
    backend `make_vec(backend="auto")` would dispatch.
    """
    best: dict = {}
    for r in cost_report["rows"]:
        if r["status"] != "ok":
            continue
        prev = best.get(r["id"])
        if prev is None or (prev["backend"] != "pallas"
                            and r["backend"] == "pallas"):
            best[r["id"]] = r
    return {
        rid: {
            "backend": r["backend"],
            "family": r["family"],
            "flops_per_step": r["flops_per_step"],
            "bytes_per_step": r["bytes_per_step"],
            "dominant": r["roofline"]["dominant"],
            "joules_per_mstep": r["static_impact"]["joules_per_mstep"],
            "co2_g_per_mstep": r["static_impact"]["co2_g_per_mstep"],
        }
        for rid, r in sorted(best.items())
    }


def _cli(argv=None) -> int:
    """`make bench-json` entry: measured Table II rows + the static per-id
    joules/gCO₂ analogue derived from the compiled-cost report."""
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python benchmarks/table2_carbon.py",
        description="Table II energy/CO₂: measured (impact tracker) + "
                    "static (compiled-cost model) rows")
    ap.add_argument("--smoke", action="store_true",
                    help="small step budgets (the make bench-json mode)")
    ap.add_argument("--static-from", default="BENCH_cost_baseline-candidate.json",
                    metavar="COST_JSON",
                    help="cost report to derive the static rows from "
                         "(written by `repro.analysis.cost --json`)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the combined table as JSON")
    args = ap.parse_args(argv)
    measured = (run(console_steps=16_000, render_steps=320) if args.smoke
                else run())
    try:
        with open(args.static_from) as f:
            static = static_rows(json.load(f))
    except FileNotFoundError:
        print(f"table2: no cost report at {args.static_from}; run "
              "`python -m repro.analysis.cost --smoke --json "
              f"{args.static_from}` first — emitting measured rows only")
        static = {}
    out = {
        "meta": {"smoke": args.smoke, "static_from": args.static_from,
                 "static_ids": len(static)},
        "measured": measured,
        "static": static,
    }
    for mode, row in measured.items():
        print(f"table2/{mode}: cairl={row['cairl_co2_kg']:.2e}kg "
              f"gym={row['gym_co2_kg']:.2e}kg ratio={row['ratio']:.1f}x")
    if static:
        worst = max(static.items(),
                    key=lambda kv: kv[1]["joules_per_mstep"])
        print(f"table2/static: {len(static)} ids, costliest {worst[0]} at "
              f"{worst[1]['joules_per_mstep']:.3g} J/Mstep "
              f"({worst[1]['co2_g_per_mstep']:.3g} gCO₂/Mstep)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"table2: wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_cli())
