"""Table II reproduction: energy & CO₂, CaiRL vs interpreted Gym.

Paper methodology (§V-C): run DQN + env, track energy/emissions with the
impact tracker, isolate the environment's share by subtracting learner-only
cost. Console variant (1e6 steps in the paper) and graphical variant
(1e4 steps), both scaled to this host's budget.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.cairl_dqn import PAPER_TABLE_I
from repro.core import PythonRunner, make, rollout_random
from repro.envs.baseline_python import BASELINES
from repro.sustainability.impact import ImpactTracker


def _measure(fn):
    with ImpactTracker() as t:
        fn()
    return t.impact


def run(console_steps: int = 160_000, render_steps: int = 1600):
    env = make("CartPole-v1")
    batch = 64
    # warm-up compiles excluded from the measurement, as the paper excludes
    # C++ compile time (it is paid once per binary, not per experiment).
    # Must use the SAME static shapes as the measured calls (jit cache key).
    jax.block_until_ready(rollout_random(
        env, jax.random.PRNGKey(0), console_steps // batch, batch, False)[0])
    jax.block_until_ready(rollout_random(
        env, jax.random.PRNGKey(0), render_steps // batch, batch, True)[0])
    runner = PythonRunner(BASELINES["CartPole-v1"])

    out = {}
    for mode, steps in (("console", console_steps), ("graphical", render_steps)):
        render = mode == "graphical"
        cairl = _measure(lambda: jax.block_until_ready(
            rollout_random(env, jax.random.PRNGKey(1), steps // batch, batch, render)[0]))
        gym_steps = min(steps, 20_000 if not render else 400)
        gym = _measure(lambda: runner.run(gym_steps, render=render))
        gym = type(gym)(wall_s=gym.wall_s * steps / gym_steps,
                        cpu_s=gym.cpu_s * steps / gym_steps)  # scale to equal steps
        out[mode] = {
            "cairl_co2_kg": cairl.co2_kg, "gym_co2_kg": gym.co2_kg,
            "cairl_mwh": cairl.energy_mwh, "gym_mwh": gym.energy_mwh,
            "ratio": gym.co2_kg / max(cairl.co2_kg, 1e-12),
        }
    return out


def main(emit):
    r = run()
    for mode, row in r.items():
        emit(f"table2/{mode}/co2", row["cairl_co2_kg"] * 1e9,
             f"cairl={row['cairl_co2_kg']:.2e}kg gym={row['gym_co2_kg']:.2e}kg "
             f"ratio={row['ratio']:.1f}x (paper: {'20.9x' if mode == 'console' else '1.5e5x'})")
