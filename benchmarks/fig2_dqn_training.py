"""Fig. 2 reproduction: DQN wall-clock, CaiRL envs vs interpreted envs.

Paper: identical DQN (Table I), training until convergence; CaiRL cuts
~30 % of wall-clock because env stepping leaves the critical path. Here:
identical jitted learner, fixed step budget; execution model is the only
variable (compiled scan vs per-step interpreted host env).
"""
from __future__ import annotations

import time

import jax

from repro.configs.cairl_dqn import PAPER_TABLE_I
from repro.core import make
from repro.envs.baseline_python import BASELINES
from repro.rl.dqn import train_compiled, train_host
import dataclasses


def run(steps: int = 2000):
    env = make("CartPole-v1")
    cfg = dataclasses.replace(PAPER_TABLE_I, num_envs=1, learn_start=100)

    t0 = time.perf_counter()
    train_compiled(env, cfg, steps, jax.random.PRNGKey(0))
    cairl_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    train_host(BASELINES["CartPole-v1"], env, cfg, steps, jax.random.PRNGKey(0))
    gym_s = time.perf_counter() - t0

    return {"cairl_s": cairl_s, "gym_s": gym_s,
            "reduction": 1.0 - cairl_s / gym_s, "steps": steps}


def main(emit):
    r = run()
    emit("fig2/dqn_cartpole/cairl", r["cairl_s"] / r["steps"] * 1e6,
         f"total={r['cairl_s']:.2f}s")
    emit("fig2/dqn_cartpole/gym", r["gym_s"] / r["steps"] * 1e6,
         f"total={r['gym_s']:.2f}s; wallclock_reduction={r['reduction']*100:.0f}% (paper: ~30%)")
