"""Fig. 2 reproduction: DQN wall-clock, CaiRL envs vs interpreted envs.

Paper: identical DQN (Table I), training until convergence; CaiRL cuts
~30 % of wall-clock because env stepping leaves the critical path. Here:
identical jitted learner, fixed step budget; execution model is the only
variable, across three rungs of host involvement:

  gym      — per-step interpreted host env (the AI-Gym execution model);
  compiled — env/replay/learner compiled, but the training loop dispatches
             host-alternating chunks (`train_compiled`, several jits);
  fused    — the whole chunk is ONE donated device program
             (`train_compiled(fused=True)` via repro.train.fused): replay
             ring, optimizer state and key chain updated in place, zero
             host transfers inside the chunk (gated by analysis/audit).

Plus the fleet-scaling rows: `repro.train.fleet` vmaps the ENTIRE training
loop over a seeds axis, so a width-F sweep is one compiled batch. The
sublinearity claim — wall-clock(F) < F x wall-clock(1) — is recorded per
width (`speedup_vs_sequential`).

`python benchmarks/fig2_dqn_training.py --smoke --json BENCH_fig2.json`
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.cairl_dqn import PAPER_TABLE_I
from repro.core import make
from repro.envs.baseline_python import BASELINES
from repro.rl.dqn import train_compiled, train_host
from repro.train.fused import Fleet, fleet

FLEET_WIDTHS = (1, 2, 4, 8)


def _cfg(num_envs: int = 1):
    return dataclasses.replace(PAPER_TABLE_I, num_envs=num_envs,
                               learn_start=100)


def run(steps: int = 2000, include_host: bool = True):
    """The execution-model comparison (one row per rung, seconds)."""
    env = make("CartPole-v1")
    cfg = _cfg()
    rows = {"steps": steps}

    t0 = time.perf_counter()
    state, _, _ = train_compiled(env, cfg, steps, jax.random.PRNGKey(0),
                                 chunk=max(steps // 8, 1))
    jax.block_until_ready(state)
    rows["compiled_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    state, _, _ = train_compiled(env, cfg, steps, jax.random.PRNGKey(0),
                                 fused=True)
    jax.block_until_ready(state)
    rows["fused_s"] = time.perf_counter() - t0
    rows["fused_vs_compiled"] = rows["compiled_s"] / rows["fused_s"]

    if include_host:
        t0 = time.perf_counter()
        train_host(BASELINES["CartPole-v1"], env, cfg, steps,
                   jax.random.PRNGKey(0))
        rows["gym_s"] = time.perf_counter() - t0
        rows["reduction"] = 1.0 - rows["compiled_s"] / rows["gym_s"]
        rows["fused_reduction"] = 1.0 - rows["fused_s"] / rows["gym_s"]
    return rows


def run_fleet(steps: int = 500, widths=FLEET_WIDTHS):
    """Fleet-scaling rows: one vmapped batch per width (compile included —
    every width is a fresh program, exactly what a user-facing sweep pays).

    `speedup_vs_sequential` = (F x wall-clock(1)) / wall-clock(F); > 1 is
    the sublinearity claim (a fleet beats F sequential solo runs).
    """
    env = make("CartPole-v1")
    cfg = _cfg()
    rows = {"steps": steps, "widths": list(widths), "rows": []}
    per_run_s = None   # wall-clock of one sequential run (first width, /w)
    for w in widths:
        grid = Fleet(jnp.arange(w, dtype=jnp.int32),
                     jnp.full((w,), cfg.lr, jnp.float32))
        t0 = time.perf_counter()
        states, _ = fleet(env, grid, steps, algo="dqn", cfg=cfg)
        jax.block_until_ready(states)
        wall_s = time.perf_counter() - t0
        per_run_s = wall_s / w if per_run_s is None else per_run_s
        rows["rows"].append({
            "width": w,
            "wall_s": wall_s,
            "runs_per_s": w / wall_s,
            "speedup_vs_sequential": (w * per_run_s) / wall_s,
            "sublinear": wall_s < w * per_run_s or w == widths[0],
        })
    return rows


def main(emit):
    r = run()
    emit("fig2/dqn_cartpole/cairl", r["compiled_s"] / r["steps"] * 1e6,
         f"total={r['compiled_s']:.2f}s")
    emit("fig2/dqn_cartpole/fused", r["fused_s"] / r["steps"] * 1e6,
         f"total={r['fused_s']:.2f}s; vs_compiled={r['fused_vs_compiled']:.2f}x")
    emit("fig2/dqn_cartpole/gym", r["gym_s"] / r["steps"] * 1e6,
         f"total={r['gym_s']:.2f}s; wallclock_reduction={r['reduction']*100:.0f}% (paper: ~30%)")
    fl = run_fleet()
    for row in fl["rows"]:
        emit(f"fig2/fleet/width{row['width']}", row["wall_s"] * 1e3,
             f"{row['runs_per_s']:.2f} runs/s; "
             f"{row['speedup_vs_sequential']:.2f}x vs sequential")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=2000,
                    help="train steps per execution-model row")
    ap.add_argument("--fleet-steps", type=int, default=500,
                    help="train steps per fleet-scaling row")
    ap.add_argument("--widths", default=",".join(map(str, FLEET_WIDTHS)),
                    help="comma-separated fleet widths")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the rows as JSON (bench-json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small step counts for CI smoke / perf trajectory")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 256)
        args.fleet_steps = min(args.fleet_steps, 128)
    widths = tuple(int(w) for w in args.widths.split(",") if w.strip())

    print(f"devices: {len(jax.devices())} ({jax.default_backend()})  "
          f"steps={args.steps} fleet_steps={args.fleet_steps}")
    modes = run(args.steps)
    print(f"  gym (interpreted host env): {modes['gym_s']:7.2f}s")
    print(f"  compiled (host-alternating): {modes['compiled_s']:6.2f}s "
          f"(reduction {modes['reduction'] * 100:.0f}%, paper ~30%)")
    print(f"  fused (one donated program): {modes['fused_s']:6.2f}s "
          f"({modes['fused_vs_compiled']:.2f}x vs compiled, reduction "
          f"{modes['fused_reduction'] * 100:.0f}%)")
    fleet_rows = run_fleet(args.fleet_steps, widths)
    for row in fleet_rows["rows"]:
        tag = "sublinear" if row["sublinear"] else "LINEAR OR WORSE"
        print(f"  fleet width {row['width']:>2}: {row['wall_s']:6.2f}s "
              f"({row['runs_per_s']:.2f} runs/s, "
              f"{row['speedup_vs_sequential']:.2f}x vs sequential) [{tag}]")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "modes": modes,
                       "fleet": fleet_rows}, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
