"""Fault-tolerance cost: what does surviving failures actually cost?

Three numbers decide whether checkpointed rollouts are affordable:

  1. steady-state tax   — steps/s with the RolloutSupervisor snapshotting
                          vs the bare pool (same compiled step; the only
                          added work is the boundary gather + async write);
  2. snapshot cost      — per-snapshot gather/save wall time as a function
                          of the snapshot interval (amortization curve);
  3. recovery time      — wall time from an injected device loss to a
                          restored, stepping pool (propose_mesh + rebuild
                          + restore), plus the replay debt in steps.

Device residency is verified, not assumed: the supervised steady-state
step is the pool's own compiled step (the supervisor only intercepts on
the host), and its HLO must contain zero host-transfer instructions.

Run: PYTHONPATH=src python benchmarks/fig_fault.py [--smoke]
     [--batch 1024] [--steps 2000] [--json BENCH_fig_fault.json]
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import EnvPool
from repro.runtime import DeviceLossError, FaultInjector, RolloutSupervisor


def _actions(pool, steps: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    shape = (steps, pool.num_envs) + tuple(pool.action_space.shape)
    return rng.integers(0, pool.action_space.n, size=shape).astype(
        pool.action_space.dtype)


def run_steady(env: str, batch: int, steps: int, snapshot_every: int,
               ckpt_dir: str) -> Dict:
    """Supervised rollout throughput; snapshot_every=0 disables snapshots
    (the bare-pool baseline through the same supervisor host path)."""
    pool = EnvPool(env, batch)
    sup = RolloutSupervisor(pool, ckpt_dir, snapshot_every=snapshot_every)
    acts = _actions(pool, steps)
    sup.reset(seed=0)
    sup.step(acts[0])                      # warm the compiled step
    sup.reset(seed=0)
    t0 = time.perf_counter()
    for t in range(steps):
        obs, _, _, _ = sup.step(acts[t])
    jax.block_until_ready(obs)
    sup.manager.wait()                     # the tax includes joining writes
    wall = time.perf_counter() - t0
    return {
        "snapshot_every": snapshot_every,
        "snapshots": sup.snapshots,
        "steps_per_s": steps * batch / wall,
        "wall_s": wall,
    }


def run_snapshot_cost(env: str, batch: int, intervals: List[int],
                      ckpt_dir: str, reps: int = 5) -> List[Dict]:
    """Per-snapshot blocking cost (gather + atomic write) and the implied
    per-step amortized overhead at each interval."""
    pool = EnvPool(env, batch)
    sup = RolloutSupervisor(pool, ckpt_dir, snapshot_every=0)
    sup.reset(seed=0)
    sup.step(_actions(pool, 1)[0])
    sup.snapshot(blocking=True)            # warm the save path
    t0 = time.perf_counter()
    for _ in range(reps):
        sup.snapshot(blocking=True)
    per_snap = (time.perf_counter() - t0) / reps
    return [{"interval": k, "snapshot_s": per_snap,
             "amortized_ms_per_step": 1e3 * per_snap / k}
            for k in intervals]


def run_recovery(env: str, batch: int, ckpt_dir: str,
                 snapshot_every: int = 64) -> Dict:
    """Injected device loss mid-rollout: time from the raise to a restored
    pool that has re-stepped once, plus the replay debt (steps lost back
    to the snapshot boundary)."""
    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    pool = EnvPool(env, batch)
    sup = RolloutSupervisor(pool, ckpt_dir, snapshot_every=snapshot_every,
                            blocking_snapshots=True, injector=inj)
    acts = _actions(pool, snapshot_every + snapshot_every // 2 + 1)
    sup.reset(seed=0)
    for t in range(snapshot_every + snapshot_every // 2):
        sup.step(acts[t])
    t_kill = sup.t
    inj.schedule(1.0, "device_loss", 1)
    clk[0] = 2.0
    t0 = time.perf_counter()
    try:
        sup.step(acts[t_kill])
        raise AssertionError("device-loss fault did not fire")
    except DeviceLossError:
        sup.recover()
        obs, _, _, _ = sup.step(acts[sup.t])   # first post-recovery step
        jax.block_until_ready(obs)
    recovery_s = time.perf_counter() - t0
    return {
        "killed_at_step": t_kill,
        "restored_step": t_kill - t_kill % snapshot_every,
        "replay_debt_steps": t_kill % snapshot_every,
        "recovery_s": recovery_s,
    }


def check_device_resident(env: str, batch: int, ckpt_dir: str) -> List[str]:
    sup = RolloutSupervisor(EnvPool(env, batch), ckpt_dir)
    return host_transfer_ops(sup.step_lowered().compile().as_text())


def run(env: str = "CartPole-v1", batch: int = 1024, steps: int = 2000,
        intervals: List[int] = (16, 64, 256)) -> Dict:
    import tempfile

    transfers = check_device_resident(env, batch, tempfile.mkdtemp())
    rows = {
        "ckpt_off": run_steady(env, batch, steps, 0, tempfile.mkdtemp()),
        "ckpt_on": run_steady(env, batch, steps, max(intervals[0], 1),
                              tempfile.mkdtemp()),
        "recovery": run_recovery(env, batch, tempfile.mkdtemp()),
        "snapshot_cost": run_snapshot_cost(env, batch, list(intervals),
                                           tempfile.mkdtemp()),
    }
    on, off = rows["ckpt_on"], rows["ckpt_off"]
    on["overhead_pct"] = 100.0 * (1.0 - on["steps_per_s"] / off["steps_per_s"])
    return {"env": env, "batch": batch, "steps": steps,
            "host_transfers": len(transfers), "transfer_ops": transfers,
            "rows": rows}


def main(emit):
    out = run(batch=256, steps=400, intervals=[8, 32, 128])
    assert out["host_transfers"] == 0, out["transfer_ops"]
    for name in ("ckpt_off", "ckpt_on"):
        r = out["rows"][name]
        emit(f"fig_fault/{name}", 1e6 / r["steps_per_s"],
             f"steps_per_s={r['steps_per_s']:.0f};"
             f"snapshots={r['snapshots']}")
    rec = out["rows"]["recovery"]
    emit("fig_fault/recovery", rec["recovery_s"] * 1e3,
         f"recovery_s={rec['recovery_s']:.3f};"
         f"replay_debt={rec['replay_debt_steps']}")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="CartPole-v1")
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--smoke", action="store_true",
                    help="small run (batch 256 / 400 steps)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as JSON (bench-json)")
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.steps = 256, 400

    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")
    out = run(args.env, args.batch, args.steps)
    resident = ("device-resident" if out["host_transfers"] == 0
                else f"HOST TRANSFERS: {out['transfer_ops']}")
    off, on = out["rows"]["ckpt_off"], out["rows"]["ckpt_on"]
    print(f"   checkpoint off: {off['steps_per_s']:>12,.0f} steps/s")
    print(f"    checkpoint on: {on['steps_per_s']:>12,.0f} steps/s  "
          f"(every {on['snapshot_every']} steps, {on['snapshots']} snapshots, "
          f"{on['overhead_pct']:.1f}% tax)  [{resident}]")
    rec = out["rows"]["recovery"]
    print(f"  device-loss recovery: {rec['recovery_s']*1e3:.0f} ms "
          f"(+{rec['replay_debt_steps']} steps replay debt)")
    for row in out["rows"]["snapshot_cost"]:
        print(f"  snapshot every {row['interval']:>4}: "
              f"{row['snapshot_s']*1e3:7.1f} ms/snap  "
              f"{row['amortized_ms_per_step']:6.3f} ms/step amortized")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
