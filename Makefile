# Developer entry points. Everything runs from the repo root with src/ on
# PYTHONPATH (no package install).

PY := PYTHONPATH=src python

.PHONY: test test-fast test-async test-conformance test-fault test-train api-check lint analyze cost-check cost-baseline bench-smoke bench-json bench docs docs-check

test:
	$(PY) -m pytest -x -q

# Skip the heavy fused/pool sweeps and training-parity tests (marked `slow`)
# for a quick inner-loop signal; `make test` remains the tier-1 gate.
# Runs the API-surface snapshot first (a broken drop-in surface should fail
# in seconds, not after the whole sweep), then the static-analysis gate.
test-fast: api-check analyze
	$(PY) -m pytest -x -q -m "not slow"

# JAX-aware AST lint only (sub-second; the inner-inner loop).
lint:
	$(PY) -m repro.analysis.lint src

# Static-analysis gate: the AST lint over src/, the compiled-cost
# regression gate (cost-check), plus the registry-driven compiled-artifact
# audit (every env id x backend lowered and checked for zero host
# transfers, full carry donation, and bounded jit retraces).
# Fails on any unallowlisted violation; see docs/analysis.md.
analyze: lint cost-check
	$(PY) -m repro.analysis.audit --smoke --json BENCH_hlo_audit.json

# Compiled-cost regression gate: lower the donated step for the smoke
# matrix (vmap+pallas per id, plus the fused-train cells), extract static
# FLOPs / HBM bytes / peak live buffers per env step, and diff against the
# committed baseline with per-family thresholds. Zero timing noise: a PR
# only fails this if its *compiled artifact* got more expensive.
cost-check:
	$(PY) -m repro.analysis.cost --smoke --check BENCH_cost_baseline.json --table

# Regenerate the committed cost baseline after an *intentional* cost
# change; the diff is the review artifact.
cost-baseline:
	$(PY) -m repro.analysis.cost --smoke --regen-baseline BENCH_cost_baseline.json

# CI gate: the public exports of repro / repro.core / repro.pool / cairl
# match the checked-in snapshot (tests/test_api_surface.py) — refactors
# cannot silently break the drop-in surface.
api-check:
	$(PY) -m pytest -x -q tests/test_api_surface.py

# Async env serving: the traffic-replay determinism harness, the shared
# slot-table unit tests, and the async rows of the conformance/golden
# sweeps (send/recv parity with the lock-step engine for every env id).
test-async:
	$(PY) -m pytest -x -q tests/test_async_pool.py tests/test_slots.py
	$(PY) -m pytest -x -q tests/test_conformance.py tests/test_golden.py \
		-k "async"

# Fault-tolerance harness: checkpoint atomicity under injected mid-save
# kills, heartbeat/straggler detection, supervised kill-and-resume golden
# sweeps, EnvService eviction/drain/restore, and (slow, subprocess) the
# multi-device device-loss re-mesh proof.
test-fault:
	$(PY) -m pytest -x -q tests/test_checkpoint.py tests/test_failures.py \
		tests/test_supervisor.py

# Fused on-device training + fleets: the training-parity harness
# (tests/test_train_fused.py — committed 64-step goldens, fused ≡
# host-alternating bit-parity, chunk-size invariance, fleet-vs-solo
# determinism) plus the hypothesis drivers when hypothesis is installed.
# The fast parity subset also rides in `make test-fast`; the fleet /
# interleaving sweeps are marked `slow`. Regenerate the training goldens
# (host-alternating path only) with
#   $(PY) -m pytest tests/test_train_fused.py --regen-golden
test-train:
	$(PY) -m pytest -x -q tests/test_train_fused.py tests/test_train_property.py

# Registry-driven conformance: every registered env id × every backend
# (python baseline / vmap / fused / pool) + the committed golden traces.
# After an intentional dynamics change, regenerate the goldens with
#   $(PY) -m pytest tests/test_golden.py --regen-golden
test-conformance:
	$(PY) -m pytest -x -q tests/test_conformance.py tests/test_golden.py

# Fast end-to-end benchmark smoke: pool scaling sweep + HLO device-residency
# check (the fig4 acceptance gate), small step counts — and the JSON perf
# record so the trajectory across PRs is captured.
bench-smoke: bench-json

# Machine-readable perf record: fig1 (steps/s per backend, vmap vs fused
# pallas megastep), fig2 (DQN training wall-clock: gym vs compiled vs
# fused one-program training, plus fleet-scaling sublinearity rows),
# fig4 (batch/device scaling), fig_async (continuous slot refill vs
# lock-step wave serving), fig_fault (checkpointing tax, snapshot
# amortization, device-loss recovery time), the HLO audit (per-id
# residency/donation/flops rows + the fused-train cells), the static cost
# report (as BENCH_cost_baseline-candidate.json, so regenerating the
# committed baseline is a reviewed diff) and table2 (measured + static
# joules/gCO₂ per million steps), all in smoke mode.
bench-json:
	$(PY) benchmarks/fig1_env_throughput.py --smoke --json BENCH_fig1.json
	$(PY) benchmarks/fig2_dqn_training.py --smoke --json BENCH_fig2.json
	$(PY) benchmarks/fig4_pool_scaling.py --steps 300 --batches 1,64,1024 \
		--json BENCH_fig4.json
	$(PY) benchmarks/fig_async.py --smoke --json BENCH_fig_async.json
	$(PY) benchmarks/fig_fault.py --smoke --json BENCH_fig_fault.json
	$(PY) -m repro.analysis.audit --smoke --json BENCH_hlo_audit.json
	$(PY) -m repro.analysis.cost --smoke --json BENCH_cost_baseline-candidate.json
	$(PY) benchmarks/table2_carbon.py --smoke \
		--static-from BENCH_cost_baseline-candidate.json --json BENCH_table2.json

# Full paper-figure reproduction (CSV to stdout; slow).
bench:
	$(PY) -m benchmarks.run

# Regenerate the env gallery from the registry.
docs:
	$(PY) docs/gen_environments.py

# CI gate: every id in repro.core.registry is documented in docs/environments.md.
docs-check:
	$(PY) docs/gen_environments.py --check
