# Developer entry points. Everything runs from the repo root with src/ on
# PYTHONPATH (no package install).

PY := PYTHONPATH=src python

.PHONY: test bench-smoke bench docs docs-check

test:
	$(PY) -m pytest -x -q

# Fast end-to-end benchmark smoke: pool scaling sweep + HLO device-residency
# check (the fig4 acceptance gate), small step counts.
bench-smoke:
	$(PY) benchmarks/fig4_pool_scaling.py --steps 300 --batches 1,64,1024

# Full paper-figure reproduction (CSV to stdout; slow).
bench:
	$(PY) -m benchmarks.run

# Regenerate the env gallery from the registry.
docs:
	$(PY) docs/gen_environments.py

# CI gate: every id in repro.core.registry is documented in docs/environments.md.
docs-check:
	$(PY) docs/gen_environments.py --check
