"""End-to-end driver: DQN on CartPole-v1 with compiled environments.

Reproduces the paper's §V-B result shape on this host: the paper's Table I
hyperparameters train ~30 % faster on CaiRL envs than on interpreted envs;
the tuned config solves CartPole (500/500) in under a minute of wall-clock.

Run: PYTHONPATH=src python examples/train_dqn_cartpole.py [--steps 60000]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.cairl_dqn import TUNED
from repro.core import make
from repro.rl.dqn import greedy_returns, train_compiled
from repro.sustainability.impact import ImpactTracker

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60000)
args = ap.parse_args()

env = make("CartPole-v1")
print(f"training DQN (tuned config) for {args.steps} compiled steps ...")
with ImpactTracker() as tracker:
    t0 = time.time()
    state, apply_fn, metrics = train_compiled(env, TUNED, args.steps,
                                              jax.random.PRNGKey(0), chunk=10000)
    train_s = time.time() - t0

rets = np.asarray(greedy_returns(env, apply_fn, state.params, jax.random.PRNGKey(7)))
print(f"wall-clock        : {train_s:.1f}s "
      f"({args.steps * TUNED.num_envs / train_s:,.0f} transitions/s incl. learning)")
print(f"train return (ema): {float(metrics['return'][-1]):.1f}")
print(f"greedy eval return: {rets.mean():.1f} ± {rets.std():.1f}  (solved = 500)")
print(f"energy            : {tracker.impact.energy_mwh:.3f} mWh, "
      f"CO2 {tracker.impact.co2_kg:.2e} kg (impact tracker, Table II method)")
