"""Train a ~100M-param LM for a few hundred steps on the synthetic pipeline.

Uses the xlstm-350m family at reduced width (fits CPU) — swap --arch for any
of the 10 assigned architectures. Loss must drop well below uniform log(V).

Run: PYTHONPATH=src python examples/train_lm.py [--arch yi-6b] [--steps 200]
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="yi-6b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

# examples stay thin: the real driver is the launcher
sys.exit(subprocess.call([
    sys.executable, "-m", "repro.launch.train",
    "--arch", args.arch, "--steps", str(args.steps),
    "--batch", str(args.batch), "--seq", str(args.seq),
    "--ckpt-dir", "/tmp/repro_lm_ckpt", "--ckpt-every", "100",
], env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}))
