"""Quickstart: the paper's Listing 2, verbatim shape, plus the compiled fast path.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro import cairl  # <- the one-line migration the paper advertises

# ---- Listing 2: classic Gym loop (drop-in) ---------------------------------
e = cairl.make("CartPole-v1")          # was: gym.make("CartPole-v1")
for ep in range(3):
    e.reset()
    term, steps, ret = False, 0, 0.0
    while not term and steps < 200:
        steps += 1
        s1, r, term, info = e.step(e.action_space.sample())
        obs = e.render()
        ret += r
    print(f"episode {ep}: {steps} steps, return {ret:.0f}, frame {obs.shape}")

# ---- make_vec: batched Gym-style stepping, state lives on device ------------
# The unified vector frontend: one constructor for every pool backend
# (backend="auto" picks the fused megastep engine when the id supports it).
pool = cairl.make_vec("CartPole-v1", 256, backend="vmap")
obs = pool.reset(seed=0)                       # (256, 4), device-resident
for i in range(100):
    obs, rew, done, info = pool.step(pool.sample_actions(i))
print(f"\nEnvPool: stepped {pool.num_envs} envs 100x; "
      f"mean reward {float(rew.mean()):.2f}, {int(done.sum())} resets this step")

# ---- the run() fast path: whole rollout as ONE device program ---------------
steps, batch = 2000, 256
rew, episodes, _ = pool.rollout(steps, jax.random.PRNGKey(0))  # compile
jax.block_until_ready(rew)
t0 = time.perf_counter()
rew, episodes, _ = pool.rollout(steps, jax.random.PRNGKey(1))
jax.block_until_ready(rew)
dt = time.perf_counter() - t0
print(f"compiled rollout: {steps * batch:,} env steps in {dt:.3f}s "
      f"= {steps * batch / dt:,.0f} steps/s across {batch} envs")
print(f"episodes completed on-device: {int(episodes.sum())}")
