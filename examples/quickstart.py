"""Quickstart: the paper's Listing 2, verbatim shape, plus the compiled fast path.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro import cairl  # <- the one-line migration the paper advertises

# ---- Listing 2: classic Gym loop (drop-in) ---------------------------------
e = cairl.make("CartPole-v1")          # was: gym.make("CartPole-v1")
for ep in range(3):
    e.reset()
    term, steps, ret = False, 0, 0.0
    while not term and steps < 200:
        steps += 1
        s1, r, term, info = e.step(e.action_space.sample())
        obs = e.render()
        ret += r
    print(f"episode {ep}: {steps} steps, return {ret:.0f}, frame {obs.shape}")

# ---- the run() fast path: whole rollout as ONE device program ---------------
env = cairl.make_functional("CartPole-v1")
steps, batch = 2000, 256
key = jax.random.PRNGKey(0)
rew, episodes, _ = cairl.rollout_random(env, key, steps, batch)  # compile
jax.block_until_ready(rew)
t0 = time.perf_counter()
rew, episodes, _ = cairl.rollout_random(env, jax.random.PRNGKey(1), steps, batch)
jax.block_until_ready(rew)
dt = time.perf_counter() - t0
print(f"\ncompiled rollout: {steps * batch:,} env steps in {dt:.3f}s "
      f"= {steps * batch / dt:,.0f} steps/s across {batch} envs")
print(f"episodes completed on-device: {int(episodes.sum())}")
