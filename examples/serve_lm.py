"""Serve a small LM with batched requests (continuous slot batching).

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import lm
from repro.serving.engine import Request, ServeEngine

cfg = get_config("yi-6b", reduced=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, slots=4, max_seq=128)

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(4, 24))),
            max_new_tokens=16)
    for i in range(12)
]
for r in requests:
    engine.submit(r)

t0 = time.time()
engine.run(max_ticks=500)
dt = time.time() - t0
total_tokens = sum(len(r.output) for r in requests)
print(f"served {len(requests)} requests / {total_tokens} tokens in {dt:.2f}s "
      f"({total_tokens / dt:,.1f} tok/s on CPU, 4-slot continuous batching)")
for r in requests[:3]:
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output[:8]}...")
