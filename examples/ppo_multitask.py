"""PPO on the Multitask environment (the paper's flagship Flash game, §IV-C).

Rollout collection runs as one compiled program per update (the `run()`
fast path); shows the learning signal well above the random baseline.

Run: PYTHONPATH=src python examples/ppo_multitask.py [--updates 40]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import make
from repro.pool import make_vec
from repro.rl.ppo import PPOConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--updates", type=int, default=40)
args = ap.parse_args()

env = make("Multitask-v0")

rew, eps, _ = make_vec(env, 16).rollout(2000, jax.random.PRNGKey(1))
random_return = float(rew.sum() / max(int(eps.sum()), 1))
print(f"random policy return: {random_return:.1f}")

cfg = PPOConfig(num_envs=16, rollout_len=128, epochs=3, minibatches=4, lr=3e-4)
t0 = time.time()
state, metrics = train(env, cfg, args.updates, jax.random.PRNGKey(0))
rets = np.asarray(metrics["return"])
print(f"PPO {args.updates} updates in {time.time()-t0:.1f}s "
      f"({args.updates * cfg.num_envs * cfg.rollout_len / (time.time()-t0):,.0f} steps/s)")
print(f"return trajectory: first {rets[0]:.1f} -> best {rets.max():.1f} "
      f"(alive-bonus env; higher = survives longer)")
