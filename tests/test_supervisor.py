"""RolloutSupervisor: kill-and-resume against the committed goldens.

The fault-tolerance contract is bit-identity, not "roughly resumes": a
supervised rollout that is killed mid-flight, re-meshed and restored must
produce EXACTLY the trajectory the uninterrupted run would have — proven
here against the same committed 32-step checksums (tests/golden/) that pin
the dynamics, for lock-step pools, the async send/recv engine, and (in a
subprocess with 8 fake devices) a real 2-device -> 1-device re-mesh.

Also here: the EnvService graceful-degradation paths — injected client
stalls -> exponential backoff -> eviction -> reconnect resumes the episode
bit-exactly, and drain-to-checkpoint -> restore-service preserves every
in-flight session against an uninterrupted oracle service.
"""
import json
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import make
from repro.core.spaces import sample_batch
from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import AsyncEnvPool, EnvPool
from repro.runtime import (DeviceLossError, FaultInjector, HeartbeatMonitor,
                           RolloutSupervisor)
from repro.serving.env_service import EnvService, Session

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
STEPS = 32
BATCH = 2
KILL_AT = 20        # mid-flight, after the step-16 snapshot
SNAP_EVERY = 8

# one classic-control id, one procedural grid id, one continuous-action id
LOCKSTEP_IDS = ["CartPole-v1", "Maze-v0", "Pendulum-v1"]
ASYNC_ID = "FrozenLake-v0"


def _golden_rows(name):
    want = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    return np.asarray(want["rows"], np.float64)


def _row(obs, rew, done):
    return [float(np.asarray(obs, np.float64).sum()),
            float(np.asarray(rew, np.float64).sum()),
            int(np.asarray(done).sum())]


@pytest.mark.slow           # full 3-id golden sweep; the async variant below
@pytest.mark.parametrize("name", LOCKSTEP_IDS)  # stays in the fast loop
def test_kill_and_resume_matches_golden_lockstep(name, tmp_path):
    """save -> injected device loss -> recover() -> restore resumes the
    exact committed golden trajectory (EnvPool.step(key=) replays the
    golden trace's per-step key chain deterministically)."""
    env = make(name)
    key = jax.random.PRNGKey(sum(map(ord, name)))
    acts = [sample_batch(env.action_space, jax.random.fold_in(key, 1000 + t),
                         BATCH) for t in range(STEPS)]

    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    sup = RolloutSupervisor(EnvPool(env, BATCH), str(tmp_path),
                            snapshot_every=SNAP_EVERY,
                            blocking_snapshots=True, injector=inj)
    sup.reset(seed=sum(map(ord, name)))
    rows = [None] * STEPS
    t = 0
    killed = False
    while t < STEPS:
        if t == KILL_AT and not killed:
            inj.schedule(0.5, "device_loss", 1)
            clk[0] = 1.0
        try:
            obs, rew, done, _ = sup.step(acts[t],
                                         key=jax.random.fold_in(key, t))
        except DeviceLossError:
            assert not killed, "fault fired twice"
            killed = True
            plan = sup.recover()
            assert plan["restored_step"] == (KILL_AT // SNAP_EVERY) * SNAP_EVERY
            t = sup.t           # rewind the deterministic stream
            continue
        rows[t] = _row(obs, rew, done)
        t += 1
    assert killed and sup.recoveries == 1
    np.testing.assert_allclose(
        np.asarray(rows, np.float64), _golden_rows(name),
        rtol=1e-4, atol=1e-4,
        err_msg=f"{name}: kill-and-resume trajectory drifted from the "
                "committed golden trace")


def test_kill_and_resume_matches_golden_async(tmp_path):
    """The same proof through the async engine's send/recv: the supervisor
    snapshots the whole slot table (active mask + key chains) and the
    restored pool replays the golden recv-key stream bit-identically."""
    name = ASYNC_ID
    env = make(name)
    key = jax.random.PRNGKey(sum(map(ord, name)))
    acts = [np.asarray(sample_batch(env.action_space,
                                    jax.random.fold_in(key, 1000 + t), BATCH))
            for t in range(STEPS)]

    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    sup = RolloutSupervisor(AsyncEnvPool(env, BATCH), str(tmp_path),
                            snapshot_every=SNAP_EVERY,
                            blocking_snapshots=True, injector=inj)
    sup.reset(seed=sum(map(ord, name)))
    rows = [None] * STEPS
    t = 0
    killed = False
    while t < STEPS:
        if t == KILL_AT and not killed:
            inj.schedule(0.5, "device_loss", 1)
            clk[0] = 1.0
        try:
            sup.send(acts[t], np.arange(BATCH))
        except DeviceLossError:
            assert not killed
            killed = True
            sup.recover()
            t = sup.t
            continue
        obs, rew, done, _, _ = sup.recv(key=jax.random.fold_in(key, t))
        rows[t] = _row(obs, rew, done)
        t += 1
    assert killed and sup.recoveries == 1
    np.testing.assert_allclose(
        np.asarray(rows, np.float64), _golden_rows(name),
        rtol=1e-4, atol=1e-4,
        err_msg=f"{name}: async kill-and-resume drifted from the committed "
                "golden trace")


def test_async_snapshot_refuses_inflight_actions(tmp_path):
    pool = AsyncEnvPool("CartPole-v1", 2)
    pool.reset(seed=0)
    pool.send(np.zeros(2, np.int32), np.arange(2))
    with pytest.raises(RuntimeError, match="in flight"):
        pool.state_dict()
    pool.recv(key=jax.random.PRNGKey(0))
    pool.state_dict()  # step boundary: fine


def test_monitor_times_out_host_killed_by_injector(tmp_path):
    """A scripted "host_death" silences that host's heartbeat relay; the
    monitor times it out exactly like a real silence and sizes recovery."""
    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    mon = HeartbeatMonitor(4, timeout_s=5.0, clock=lambda: clk[0])
    sup = RolloutSupervisor(EnvPool("CartPole-v1", 4), str(tmp_path),
                            snapshot_every=4, blocking_snapshots=True,
                            injector=inj, monitor=mon)
    sup.reset(seed=0)
    for _ in range(4):
        sup.step(np.zeros(4, np.int32))
    assert mon.healthy()
    inj.schedule(1.0, "host_death", 3)
    clk[0] = 2.0
    sup.step(np.zeros(4, np.int32))      # fault consumed: host 3 goes silent
    clk[0] = 10.0                        # > timeout since host 3's last beat
    sup.step(np.zeros(4, np.int32))
    assert mon.dead_hosts() == [3]
    plan = sup.recover()                 # sized from the 3 survivors
    assert plan["n_devices"] >= 1        # clamped to real local devices
    assert "3" in plan["notes"]


def test_supervised_step_path_stays_device_resident(tmp_path):
    """Snapshots gather at boundaries; the compiled steady-state step the
    supervisor drives must still contain zero host-transfer ops."""
    sup = RolloutSupervisor(EnvPool("CartPole-v1", 8), str(tmp_path))
    hlo = sup.step_lowered().compile().as_text()   # pool passthrough
    assert host_transfer_ops(hlo) == []


def test_snapshot_roundtrips_through_fresh_pool(tmp_path):
    """Restore into a brand-new pool (the host-died-and-came-back path):
    continuation is bit-identical to the original pool's continuation."""
    key = jax.random.PRNGKey(3)
    sup = RolloutSupervisor(EnvPool("MountainCar-v0", 4), str(tmp_path),
                            snapshot_every=5, blocking_snapshots=True)
    sup.reset(seed=3)
    for t in range(5):
        sup.step(np.zeros(4, np.int32), key=jax.random.fold_in(key, t))
    ref = [np.asarray(sup.step(np.zeros(4, np.int32),
                               key=jax.random.fold_in(key, t))[0]).copy()
           for t in range(5, 8)]
    sup2 = RolloutSupervisor(EnvPool("MountainCar-v0", 4), str(tmp_path))
    sup2.restore()
    assert sup2.t == 5
    for t in range(5, 8):
        obs, *_ = sup2.step(np.zeros(4, np.int32),
                            key=jax.random.fold_in(key, t))
        np.testing.assert_array_equal(np.asarray(obs), ref[t - 5])


# -- elastic re-mesh (subprocess: needs >1 device) -----------------------------

_REMESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, numpy as np
from repro.pool import EnvPool, ShardedEnvPool
from repro.runtime import DeviceLossError, FaultInjector, RolloutSupervisor

B, SNAP, KILL, END = 8, 8, 12, 16
key = jax.random.PRNGKey(0)
d = tempfile.mkdtemp()
clk = [0.0]
inj = FaultInjector(clock=lambda: clk[0])
mesh2 = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
pool = ShardedEnvPool("CartPole-v1", B, mesh=mesh2)
sup = RolloutSupervisor(pool, d, snapshot_every=SNAP,
                        blocking_snapshots=True, injector=inj)
sup.reset(seed=0)
devices_before = len(set(sup.pool.reset(seed=0).sharding.device_set))
sup.reset(seed=0)
for t in range(KILL):
    sup.step(np.zeros(B, np.int32), key=jax.random.fold_in(key, t))

# oracle: load the step-8 snapshot into a plain single-device EnvPool and
# replay 8..16 (a 1-device mesh is bit-identical to EnvPool by contract)
oracle = EnvPool("CartPole-v1", B)
osup = RolloutSupervisor(oracle, d)
osup.restore(step=SNAP)
ref = []
for t in range(SNAP, END):
    obs, *_ = osup.step(np.zeros(B, np.int32), key=jax.random.fold_in(key, t))
    ref.append(np.asarray(obs).copy())

inj.schedule(1.0, "device_loss", 1)
clk[0] = 2.0
try:
    sup.step(np.zeros(B, np.int32), key=jax.random.fold_in(key, KILL))
    raise SystemExit("expected DeviceLossError")
except DeviceLossError:
    plan = sup.recover(n_devices=1)   # survivors: one device
got = []
for t in range(sup.t, END):
    obs, *_ = sup.step(np.zeros(B, np.int32), key=jax.random.fold_in(key, t))
    got.append(np.asarray(obs).copy())
devices_after = len(sup.pool.mesh.devices.flatten())

bit_identical = all(np.array_equal(a, b) for a, b in zip(ref, got))
print(json.dumps({
    "devices_before": devices_before,
    "devices_after": devices_after,
    "restored_step": plan["restored_step"],
    "mesh_shape": list(plan["mesh_shape"]),
    "bit_identical": bool(bit_identical),
}))
"""


@pytest.mark.slow
def test_device_loss_remesh_resumes_bit_identically():
    """2-device sharded rollout -> injected device loss -> propose_mesh over
    the 1 survivor -> restore: continuation equals the single-device oracle
    bit-for-bit (8 fake CPU devices, subprocess)."""
    out = subprocess.run([sys.executable, "-c", _REMESH_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices_before"] == 2
    assert res["devices_after"] == 1
    assert res["restored_step"] == 8
    assert res["bit_identical"]


def _env():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return env


# -- EnvService graceful degradation ------------------------------------------

def _pol(obs, t):
    return np.int32(t % 2)


def test_service_stall_backoff_then_eviction_then_reconnect():
    """Injected client stalls: exponential backoff idles the lane, repeated
    misses evict it (lane parked off-device), reconnect() resumes the
    episode so the final result matches an undisturbed solo session."""
    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    svc = EnvService("CartPole-v1", 2, clock=lambda: clk[0], injector=inj,
                     max_retries=2)
    for i in range(3):
        svc.submit(Session(sid=i, seed=i, num_steps=12, policy=_pol))
    for _ in range(3):
        svc.tick()
    for at in (1.0, 2.0, 3.0):   # 3 misses > max_retries=2 -> eviction
        inj.schedule(at, "stall", 1)
    t = 0
    while 1 not in svc._evicted and t < 40:
        clk[0] += 1.0
        svc.tick()
        t += 1
    assert svc.evicted == [1]
    assert svc._sessions[1].steps < 12
    assert svc.stats()["timeouts"] == 3
    assert "timeout" in svc.eviction_log[1]

    svc.run(max_ticks=200)       # others finish; slot 1 was refilled
    assert svc._sessions[0].steps == 12
    assert svc._sessions[2].steps == 12
    svc.reconnect(1)
    svc.run(max_ticks=200)
    assert svc._sessions[1].steps == 12

    solo = EnvService("CartPole-v1", 2, clock=lambda: clk[0])
    solo.submit(Session(sid=1, seed=1, num_steps=12, policy=_pol))
    solo.run()
    assert svc._sessions[1].total_reward == solo._sessions[1].total_reward
    assert svc._sessions[1].episodes == solo._sessions[1].episodes


def test_service_slow_client_times_out_via_clock():
    """A measured action round-trip over `action_timeout_s` counts as a
    miss even without an injector (the action is stale: discarded)."""
    clk = [0.0]

    def slow_policy(obs, t):
        clk[0] += 2.0            # the client "takes" 2s to answer
        return np.int32(0)

    svc = EnvService("CartPole-v1", 1, clock=lambda: clk[0],
                     action_timeout_s=1.0, max_retries=1)
    svc.submit(Session(sid=0, seed=0, num_steps=5, policy=slow_policy))
    for _ in range(8):
        svc.tick()
    assert svc.evicted == [0]
    assert svc._sessions[0].steps == 0   # no stale action was ever applied


def test_service_drain_to_checkpoint_and_restore_matches_oracle(tmp_path):
    """Service restart preserves every in-flight session: drain to a
    checkpoint mid-serve, rebuild from it, finish — results identical to an
    uninterrupted oracle service (same sessions, same slots, same order)."""
    clk = [0.0]
    svc = EnvService("CartPole-v1", 2, clock=lambda: clk[0])
    for i in range(4):
        svc.submit(Session(sid=i, seed=i, num_steps=10, policy=_pol))
    for _ in range(4):
        svc.tick()
    mid_steps = {i: svc._sessions[i].steps for i in range(4)}
    assert any(v > 0 for v in mid_steps.values())
    assert any(v == 0 for v in mid_steps.values())  # some still queued
    with CheckpointManager(str(tmp_path)) as mgr:
        svc.drain_to_checkpoint(mgr, step=svc.ticks)
    with pytest.raises(RuntimeError, match="draining"):
        svc.submit(Session(sid=99, seed=9, num_steps=3))

    fresh = [Session(sid=i, seed=i, num_steps=10, policy=_pol)
             for i in range(4)]
    svc2 = EnvService.restore_service(
        "CartPole-v1", 2, CheckpointManager(str(tmp_path)), fresh,
        clock=lambda: clk[0])
    assert {i: svc2._sessions[i].steps for i in range(4)} == mid_steps
    svc2.run(max_ticks=200)

    oracle = EnvService("CartPole-v1", 2, clock=lambda: clk[0])
    for i in range(4):
        oracle.submit(Session(sid=i, seed=i, num_steps=10, policy=_pol))
    oracle.run(max_ticks=200)
    for i in range(4):
        a, b = svc2._sessions[i], oracle._sessions[i]
        assert (a.steps, a.total_reward, a.episodes) == \
               (b.steps, b.total_reward, b.episodes), i


def test_service_restore_preserves_default_policy_rng(tmp_path):
    """Un-scripted clients sample from a numpy generator; its bit-state is
    checkpointed, so even random-policy sessions resume bit-exactly."""
    clk = [0.0]
    svc = EnvService("FrozenLake-v0", 2, clock=lambda: clk[0])
    for i in range(2):
        svc.submit(Session(sid=i, seed=100 + i, num_steps=9))
    for _ in range(5):
        svc.tick()
    with CheckpointManager(str(tmp_path)) as mgr:
        svc.drain_to_checkpoint(mgr, step=5)
    svc2 = EnvService.restore_service(
        "FrozenLake-v0", 2, CheckpointManager(str(tmp_path)),
        [Session(sid=i, seed=100 + i, num_steps=9) for i in range(2)],
        clock=lambda: clk[0])
    svc2.run(max_ticks=100)
    oracle = EnvService("FrozenLake-v0", 2, clock=lambda: clk[0])
    for i in range(2):
        oracle.submit(Session(sid=i, seed=100 + i, num_steps=9))
    oracle.run(max_ticks=100)
    for i in range(2):
        a, b = svc2._sessions[i], oracle._sessions[i]
        assert (a.total_reward, a.episodes) == (b.total_reward, b.episodes)


def test_service_restore_rejects_missing_sessions_and_bad_slots(tmp_path):
    clk = [0.0]
    svc = EnvService("CartPole-v1", 2, clock=lambda: clk[0])
    svc.submit(Session(sid=0, seed=0, num_steps=5, policy=_pol))
    svc.tick()
    with CheckpointManager(str(tmp_path)) as mgr:
        svc.drain_to_checkpoint(mgr, step=1)
    with pytest.raises(ValueError, match="missing"):
        EnvService.restore_service("CartPole-v1", 2,
                                   CheckpointManager(str(tmp_path)), [],
                                   clock=lambda: clk[0])
    with pytest.raises(ValueError, match="slots"):
        EnvService.restore_service(
            "CartPole-v1", 4, CheckpointManager(str(tmp_path)),
            [Session(sid=0, seed=0, num_steps=5, policy=_pol)],
            clock=lambda: clk[0])
