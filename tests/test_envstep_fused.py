"""Fused megastep scenario tests: kernels/envstep vs K iterated vmap steps.

The contract (docs/pool.md): for every fused-capable env, `fused_step` /
`EnvPool(backend=...)` must reproduce the scan-of-vmap-step path — exact for
int/bool fields (done, board states, step counters), <=1e-5 for floats —
including auto-reset boundaries and time-limit truncation. The Pallas kernel
runs under interpret=True here (CPU host); the jnp reference covers the
dispatch path compiled rollouts use off-TPU.

The per-id random-action parity sweep is registry-driven and lives in
tests/test_conformance.py (`test_backend_parity`) — every registered id
inherits it, nothing is hand-listed. This module keeps the *scenario*
cases: specific truncation/termination timings, pool chunk seams, HLO
residency and RL training parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_leaves_match, vmap_reference

from repro.core import make
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset, TimeLimit, Vec
from repro.envs.classic import CartPole, MountainCar
from repro.envs.puzzle import LightsOut
from repro.kernels.envstep import fused_step
from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import EnvPool, ShardedEnvPool, default_pool_mesh, make_pool

# The whole module is the heavy fused/pool sweep — skipped by
# `make test-fast`, run by tier-1 `make test`.
pytestmark = pytest.mark.slow

BACKENDS = ("jnp", "pallas_interpret")


def _check_parity(env, num_envs, key, actions, backend):
    st0, st_ref, obs_r, rew_r, done_r, tobs_r = vmap_reference(
        env, num_envs, key, actions)
    st_f, ts = fused_step(env, st0, actions, backend=backend)
    assert_leaves_match((obs_r, rew_r, done_r, tobs_r),
                        (ts.obs, ts.reward, ts.done, ts.info["terminal_obs"]))
    assert_leaves_match(st_ref, st_f)
    return done_r


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_autoreset_boundary(backend):
    """CartPole under always-right falls over well inside K: re-entry fires."""
    env = TimeLimit(CartPole(), 500)
    k, num_envs = 40, 6
    actions = jnp.ones((k, num_envs), jnp.int32)
    done = _check_parity(env, num_envs, jax.random.PRNGKey(1), actions, backend)
    assert int(np.asarray(done).sum()) >= num_envs  # every env reset >= once


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_timelimit_truncation(backend):
    """A 7-step TimeLimit truncates twice inside K=20: counter reset + done."""
    env = TimeLimit(MountainCar(), 7)
    k, num_envs = 20, 6
    actions = jnp.zeros((k, num_envs), jnp.int32)
    done = _check_parity(env, num_envs, jax.random.PRNGKey(2), actions, backend)
    assert int(np.asarray(done).sum()) == 2 * num_envs


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_lightsout_terminal_and_truncation(backend):
    """Integer bitboard env: solves (1-press scramble) and truncates."""
    env = TimeLimit(LightsOut(scramble_presses=1), 5)
    k, num_envs = 17, 6
    key = jax.random.PRNGKey(3)
    actions = jnp.stack([jnp.full((num_envs,), t % 25, jnp.int32)
                         for t in range(k)])
    done = _check_parity(env, num_envs, key, actions, backend)
    assert int(np.asarray(done).sum()) > 0


def test_unsupported_env_raises():
    with pytest.raises(ValueError, match="fused megastep"):
        EnvPool("Multitask-v0", 4, backend="pallas")
    env = make("Multitask-v0")
    venv = Vec(AutoReset(env), 4)
    state, _ = venv.reset(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        env.fused_step(state, jnp.zeros((3, 4), jnp.int32))


def test_pool_fused_rollout_matches_vmap():
    """EnvPool(backend fused, unroll) rollout == vmap rollout, including a
    remainder chunk (50 = 3*16 + 2)."""
    key = jax.random.PRNGKey(7)
    rew_v, eps_v, _ = EnvPool("CartPole-v1", 8).rollout(50, key)
    rew_f, eps_f, _ = EnvPool("CartPole-v1", 8, backend="jnp",
                              unroll=16).rollout(50, key)
    np.testing.assert_allclose(np.asarray(rew_v), np.asarray(rew_f),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_v), np.asarray(eps_f))
    assert int(np.asarray(eps_v).sum()) > 0  # autoresets crossed chunk seams


def test_pool_fused_stateful_matches_vmap():
    p_v = EnvPool("CartPole-v1", 4)
    p_f = EnvPool("CartPole-v1", 4, backend="jnp")
    np.testing.assert_array_equal(np.asarray(p_v.reset(0)),
                                  np.asarray(p_f.reset(0)))
    for i in range(30):
        a = p_v.sample_actions(i)
        out_v, out_f = p_v.step(a), p_f.step(a)
        for x, y in zip(out_v[:3], out_f[:3]):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out_v[3]["terminal_obs"]),
                                   np.asarray(out_f[3]["terminal_obs"]),
                                   rtol=1e-5, atol=1e-6)


def test_step_many_vmap_engine_matches_fused():
    """xla().step_many exists on both engines and agrees across them."""
    key = jax.random.PRNGKey(11)
    h_v = EnvPool("Pendulum-v1", 4).xla()
    h_f = EnvPool("Pendulum-v1", 4, backend="jnp").xla()
    ps_v, ps_f = h_v.init(key), h_f.init(key)
    acts = jnp.stack([sample_batch(make("Pendulum-v1").action_space,
                                   jax.random.fold_in(key, i), 4)
                      for i in range(6)])
    ps_v, out_v = jax.jit(h_v.step_many)(ps_v, acts)
    ps_f, out_f = jax.jit(h_f.step_many)(ps_f, acts)
    assert out_v.obs.shape == (6, 4, 3)
    np.testing.assert_allclose(np.asarray(out_v.obs), np.asarray(out_f.obs),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_v.reward),
                               np.asarray(out_f.reward), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out_v.done),
                                  np.asarray(out_f.done))
    np.testing.assert_allclose(np.asarray(ps_v.obs), np.asarray(ps_f.obs),
                               rtol=1e-5, atol=1e-6)


def test_sharded_fused_matches_unsharded_on_one_device_mesh():
    key = jax.random.PRNGKey(5)
    sharded = ShardedEnvPool("CartPole-v1", 8, mesh=default_pool_mesh(1),
                             backend="jnp", unroll=8)
    plain = EnvPool("CartPole-v1", 8)
    rew_s, eps_s, _ = sharded.rollout(40, key)
    rew_u, eps_u, _ = plain.rollout(40, key)
    np.testing.assert_allclose(np.asarray(rew_s), np.asarray(rew_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_s), np.asarray(eps_u))
    obs_s, obs_u = sharded.reset(seed=1), plain.reset(seed=1)
    np.testing.assert_array_equal(np.asarray(obs_s), np.asarray(obs_u))
    for i in range(3):
        a = plain.sample_actions(i)
        out_s, out_u = sharded.step(a), plain.step(a)
        for s, u in zip(out_s[:3], out_u[:3]):
            np.testing.assert_allclose(np.asarray(s), np.asarray(u),
                                       rtol=1e-5, atol=1e-6)


def test_pool_fused_step_loop_is_device_resident():
    """Acceptance: zero host transfers in the compiled fused rollout."""
    pool = EnvPool("CartPole-v1", 16, backend="jnp", unroll=8)
    hlo = pool.rollout_lowered(64).compile().as_text()
    assert host_transfer_ops(hlo) == []


def test_make_pool_fused_backend():
    pool = make_pool("CartPole-v1", 4, backend="pallas", unroll=4)
    assert isinstance(pool, EnvPool) and pool.unroll == 4
    assert pool.backend == "pallas"
    sharded = make_pool("CartPole-v1", 4, backend="sharded",
                        mesh=default_pool_mesh(1), step_backend="jnp",
                        unroll=4)
    assert isinstance(sharded, ShardedEnvPool)
    assert sharded.backend == "jnp" and sharded.unroll == 4


def test_dqn_training_parity_across_engines():
    from repro.rl.dqn import DQNConfig, train_compiled

    env = make("CartPole-v1")
    key = jax.random.PRNGKey(0)
    cfg = DQNConfig(num_envs=4, learn_start=20, memory_size=200)
    _, _, m_v = train_compiled(env, cfg, 40, key)
    _, _, m_f = train_compiled(
        env, dataclasses.replace(cfg, env_backend="jnp"), 40, key)
    np.testing.assert_allclose(np.asarray(m_v["return"]),
                               np.asarray(m_f["return"]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_v["loss"]),
                               np.asarray(m_f["loss"]), rtol=2e-4, atol=1e-5)


def test_ppo_training_parity_across_engines():
    from repro.rl.ppo import PPOConfig, train

    env = make("CartPole-v1")
    key = jax.random.PRNGKey(0)
    cfg = PPOConfig(num_envs=8, rollout_len=32, epochs=2, minibatches=2)
    _, m_v = train(env, cfg, 2, key)
    _, m_f = train(env, dataclasses.replace(cfg, env_backend="jnp"), 2, key)
    np.testing.assert_allclose(np.asarray(m_v["return"]),
                               np.asarray(m_f["return"]), rtol=1e-4, atol=1e-4)
