"""repro.analysis.cost: the static cost model and its regression gate.

Three layers: pure gate semantics on synthetic reports (thresholds at
X−ε/X+ε, missing cells, refusal transitions, baseline round-trip), one
real lowered cell end-to-end (schema + roofline + static impact), and the
committed-baseline contract (`BENCH_cost_baseline.json` covers the smoke
matrix and a synthetic fused-env regression fails loudly through the CLI).
"""
import copy
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import audit
from repro.analysis.cost import (DEFAULT_THRESHOLDS, GATED_METRICS,
                                 SMOKE_BACKENDS, check, cost_cell,
                                 cost_train_cell, family_of, plan, run,
                                 summary_table, threshold_for)
from repro.core.registry import registered
from repro.sustainability.impact import StaticImpact

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_cost_baseline.json")


def _fake_report(**overrides):
    """A minimal two-cell report for pure check() tests."""
    row = {
        "id": "CartPole-v1", "backend": "pallas", "batch": 4,
        "family": "classic", "status": "ok", "env_steps_per_program": 4,
        "flops_per_step": 1000.0, "bytes_per_step": 4000.0,
        "peak_live_bytes": 2000.0,
    }
    refused = {"id": "Pendulum-v1", "backend": "pallas", "batch": 4,
               "family": "classic", "status": "refused",
               "refusal": "ValueError", "refusal_msg": "no fused support"}
    report = {"meta": {"platform": "cpu"}, "rows": [dict(row), dict(refused)]}
    for k, v in overrides.items():
        report["rows"][0][k] = v
    return report


# -- gate semantics (pure functions, no lowering) -----------------------------

def test_self_diff_is_clean():
    base = _fake_report()
    problems, notes = check(_fake_report(), base)
    assert problems == [] and notes == []


@pytest.mark.parametrize("metric", GATED_METRICS)
def test_threshold_pass_at_x_minus_eps_fail_at_x_plus_eps(metric):
    base = _fake_report()
    thr = threshold_for("classic")
    b = base["rows"][0][metric]
    ok = check(_fake_report(**{metric: b * (1 + thr - 1e-3)}), base)
    assert ok[0] == []
    problems, _ = check(_fake_report(**{metric: b * (1 + thr + 1e-3)}), base)
    assert len(problems) == 1
    # loud failure: named cell + metric + signed delta
    assert "CartPole-v1×pallas" in problems[0]
    assert metric in problems[0] and "+" in problems[0]


def test_improvement_beyond_threshold_is_a_note_not_a_problem():
    base = _fake_report()
    problems, notes = check(_fake_report(flops_per_step=500.0), base)
    assert problems == []
    assert any("improved" in n and "regen" in n for n in notes)


def test_missing_cell_and_new_refusal_are_problems():
    base = _fake_report()
    gone = _fake_report()
    gone["rows"] = gone["rows"][1:]
    problems, _ = check(gone, base)
    assert any("missing" in p for p in problems)
    now_refused = _fake_report()
    now_refused["rows"][0] = {
        "id": "CartPole-v1", "backend": "pallas", "batch": 4,
        "family": "classic", "status": "refused",
        "refusal": "RuntimeError", "refusal_msg": "boom"}
    problems, _ = check(now_refused, base)
    assert any("now refused" in p and "RuntimeError" in p for p in problems)


def test_batch_change_is_a_problem_not_a_silent_rescale():
    problems, _ = check(_fake_report(batch=8), _fake_report())
    assert any("batch changed" in p for p in problems)


def test_new_cell_and_newly_hosted_are_notes():
    base = _fake_report()
    grown = _fake_report()
    grown["rows"].append({"id": "Maze-v0", "backend": "vmap", "batch": 4,
                          "family": "grid", "status": "ok",
                          "env_steps_per_program": 4, "flops_per_step": 1.0,
                          "bytes_per_step": 1.0, "peak_live_bytes": 1.0})
    grown["rows"][1] = {**grown["rows"][1], "status": "ok",
                        "env_steps_per_program": 4, "flops_per_step": 1.0,
                        "bytes_per_step": 1.0, "peak_live_bytes": 1.0}
    problems, notes = check(grown, base)
    assert problems == []
    assert any("new cell" in n for n in notes)
    assert any("newly hosted" in n for n in notes)


def test_per_family_thresholds_cover_every_registry_family():
    for env_id in registered():
        fam = family_of(env_id)
        assert fam in DEFAULT_THRESHOLDS, (env_id, fam)
    assert family_of("dqn/CartPole-v1", audit.TRAIN_BACKEND) == "train"
    assert threshold_for("arcade") > 0 and threshold_for("nonsense") > 0


def test_plan_covers_the_audit_matrix():
    """Registry-completeness: the full cost plan is exactly the audit plan
    — every hosted audit cell has a cost row."""
    assert set(plan()) == set(audit.plan())
    smoke = plan(backends=SMOKE_BACKENDS)
    assert {i for i, _ in smoke} == set(registered())


# -- one real cell end-to-end -------------------------------------------------

def test_cost_cell_schema_and_physics():
    row = cost_cell("CartPole-v1", "vmap", batch=4)
    assert row["status"] == "ok"
    assert row["family"] == "classic"
    assert row["env_steps_per_program"] == 4
    assert row["flops"] == pytest.approx(row["flops_per_step"] * 4)
    assert row["flops_per_step"] > 0 and row["bytes_per_step"] > 0
    assert row["peak_live_bytes"] > 0
    assert row["arithmetic_intensity"] == pytest.approx(
        row["flops_per_step"] / row["bytes_per_step"])
    rl = row["roofline"]
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert rl["bound_s"] == pytest.approx(
        max(rl["compute_s"], rl["memory_s"], rl["collective_s"]))
    imp = row["static_impact"]
    assert imp["joules_per_mstep"] == pytest.approx(
        rl["bound_s"] * imp["watts"] * 1e6)
    assert imp["co2_g_per_mstep"] > 0
    json.dumps(row)  # machine-readable end to end


def _unfused_id():
    from repro.core.env import supports_fused_step
    from repro.core.registry import make
    return next(i for i in sorted(registered())
                if not supports_fused_step(make(i)))


def test_cost_cell_refusal_is_named():
    row = cost_cell(_unfused_id(), "pallas", batch=4)
    assert row["status"] == "refused"
    assert row["refusal"] in audit.EXPECTED_REFUSALS


def test_cost_train_cell_unknown_id_refuses_by_name():
    row = cost_train_cell("dqn/NoSuchEnv-v9")
    assert row["status"] == "refused" and row["refusal"] == "KeyError"


def test_baseline_regen_round_trip():
    """run → dump → load → check against itself: clean, no notes."""
    report = run(ids=["CartPole-v1"], backends=("vmap",), train=False)
    loaded = json.loads(json.dumps(report))
    problems, notes = check(report, loaded)
    assert problems == [] and notes == []
    assert summary_table(report)  # renders without blowing up


# -- the committed baseline contract ------------------------------------------

def test_committed_baseline_covers_the_smoke_matrix():
    with open(BASELINE) as f:
        base = json.load(f)
    cells = {(r["id"], r["backend"]) for r in base["rows"]}
    for key in plan(backends=SMOKE_BACKENDS):
        assert key in cells, f"baseline is missing {key}; run make cost-baseline"
    from repro.train.fused import GOLDEN_TRAIN_IDS
    for gid in GOLDEN_TRAIN_IDS:
        assert (gid, audit.TRAIN_BACKEND) in cells
    hosted = [r for r in base["rows"] if r["status"] == "ok"]
    for r in hosted:
        for metric in GATED_METRICS:
            assert r.get(metric, 0) > 0, (r["id"], r["backend"], metric)


def test_synthetic_fused_regression_fails_loudly_through_the_cli(tmp_path):
    """The acceptance criterion, executed: inflate a fused env's baseline
    expectation downward (equivalently, the fresh compile regressed above
    threshold) and the CLI must exit nonzero naming cell, metric, delta."""
    fresh = run(ids=["CartPole-v1"], backends=("pallas",), train=False)
    tampered = copy.deepcopy(fresh)
    for r in tampered["rows"]:
        r["flops_per_step"] /= 1.5  # fresh compile now +50% over baseline
    path = tmp_path / "tampered_baseline.json"
    path.write_text(json.dumps(tampered))
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.cost", "--ids", "CartPole-v1",
         "--backends", "pallas", "--no-train", "--batch", "4",
         "--check", str(path)],
        env=env, capture_output=True, text=True, cwd=os.path.dirname(SRC))
    assert out.returncode == 1, out.stdout + out.stderr
    assert "COST REGRESSION" in out.stdout
    assert "CartPole-v1×pallas" in out.stdout
    assert "flops_per_step" in out.stdout and "+50" in out.stdout
    # and the untampered baseline passes the same sweep
    path.write_text(json.dumps(fresh))
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis.cost", "--ids", "CartPole-v1",
         "--backends", "pallas", "--no-train", "--batch", "4",
         "--check", str(path)],
        env=env, capture_output=True, text=True, cwd=os.path.dirname(SRC))
    assert ok.returncode == 0, ok.stdout + ok.stderr


# -- table2 static rows -------------------------------------------------------

def test_table2_static_rows_prefer_pallas_and_cover_all_ids():
    from benchmarks.table2_carbon import static_rows
    with open(BASELINE) as f:
        base = json.load(f)
    rows = static_rows(base)
    for env_id in registered():
        assert env_id in rows, f"no static table2 row for {env_id}"
        assert rows[env_id]["joules_per_mstep"] > 0
        assert rows[env_id]["co2_g_per_mstep"] > 0
    # pallas preferred where hosted, named fallback where refused
    assert rows["CartPole-v1"]["backend"] == "pallas"
    assert rows[_unfused_id()]["backend"] == "vmap"
    assert rows["dqn/CartPole-v1"]["family"] == "train"


def test_static_impact_accounting():
    imp = StaticImpact(seconds_per_step=1e-6, watts=200.0)
    assert imp.joules_per_step == pytest.approx(2e-4)
    assert imp.joules_per_mstep == pytest.approx(200.0)
    assert imp.kwh_per_mstep == pytest.approx(200.0 / 3.6e6)
    assert imp.co2_g_per_mstep == pytest.approx(
        200.0 / 3.6e6 * 0.475 * 1e3)
    json.dumps(imp.report())
