"""CheckpointManager: concurrency, atomicity under injected kills, keep-k.

The concurrency contract (manager docstring): writes serialize, the writer
thread is joined not dropped, `wait()` re-raises writer errors, `close()`
refuses further saves. Atomicity is proven by killing a save INSIDE the
mid-save preemption window (`_pre_replace_hook`, driven by a FaultInjector
"preempt_save" fault on a scripted clock) and checking the previous
checkpoint still restores. Mesh-agnosticism is proven by round-tripping a
real pool carry through a checkpoint into a fresh pool.
"""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.pool import EnvPool
from repro.runtime.failures import FaultInjector


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "n": {"step": jnp.asarray(seed, jnp.int32)}}


# -- write serialization -------------------------------------------------------

def test_nonblocking_saves_never_overlap(tmp_path):
    """save() joins the previous write before starting the next, so the
    write+GC critical section holds at most one writer at a time."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    inside = []
    lock = threading.Lock()

    def hook(tmp):
        with lock:
            inside.append(tmp)
            assert len(inside) == 1, "two writes in the critical section"
        with lock:
            inside.pop()

    mgr._pre_replace_hook = hook
    for step in range(6):
        mgr.save(step, _tree(step), blocking=False)
    mgr.close()
    assert mgr.all_steps() == [4, 5]   # keep-k GC ran under the same lock


def test_wait_reraises_writer_error_once(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    def boom(tmp):
        raise OSError("disk gone")

    mgr._pre_replace_hook = boom
    mgr.save(1, _tree(), blocking=False)
    with pytest.raises(OSError, match="disk gone"):
        mgr.wait()
    mgr.wait()                         # error is consumed, not sticky
    mgr._pre_replace_hook = None
    mgr.save(2, _tree())               # manager still usable
    assert mgr.latest_step() == 2


def test_save_surfaces_previous_async_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    def hook(tmp):                     # only the step-1 write dies
        if "step_0000000001" in tmp:
            raise OSError("x")

    mgr._pre_replace_hook = hook
    mgr.save(1, _tree(), blocking=False)
    with pytest.raises(OSError):       # the serializing wait() re-raises
        mgr.save(2, _tree())


def test_close_joins_and_refuses_further_saves(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(), blocking=False)
    mgr.close()
    assert mgr.latest_step() == 3      # close() joined the in-flight write
    with pytest.raises(RuntimeError, match="closed"):
        mgr.save(4, _tree())


def test_context_manager_closes(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, _tree(), blocking=False)
    assert mgr.latest_step() == 1


# -- atomicity under injected mid-save preemption ------------------------------

def test_midsave_kill_preserves_previous_checkpoint(tmp_path):
    """A "preempt_save" fault kills the write after the tmp dir is fully
    written but before the atomic rename — the worst window. The previous
    checkpoint must survive and restore; the next save must succeed."""
    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def preempt(tmp):
        for f in inj.due(kinds=("preempt_save",)):
            raise KeyboardInterrupt(f"preempted mid-save ({f.arg})")

    mgr._pre_replace_hook = preempt
    tree = _tree(7)
    mgr.save(10, tree)                       # a good checkpoint exists

    inj.schedule(1.0, "preempt_save", "host preempted")
    clk[0] = 2.0
    with pytest.raises(KeyboardInterrupt):
        mgr.save(20, _tree(8))               # dies in the window
    assert mgr.all_steps() == [10]           # no torn step_20
    restored = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    mgr.save(20, _tree(8))                   # stale tmp dir is cleared
    assert mgr.all_steps() == [10, 20]


def test_midsave_kill_of_async_save_surfaces_and_preserves(tmp_path):
    clk = [0.0]
    inj = FaultInjector(clock=lambda: clk[0])
    mgr = CheckpointManager(str(tmp_path))
    mgr._pre_replace_hook = lambda tmp: [
        (_ for _ in ()).throw(KeyboardInterrupt("preempted"))
        for _ in inj.due(kinds=("preempt_save",))]
    mgr.save(1, _tree(1))
    inj.schedule(1.0, "preempt_save")
    clk[0] = 2.0
    mgr.save(2, _tree(2), blocking=False)
    with pytest.raises(KeyboardInterrupt):
        mgr.wait()
    assert mgr.all_steps() == [1]
    assert not any(n.endswith(".tmp") and False
                   for n in os.listdir(str(tmp_path)))  # listing sane
    assert mgr.latest_step() == 1


# -- meta sidecar --------------------------------------------------------------

def test_meta_roundtrip_and_absence(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), meta={"sessions": {"3": {"steps": 4}}, "ticks": 9})
    mgr.save(2, _tree())
    assert mgr.read_meta(1) == {"sessions": {"3": {"steps": 4}}, "ticks": 9}
    assert mgr.read_meta(2) is None
    assert mgr.read_meta() is None           # latest (=2) has no meta


# -- mesh-agnostic pool-carry round-trip ---------------------------------------

def test_pool_carry_roundtrip_into_fresh_pool(tmp_path):
    """A pool snapshot checkpointed and restored into a BRAND NEW pool
    continues bit-identically — the gathered (unsharded) array format is
    what makes the restore mesh/topology-agnostic."""
    key = jax.random.PRNGKey(11)
    pool = EnvPool("Pendulum-v1", 4)
    pool.reset(seed=11)
    for t in range(6):
        pool.step(np.zeros((4, 1), np.float32), key=jax.random.fold_in(key, t))
    mgr = CheckpointManager(str(tmp_path))
    snap = pool.state_dict()
    mgr.save(6, snap)
    ref = [np.asarray(pool.step(np.zeros((4, 1), np.float32),
                                key=jax.random.fold_in(key, t))[0]).copy()
           for t in range(6, 9)]

    pool2 = EnvPool("Pendulum-v1", 4)
    pool2.reset(seed=0)                      # template structure only
    restored = mgr.restore(pool2.state_dict())
    pool2.load_state_dict(restored)
    for t in range(6, 9):
        obs, *_ = pool2.step(np.zeros((4, 1), np.float32),
                             key=jax.random.fold_in(key, t))
        np.testing.assert_array_equal(np.asarray(obs), ref[t - 6])


def test_snapshot_survives_donated_buffer_reuse(tmp_path):
    """state_dict() must deep-copy: the carry is DONATED to the next step,
    so an aliasing snapshot would silently mutate. Stepping after snapshot
    must not change what restore sees."""
    key = jax.random.PRNGKey(5)
    pool = EnvPool("CartPole-v1", 4)
    pool.reset(seed=5)
    snap = pool.state_dict()
    frozen = jax.tree.map(lambda x: np.array(x, copy=True), snap)
    for t in range(4):                       # donated buffers get reused
        pool.step(np.zeros(4, np.int32), key=jax.random.fold_in(key, t))
    for a, b in zip(jax.tree.leaves(frozen), jax.tree.leaves(snap)):
        np.testing.assert_array_equal(a, np.asarray(b))
