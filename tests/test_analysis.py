"""repro.analysis: lint rules, pragmas, retrace guard, and the HLO audit.

Three layers of coverage:

  1. Rule-by-rule: every lint rule has a checked-in known-bad fixture that
     trips exactly that rule and a known-good twin that stays clean
     (tests/fixtures/analysis/) — the proof that `make analyze` actually
     fails on each pattern it claims to gate.
  2. Gate: the repo's own `src/` tree lints clean (zero unallowlisted
     violations) — the satellite fixes of this PR, held in place.
  3. Audit: the registry sweep covers every id × backend (hosted or named
     refusal, the conformance-matrix contract), one cheap end-to-end cell
     proves residency+donation on a real compiled step, and the async
     retrace budget — the PR-6 recv-size respecialization fact — is
     executed, not just asserted.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import RULES, RetraceError, RetraceGuard, lint_paths, lint_source
from repro.analysis.audit import (BACKENDS, EXPECTED_REFUSALS, RETRACE_BUDGET,
                                  TRAIN_BACKEND, audit_cell, audit_train_cell,
                                  plan, row_violations)
from repro.analysis.retrace import trace_count
from repro.analysis.rules import pragma_lines
from repro.core.registry import registered
from repro.launch.hlo_analysis import donated_params

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

RULE_FIXTURE = {
    "key-reuse": "key_reuse",
    "host-read-in-jit": "host_read",
    "use-after-donate": "use_after_donate",
    "tracer-branch": "tracer_branch",
    "unguarded-mutation": "unguarded_mutation",
    "lock-discipline": "lock_discipline",
    "donation-lifetime": "donation_lifetime",
    "silent-except": "silent_except",
    "wall-clock": "wall_clock",
}


def _lint_fixture(name):
    path = os.path.join(FIXTURES, name)
    with open(path) as f:
        return lint_source(f.read(), path)


# -- 1. rule-by-rule fixtures -------------------------------------------------

def test_every_rule_has_a_fixture_pair():
    assert set(RULE_FIXTURE) == set(RULES)
    for stem in RULE_FIXTURE.values():
        assert os.path.exists(os.path.join(FIXTURES, f"bad_{stem}.py"))
        assert os.path.exists(os.path.join(FIXTURES, f"good_{stem}.py"))


@pytest.mark.parametrize("rule", sorted(RULES))
def test_bad_fixture_trips_its_rule(rule):
    hits = _lint_fixture(f"bad_{RULE_FIXTURE[rule]}.py")
    assert any(v.rule == rule for v in hits), (
        f"bad_{RULE_FIXTURE[rule]}.py should trip [{rule}]; got {hits}")


@pytest.mark.parametrize("rule", sorted(RULES))
def test_good_fixture_stays_clean(rule):
    hits = _lint_fixture(f"good_{RULE_FIXTURE[rule]}.py")
    assert not [v for v in hits if v.rule == rule], (
        f"good_{RULE_FIXTURE[rule]}.py false-positives [{rule}]: {hits}")


# -- pragmas ------------------------------------------------------------------

def test_pragma_allowlists_same_and_next_line():
    assert _lint_fixture("pragma_allowed.py") == []


def test_pragma_with_unknown_rule_is_reported_and_allows_nothing():
    hits = _lint_fixture("pragma_unknown_rule.py")
    rules = {v.rule for v in hits}
    assert "wall-clock" in rules        # the typo'd pragma allowed nothing
    assert any("unknown rule" in v.message for v in hits)


def test_pragma_in_docstring_is_inert():
    src = '"""docs show `# repro: allow[wall-clock]` usage"""\n' \
          "import time\n\n\ndef f():\n    return time.time()\n"
    assert any(v.rule == "wall-clock" for v in lint_source(src))
    assert pragma_lines(src) == {}


def test_pragma_multiple_rules():
    src = ("import time\n\n\ndef f():\n"
           "    return time.time()  # repro: allow[wall-clock,key-reuse] x\n")
    assert lint_source(src) == []


# -- 2. the gate: repo lints clean, CLI exit codes ---------------------------

def test_repo_source_tree_is_clean():
    hits = lint_paths([SRC])
    assert hits == [], "\n".join(str(v) for v in hits)


def test_cli_exit_codes():
    env = dict(os.environ, PYTHONPATH=SRC)
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         os.path.join(FIXTURES, "bad_wall_clock.py")],
        env=env, capture_output=True, text=True)
    assert bad.returncode == 1 and "[wall-clock]" in bad.stdout
    good = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         os.path.join(FIXTURES, "good_wall_clock.py")],
        env=env, capture_output=True, text=True)
    assert good.returncode == 0


# -- retrace guard ------------------------------------------------------------

def test_retrace_guard_enforces_budget():
    step = RetraceGuard(jax.jit(lambda x: x * 2), budget=1, name="toy.step")
    step(jnp.ones((4,), jnp.float32))
    step(jnp.zeros((4,), jnp.float32))          # cached: same signature
    assert step.traces == 1
    with pytest.raises(RetraceError) as e:
        step(jnp.ones((8,), jnp.float32))       # new shape: second trace
    assert e.value.traces == 2 and e.value.budget == 1


def test_retrace_guard_rejects_plain_functions():
    with pytest.raises(TypeError):
        RetraceGuard(lambda x: x)


def test_trace_count_none_on_foreign_callables():
    assert trace_count(print) is None


# -- donation parser ----------------------------------------------------------

def test_donated_params_survives_nested_brace_attrs():
    sig = ('module @jit_step {\n'
           '  func.func public @main('
           '%arg0: tensor<4xf32> {mhlo.sharding = "{replicated}", '
           'tf.aliasing_output = 0 : i32}, '
           '%arg1: tensor<2xui32> {tf.aliasing_output = 1 : i32}, '
           '%arg2: tensor<4xf32> {mhlo.sharding = "{replicated}"}) '
           '-> (tensor<4xf32>, tensor<2xui32>) {\n')
    assert donated_params(sig) == [0, 1]
    assert donated_params("no main signature here") == []


# -- 3. the audit sweep -------------------------------------------------------

def test_audit_plan_covers_every_registry_id_and_backend():
    cells = plan()
    ids = {c[0] for c in cells}
    assert ids == set(registered())
    for env_id in ids:
        assert {b for i, b in cells if i == env_id} == set(BACKENDS)


def test_audit_cell_end_to_end_vmap():
    row = audit_cell("CartPole-v1", "vmap", batch=4)
    assert row["status"] == "ok"
    assert row["host_transfer_ops"] == []
    assert row["donation"] == 1.0
    assert row["flops"] >= 0
    assert row_violations(row) == []


def test_audit_cell_refusal_is_named():
    # Pendulum has no fused megastep kernel -> the pallas cell must refuse
    # with the documented class, and the refusal is not a violation.
    from repro.core.env import supports_fused_step
    from repro.core.registry import make
    unfused = next(i for i in sorted(registered())
                   if not supports_fused_step(make(i)))
    row = audit_cell(unfused, "pallas", batch=4)
    assert row["status"] == "refused"
    assert row["refusal"] in EXPECTED_REFUSALS
    assert row_violations(row) == []


def test_row_violations_gate():
    base = {"id": "X-v0", "backend": "vmap", "status": "ok",
            "host_transfer_ops": [], "donation": 1.0, "donated_params": 2,
            "carry_params": 2}
    assert row_violations(base) == []
    assert row_violations({**base, "host_transfer_ops": ["e/cc:custom-call"]})
    assert row_violations({**base, "donation": 0.5, "donated_params": 1})
    assert row_violations({**base, "retraces": 2, "retrace_budget": 1})
    assert not row_violations({**base, "retraces": 1, "retrace_budget": 1})
    refused = {"id": "X-v0", "backend": "pallas", "status": "refused",
               "refusal": "ValueError", "refusal_msg": "no fused support"}
    assert row_violations(refused) == []
    assert row_violations({**refused, "refusal": "ZeroDivisionError"})


@pytest.mark.slow
def test_async_retrace_budget_is_a_fact():
    # The PR-6 claim, executed: stepping ready sets of size 1, 2 and N owns
    # exactly one jit trace (recv masks on device, row-selects host-side).
    row = audit_cell("CartPole-v1", "async", batch=4, run_retrace=True)
    assert row["status"] == "ok"
    assert row["retraces"] <= RETRACE_BUDGET["async"] == row["retrace_budget"]
    assert row_violations(row) == []


@pytest.mark.slow
def test_audit_smoke_report_schema():
    from repro.analysis.audit import run
    report = run(ids=["CartPole-v1"], backends=("vmap", "async"), smoke=True)
    assert report["ok"], report["violations"]
    assert report["summary"]["cells"] == 2
    assert {r["backend"] for r in report["rows"]} == {"vmap", "async"}
    assert report["meta"]["train_cells"] == []  # auto-off for id subsets
    json.dumps(report)  # machine-readable end to end


# -- fused-train cells ---------------------------------------------------------

def test_audit_train_cell_certifies_fused_dqn():
    """The tentpole's machine-checkable claim: the donated fused-train
    chunk — rollout + replay ring + learner + target sync in one program —
    has zero host-transfer ops and donates EVERY carry leaf (replay ring
    and optimizer moments included)."""
    row = audit_train_cell("dqn/CartPole-v1")
    assert row["status"] == "ok"
    assert row["backend"] == TRAIN_BACKEND
    assert row["host_transfer_ops"] == []
    assert row["donation"] == 1.0
    # the carry is the full DQNState: params+target+opt+replay+pool+key+...
    assert row["carry_params"] == row["donated_params"] > 20
    assert row_violations(row) == []


def test_audit_train_cell_unknown_id_refuses_by_name():
    row = audit_train_cell("dqn/NoSuchEnv-v9")
    assert row["status"] == "refused"
    assert row["refusal"] == "KeyError"


@pytest.mark.slow
def test_audit_run_with_train_appends_golden_train_rows():
    from repro.analysis.audit import run
    from repro.train.fused import GOLDEN_TRAIN_IDS
    report = run(ids=["CartPole-v1"], backends=("vmap",), smoke=True,
                 train=True)
    assert report["ok"], report["violations"]
    assert report["meta"]["train_cells"] == list(GOLDEN_TRAIN_IDS)
    train_rows = [r for r in report["rows"] if r["backend"] == TRAIN_BACKEND]
    assert [r["id"] for r in train_rows] == list(GOLDEN_TRAIN_IDS)
    for r in train_rows:
        assert r["host_transfer_ops"] == [] and r["donation"] == 1.0
    json.dumps(report)
