"""Shared pytest plumbing and cross-module test helpers.

`--regen-golden` (tests/test_golden.py):

    PYTHONPATH=src python -m pytest tests/test_golden.py --regen-golden

rewrites every committed trace under tests/golden/ from the current
dynamics instead of comparing against them. Use after an *intentional*
dynamics change; the diff of the regenerated JSON is the review artifact.

The helpers below are the single copies of the parity oracle
(`vmap_reference` — K iterated `Vec(AutoReset(env)).step` calls), its
comparison policy (`assert_leaves_match` — exact for int/bool/key leaves,
<=1e-5 for floats) and the layout-solvability oracle (`bfs_reachable`),
shared by tests/test_conformance.py, tests/test_envstep_fused.py,
tests/test_grid.py and tests/test_property.py so the contracts cannot
drift apart between suites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="regenerate tests/golden/*.json from current env dynamics "
             "instead of asserting against them")


@pytest.fixture
def regen_golden(request) -> bool:
    return request.config.getoption("--regen-golden")


def vmap_reference(env, num_envs, key, actions):
    """K iterated `Vec(AutoReset(env)).step` calls — the oracle trajectory
    every fused/pool execution path must reproduce."""
    from repro.core.wrappers import AutoReset, Vec

    venv = Vec(AutoReset(env), num_envs)
    state0, _ = venv.reset(key)
    state, outs = state0, []
    for t in range(actions.shape[0]):
        ts = venv.step(state, actions[t], jax.random.fold_in(key, t))
        state = ts.state
        outs.append((ts.obs, ts.reward, ts.done, ts.info["terminal_obs"]))
    stack = lambda i: jnp.stack([o[i] for o in outs])
    return state0, state, stack(0), stack(1), stack(2), stack(3)


def assert_leaves_match(ref, got, what=""):
    """Parity contract: dtype/shape equal; ints, bools and PRNG keys exact;
    floats to 1e-5/1e-6 (compilers may reassociate)."""
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape, (what, a.dtype,
                                                           b.dtype)
        if np.issubdtype(a.dtype, np.integer) or a.dtype in (np.bool_,
                                                             np.uint32):
            np.testing.assert_array_equal(a, b, err_msg=what)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                       err_msg=what)


def bfs_reachable(blocked, n_rows, n_cols, start, goal):
    """Host-side search over a generated layout (4-neighbourhood)."""
    seen, frontier = {start}, [start]
    while frontier:
        pos = frontier.pop()
        if pos == goal:
            return True
        r, c = divmod(pos, n_cols)
        for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
            nr, nc = r + dr, c + dc
            np_ = nr * n_cols + nc
            if (0 <= nr < n_rows and 0 <= nc < n_cols and np_ not in seen
                    and not blocked[np_]):
                seen.add(np_)
                frontier.append(np_)
    return False
