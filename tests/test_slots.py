"""Unit tests for serving/slots.SlotTable — the shared slot bookkeeping.

ServeEngine's `_free_slots`/admit ordering used to be inline and untested
(the refill-latency blind spot this PR closes); these tests pin the
extracted table's contract for BOTH consumers: FIFO admission into the
lowest free slots, one-owner-per-slot, and scripted-clock queue-wait /
residency accounting.
"""
import math

import pytest

from repro.serving.slots import SlotTable, percentile


class ScriptedClock:
    """Deterministic clock: every read advances by `tick` (default 1.0)."""

    def __init__(self, tick: float = 1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


def test_fifo_admit_fills_lowest_slots_first():
    tab = SlotTable(3)
    for rid in ("a", "b", "c", "d", "e"):
        tab.submit(rid)
    assert tab.admit() == [(0, "a"), (1, "b"), (2, "c")]
    assert tab.queued_count == 2 and tab.active_count == 3
    assert tab.running() == ["a", "b", "c"]
    # free the MIDDLE slot: the earliest queued id must take exactly it
    assert tab.release("b") == 1
    assert tab.admit() == [(1, "d")]
    assert tab.running() == ["a", "d", "c"]  # slot order, not admit order
    assert tab.slot_of("d") == 1 and tab.owner(1) == "d"


def test_admit_never_leaves_slot_free_with_queue_nonempty():
    tab = SlotTable(4)
    for rid in range(2):
        tab.submit(rid)
    tab.admit()
    assert tab.queued_count == 0
    assert len(tab.free_slots()) == 2  # queue drained, slots legitimately free
    for rid in range(2, 9):
        tab.submit(rid)
    tab.admit()
    assert tab.free_slots() == [] and tab.queued_count == 5


def test_double_submit_rejected():
    tab = SlotTable(2)
    tab.submit("x")
    with pytest.raises(ValueError, match="already queued"):
        tab.submit("x")
    tab.admit()
    with pytest.raises(ValueError, match="already queued or running"):
        tab.submit("x")  # running ids can't re-queue either
    tab.release("x")
    tab.submit("x")  # released ids may come back


def test_release_unknown_id_raises():
    tab = SlotTable(1)
    with pytest.raises(KeyError):
        tab.release("ghost")


def test_scripted_clock_wait_and_residency_accounting():
    clock = ScriptedClock()
    tab = SlotTable(1, clock=clock)
    tab.submit("a")      # t=1
    tab.submit("b")      # t=2
    tab.admit()          # t=3: a admitted, waited 2
    assert tab.queue_waits == [2.0]
    tab.release("a")     # t=4: a resided 1
    assert tab.residencies == [1.0]
    tab.admit()          # t=5: b admitted, waited 3
    tab.release("b")     # t=6: b resided 1
    st = tab.stats()
    assert st["admitted"] == 2 and st["released"] == 2
    assert st["queue_wait_p50"] == pytest.approx(2.0)
    assert st["queue_wait_p99"] == pytest.approx(3.0)
    assert st["residency_p50"] == st["residency_p99"] == pytest.approx(1.0)


def test_stats_empty_table_is_nan_not_crash():
    st = SlotTable(2).stats()
    assert math.isnan(st["queue_wait_p50"]) and math.isnan(st["residency_p99"])
    assert st["admitted"] == st["released"] == 0


def test_percentile_nearest_rank():
    xs = [5.0, 1.0, 3.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 100) == 5.0
    assert math.isnan(percentile([], 50))


def test_serve_engine_delegates_to_slot_table():
    """ServeEngine's slot bookkeeping IS the shared table (no parallel
    copy that could drift): `_free_slots` reflects SlotTable state and
    `stats()` surfaces the table's accounting."""
    from repro.serving.engine import ServeEngine

    eng = ServeEngine.__new__(ServeEngine)  # bookkeeping only, no model
    eng._requests = {}
    eng.slots_table = SlotTable(3)
    assert eng._free_slots() == [0, 1, 2]
    eng.slots_table.submit(7)
    eng.slots_table.admit()
    assert eng._free_slots() == [1, 2]
    assert eng.stats()["running"] == 1
    eng.slots_table.release(7)
    assert eng._free_slots() == [0, 1, 2]
