"""Hypothesis property tests over the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

hypothesis.settings.register_profile("ci", deadline=None, max_examples=20)
hypothesis.settings.load_profile("ci")


# -- GLA: chunked form ≡ sequential recurrence, any shape/chunk ------------------
@given(
    b=st.integers(1, 3), h=st.integers(1, 3), l=st.integers(1, 33),
    k=st.integers(1, 9), v=st.integers(1, 9), chunk=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_gla_chunked_equals_ref(b, h, l, k, v, chunk, seed):
    from repro.models.gla import gla_chunked, gla_ref

    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, l, k))
    kk = jax.random.normal(ks[1], (b, h, l, k))
    vv = jax.random.normal(ks[2], (b, h, l, v))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, l)))
    gate = jax.nn.sigmoid(jax.random.normal(ks[4], (b, h, l)))
    s0 = jnp.zeros((b, h, k, v))
    y1, s1 = gla_chunked(q, kk, vv, log_a, gate, s0, chunk)
    y2, s2 = gla_ref(q, kk, vv, log_a, gate, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-3)


# -- replay ring: size/ptr invariants under arbitrary add sequences ---------------
@given(st.lists(st.integers(1, 7), min_size=1, max_size=12), st.integers(8, 32))
def test_replay_invariants(batches, cap):
    from repro.rl.replay import replay_add_batch, replay_init

    st_ = replay_init(cap, (1,))
    total = 0
    for i, b in enumerate(batches):
        obs = jnp.full((b, 1), float(i + 1))
        st_ = replay_add_batch(st_, obs, jnp.zeros((b,), jnp.int32),
                               jnp.zeros((b,)), obs, jnp.zeros((b,)))
        total += b
        assert int(st_.size) == min(total, cap)
        assert 0 <= int(st_.ptr) < cap


# -- spaces: samples are contained ------------------------------------------------
@given(st.integers(1, 64), st.integers(0, 2**16))
def test_discrete_sample_contained(n, seed):
    from repro.core.spaces import Discrete

    sp = Discrete(n)
    assert bool(sp.contains(sp.sample(jax.random.PRNGKey(seed))))


@given(st.floats(-5, 0), st.floats(0.1, 5), st.integers(1, 4), st.integers(0, 2**16))
def test_box_sample_contained(low, width, dims, seed):
    from repro.core.spaces import Box

    sp = Box(low=low, high=low + width, shape=(dims,))
    assert bool(sp.contains(sp.sample(jax.random.PRNGKey(seed))))


# -- chunked CE == direct CE for any chunking --------------------------------------
@given(st.integers(1, 3), st.integers(1, 24), st.integers(2, 40), st.integers(0, 2**16))
def test_chunked_ce_property(b, l, v, seed):
    from repro.models.layers import chunked_cross_entropy
    from repro.train.optim import softmax_cross_entropy

    key = jax.random.PRNGKey(seed)
    d = 8
    hidden = jax.random.normal(key, (b, l, d))
    embed = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (b, l), 0, v)
    # chunk sizes that don't divide l are snapped down by the impl
    chunked = float(chunked_cross_entropy(hidden, embed, labels, chunk=5))
    direct = float(softmax_cross_entropy(hidden @ embed.T, labels).mean())
    np.testing.assert_allclose(chunked, direct, rtol=2e-4, atol=1e-5)


# -- rasteriser: intensity monotonicity + bounds ------------------------------------
@given(st.integers(1, 4), st.integers(1, 5), st.integers(0, 2**16))
def test_raster_bounds(b, s, seed):
    from repro.kernels.raster import rasterize_ref

    key = jax.random.PRNGKey(seed)
    segs = jax.random.uniform(key, (b, s, 5)) * jnp.asarray([1, 1, 1, 1, 0.2])
    intens = jax.random.uniform(jax.random.fold_in(key, 1), (b, s))
    fb = rasterize_ref(segs, intens, 16, 16)
    assert float(fb.min()) >= 0.0
    assert float(fb.max()) <= float(intens.max()) + 1e-6


# -- grid suite: regenerated levels are always solvable -----------------------------
@given(st.integers(0, 2**16))
def test_frozen_lake_levels_solvable(seed):
    from conftest import bfs_reachable
    from repro.envs.grid import FrozenLake

    env = FrozenLake()
    state, _ = env.reset(jax.random.PRNGKey(seed))
    holes = np.asarray(state.holes)
    assert bfs_reachable(holes, env.n, env.n, 0, env.m - 1), holes


@given(st.integers(0, 2**16))
def test_maze_levels_solvable(seed):
    from conftest import bfs_reachable
    from repro.envs.grid import Maze

    env = Maze()
    state, _ = env.reset(jax.random.PRNGKey(seed))
    walls = np.asarray(state.walls)
    goal = int(state.goal)
    assert not walls[goal]  # the goal cell itself is carved free
    assert bfs_reachable(walls, env.n, env.n, 0, goal), (walls, goal)


# -- grid suite: rewards within declared bounds, obs inside the space ----------------
@given(st.integers(0, 2**16))
def test_grid_rewards_and_obs_bounded(seed):
    from repro.core.wrappers import AutoReset
    from repro.envs.grid import CliffWalk, FrozenLake, Maze, Snake

    key = jax.random.PRNGKey(seed)
    for env in (FrozenLake(), CliffWalk(), Snake(), Maze()):
        lo, hi = env.reward_range
        aenv = AutoReset(env)
        state, obs = aenv.reset(key)
        for i in range(12):
            a = env.action_space.sample(jax.random.fold_in(key, i))
            ts = aenv.step(state, a, jax.random.fold_in(key, 100 + i))
            state = ts.state
            assert lo <= float(ts.reward) <= hi, (env.name, float(ts.reward))
            assert bool(env.observation_space.contains(np.asarray(ts.obs))), \
                (env.name, np.asarray(ts.obs))


@given(st.integers(0, 2**16))
def test_snake_body_length_invariant(seed):
    """The age grid is consistent: #body cells == length while alive."""
    from repro.envs.grid import Snake

    env = Snake()
    key = jax.random.PRNGKey(seed)
    state, _ = env.reset(key)
    for i in range(15):
        ts = env.step(state, env.action_space.sample(jax.random.fold_in(key, i)),
                      jax.random.fold_in(key, 100 + i))
        if bool(ts.done):
            break
        state = ts.state
        ages = np.asarray(state.ages)
        assert int((ages > 0).sum()) == int(state.length)
        assert int(ages.max()) == int(state.length)  # head carries the length
        assert not ages[int(state.food)]             # food never on the body


# -- attention masks: window never widens the receptive field -----------------------
@given(st.integers(4, 24), st.integers(1, 8), st.integers(0, 2**16))
def test_window_subset_of_causal(l, w, seed):
    from repro.kernels.attention import attention_ref

    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 2, l, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, l, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, l, 8))
    causal = attention_ref(q, k, v, causal=True, window=0)
    windowed = attention_ref(q, k, v, causal=True, window=w)
    # first w positions see identical context under both masks
    np.testing.assert_allclose(np.asarray(causal[:, :, :w]), np.asarray(windowed[:, :, :w]),
                               rtol=1e-4, atol=1e-4)


# -- slot scheduler: no double-assign, no starvation, mask == running ---------------
_ASYNC_POOL = []  # built once; jit caches are per-instance, so reuse across examples


def _shared_async_pool():
    from repro.pool import AsyncEnvPool

    if not _ASYNC_POOL:
        _ASYNC_POOL.append(AsyncEnvPool("CartPole-v1", 4, backend="auto"))
    pool = _ASYNC_POOL[0]
    for slot in range(pool.num_slots):       # scrub state between examples
        if pool._active[slot]:
            pool.release(slot)
    return pool


@given(st.lists(st.sampled_from(["submit", "admit", "step", "finish"]),
                min_size=1, max_size=40),
       st.integers(0, 2**16))
def test_slot_scheduler_interleavings(ops, seed):
    """Random submit/step/finish interleavings through the REAL async pool +
    SlotTable: a slot never hosts two sessions, a queued session is never
    starved while a slot sits free, and the pool's device-side `active`
    mask always equals the table's running count."""
    from repro.serving.slots import SlotTable

    rng = np.random.default_rng(seed)
    pool = _shared_async_pool()
    table = SlotTable(pool.num_slots)
    next_sid = [0]

    for op in ops:
        running = table.running()
        if op == "submit":
            table.submit(next_sid[0])
            next_sid[0] += 1
        elif op == "admit":
            for slot, sid in table.admit():
                got_slot, _ = pool.admit(seed=sid, slot=slot)
                assert got_slot == slot
        elif op == "step" and running:
            k = int(rng.integers(1, len(running) + 1))
            sids = sorted(rng.choice(running, size=k, replace=False).tolist())
            ids = [table.slot_of(s) for s in sids]
            pool.send(np.zeros(len(ids), np.int32), np.asarray(ids))
            *_, out_ids = pool.recv()
            assert sorted(out_ids.tolist()) == sorted(ids)
        elif op == "finish" and running:
            sid = running[int(rng.integers(len(running)))]
            pool.release(table.release(sid))
        # invariants, after every op --------------------------------------
        slots_held = [table.slot_of(s) for s in table.running()]
        assert len(slots_held) == len(set(slots_held)), "slot double-assigned"
        assert not (table.queued_count and table.free_slots()
                    and op == "admit"), "queued session starved of a free slot"
        assert int(pool.active.sum()) == table.active_count == len(slots_held)
        assert sorted(pool.free_slots()) == sorted(table.free_slots())


# -- supervisor: arbitrary step/snapshot/kill/restore interleavings -----------------
_FT_ORACLE = []  # (pool, [(obs, done), ...]) — the uninterrupted trajectory
_FT_STEPS = 40


def _ft_oracle():
    """Shared EnvPool (jit caches are per-instance) + the oracle trajectory
    it must reproduce under ANY fault schedule: 40 steps, pinned keys."""
    from repro.pool import EnvPool

    if not _FT_ORACLE:
        pool = EnvPool("CartPole-v1", 2)
        key = jax.random.PRNGKey(0)
        pool.reset(seed=0)
        rows = []
        for t in range(_FT_STEPS):
            obs, _, done, _ = pool.step(np.zeros(2, np.int32),
                                        key=jax.random.fold_in(key, t))
            rows.append((np.asarray(obs).copy(), np.asarray(done).copy()))
        _FT_ORACLE.append((pool, rows))
    return _FT_ORACLE[0]


@given(st.lists(st.sampled_from(["step", "step", "step", "snapshot", "kill"]),
                min_size=1, max_size=30))
def test_supervisor_interleavings_never_lose_or_duplicate_steps(ops):
    """Random interleavings of step/snapshot/kill+restore on a REAL pool:
    every executed step t — including steps replayed after a restore — is
    bit-identical to the uninterrupted oracle's step t, and the stream
    coverage has no holes up to the furthest point reached. Bit-equality
    per (lane, t) implies no lane ever loses or double-counts an episode:
    the done flags land exactly once per canonical step."""
    import tempfile

    from repro.runtime import RolloutSupervisor

    pool, oracle = _ft_oracle()
    key = jax.random.PRNGKey(0)
    sup = RolloutSupervisor(pool, tempfile.mkdtemp(), snapshot_every=0,
                            blocking_snapshots=True)
    sup.reset(seed=0)
    executed = {}
    t_max = 0
    for op in ops:
        if op == "step" and sup.t < _FT_STEPS:
            t = sup.t
            obs, _, done, _ = sup.step(np.zeros(2, np.int32),
                                       key=jax.random.fold_in(key, t))
            assert np.array_equal(np.asarray(obs), oracle[t][0]), \
                f"step {t} diverged from the uninterrupted oracle"
            assert np.array_equal(np.asarray(done), oracle[t][1])
            executed[t] = True
            t_max = max(t_max, sup.t)
        elif op == "snapshot":
            sup.snapshot()
        elif op == "kill" and sup.manager.latest_step() is not None:
            sup.restore()            # kill + restore from the latest snapshot
            assert sup.t == sup.manager.latest_step()
    assert sorted(executed) == list(range(t_max)), "hole in the step stream"
