"""Environment dynamics: cross-validation against the pure-Python ports.

The compiled envs and the interpreted baselines share constants, so driving
both with the same action sequence from the same start state must produce
the same trajectory — this pins the JAX dynamics to Gym's reference maths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make
from repro.envs.baseline_python.classic import AcrobotPy, CartPolePy, MountainCarPy, PendulumPy
from repro.envs.classic import Acrobot, CartPole, MountainCar, Pendulum
from repro.envs.classic.cartpole import CartPoleState
from repro.envs.classic.acrobot import AcrobotState
from repro.envs.classic.mountain_car import MountainCarState
from repro.envs.classic.pendulum import PendulumState


def test_registered_populates_builtins_before_first_make():
    """Regression: `cairl.registered()` must not return [] in a fresh
    process where no `make()` has run yet (registry.registered() has to
    trigger builtin registration itself). Needs a clean interpreter —
    this test file's imports already populate the registry in-process."""
    import os
    import pathlib
    import subprocess
    import sys

    code = ("from repro.core.registry import registered\n"
            "ids = registered()\n"
            "assert 'CartPole-v1' in ids and 'LightsOut-v0' in ids, ids\n"
            "print(len(ids))\n")
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert int(out.stdout.strip()) >= 12


def _drive(env, state, actions, to_state):
    traj = []
    for a in actions:
        ts = env.step(state, jnp.asarray(a), jax.random.PRNGKey(0))
        state = ts.state
        traj.append(np.asarray(ts.obs))
    return np.stack(traj)


def test_cartpole_matches_python():
    actions = [0, 1, 1, 0, 1, 0, 0, 1, 1, 1]
    py = CartPolePy()
    py.reset()
    py.x, py.x_dot, py.theta, py.theta_dot = 0.01, -0.02, 0.03, 0.04
    py_traj = [py.step(a)[0] for a in actions]
    env = CartPole()
    state = CartPoleState(*(jnp.asarray(v) for v in (0.01, -0.02, 0.03, 0.04)))
    jx_traj = _drive(env, state, actions, CartPoleState)
    np.testing.assert_allclose(jx_traj, np.asarray(py_traj), rtol=1e-5, atol=1e-6)


def test_mountain_car_matches_python():
    actions = [0, 2, 2, 2, 1, 0, 0, 2, 2, 0]
    py = MountainCarPy()
    py.reset()
    py.position, py.velocity = -0.5, 0.0
    py_traj = [py.step(a)[0] for a in actions]
    env = MountainCar()
    state = MountainCarState(jnp.asarray(-0.5), jnp.asarray(0.0))
    jx_traj = _drive(env, state, actions, MountainCarState)
    np.testing.assert_allclose(jx_traj, np.asarray(py_traj), rtol=1e-5, atol=1e-6)


def test_acrobot_matches_python():
    actions = [0, 2, 1, 2, 0, 1]
    py = AcrobotPy()
    py.reset()
    py.s = [0.05, -0.03, 0.02, -0.01]
    py_traj = [py.step(a)[0] for a in actions]
    env = Acrobot()
    state = AcrobotState(*(jnp.asarray(v) for v in (0.05, -0.03, 0.02, -0.01)))
    jx_traj = _drive(env, state, actions, AcrobotState)
    np.testing.assert_allclose(jx_traj, np.asarray(py_traj), rtol=1e-4, atol=1e-5)


def test_pendulum_matches_python():
    actions = [[0.5], [-1.0], [2.0], [0.0], [-2.0]]
    py = PendulumPy()
    py.reset()
    py.theta, py.theta_dot = 0.3, -0.2
    py_traj = [py.step(a)[0] for a in actions]
    env = Pendulum()
    state = PendulumState(jnp.asarray(0.3), jnp.asarray(-0.2))
    jx_traj = _drive(env, state, [jnp.asarray(a) for a in actions], PendulumState)
    np.testing.assert_allclose(jx_traj, np.asarray(py_traj), rtol=1e-5, atol=1e-6)


def test_cartpole_terminates_at_bounds():
    env = CartPole()
    state = CartPoleState(jnp.asarray(2.39), jnp.asarray(5.0), jnp.asarray(0.0), jnp.asarray(0.0))
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(0))
    assert bool(ts.done)


def test_mountain_car_goal():
    env = MountainCar()
    state = MountainCarState(jnp.asarray(0.49), jnp.asarray(0.07))
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(0))
    assert bool(ts.done)


def test_multitask_fails_on_missed_ball():
    from repro.envs.multitask import Multitask, MultitaskState

    env = Multitask()
    state = MultitaskState(
        paddle_x=jnp.asarray(0.1), ball_x=jnp.asarray(0.9), ball_y=jnp.asarray(0.99),
        lane=jnp.asarray(0, jnp.int32), obs_lane=jnp.asarray(2, jnp.int32),
        obs_y=jnp.asarray(0.0), t=jnp.asarray(0, jnp.int32),
    )
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(0))
    assert bool(ts.done)
    assert float(ts.reward) < 0


def test_lightsout_solver_solves():
    from repro.envs.puzzle import LightsOut

    env = LightsOut(n=4, scramble_presses=5)
    key = jax.random.PRNGKey(5)
    state, obs = env.reset(key)
    presses = env.solve(np.asarray(state.board))
    for p in presses:
        ts = env.step(state, jnp.asarray(p), key)
        state = ts.state
    assert int(np.asarray(state.board).sum()) == 0
    assert bool(ts.done)


def test_autoreset_keeps_episodes_flowing():
    from repro.core import AutoReset, Vec

    env = Vec(AutoReset(make("MountainCar-v0")), 4)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    for i in range(250):  # > TimeLimit of 200 — must keep running via autoreset
        actions = jnp.zeros((4,), jnp.int32)
        ts = env.step(state, actions, jax.random.fold_in(key, i))
        state = ts.state
    assert np.all(np.isfinite(np.asarray(ts.obs)))
