"""Failure detection & the fault-injection harness (simulated clocks only).

HeartbeatMonitor timeout/quorum semantics, FaultInjector exactly-once
scheduled delivery, recovery planning over survivors, and the straggler
telemetry wired through HostPool's worker steps — every clock here is
scripted, so the tests are deterministic on any machine.
"""
import numpy as np
import pytest

from repro.pool.host import HostPool
from repro.runtime.failures import (DeviceLossError, Fault, FaultInjector,
                                    HeartbeatMonitor, plan_recovery)
from repro.runtime.straggler import StragglerTracker


# -- heartbeat monitor ---------------------------------------------------------

def test_dead_host_revives_on_next_beat():
    clk = [0.0]
    mon = HeartbeatMonitor(3, timeout_s=5.0, clock=lambda: clk[0])
    for h in range(3):
        mon.beat(h, 1)
    clk[0] = 10.0
    mon.beat(0, 2)
    mon.beat(1, 2)
    assert mon.dead_hosts() == [2]
    assert not mon.healthy()
    mon.beat(2, 2)                       # silence ends: host is live again
    assert mon.healthy()
    assert mon.quorum_step() == 2


def test_quorum_step_ignores_dead_hosts():
    clk = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=2.0, clock=lambda: clk[0])
    for h in range(4):
        mon.beat(h, 10)
    clk[0] = 1.0
    for h in range(3):                   # host 3 stalls at step 10
        mon.beat(h, 50)
    assert mon.quorum_step() == 10       # still live: it drags the quorum
    clk[0] = 2.5                         # host 3 silent > 2s; rest beat at 1.0
    assert mon.dead_hosts() == [3]
    assert mon.quorum_step() == 50       # dead: no longer counted


def test_plan_recovery_notes_and_sizing():
    clk = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=1.0, clock=lambda: clk[0])
    for h in range(4):
        mon.beat(h, 7)
    clk[0] = 5.0
    for h in (0, 2):
        mon.beat(h, 9)
    plan = plan_recovery(mon, devices_per_host=2, checkpoint_step=8)
    assert plan.surviving_hosts == [0, 2]
    assert plan.new_device_count == 4
    assert plan.restart_step == 8
    assert "[1, 3]" in plan.notes


# -- fault injector ------------------------------------------------------------

def test_faults_deliver_exactly_once_in_order():
    clk = [0.0]
    inj = FaultInjector(
        faults=[Fault(3.0, "host_death", 1), Fault(1.0, "device_loss", 2)],
        clock=lambda: clk[0])
    assert inj.due() == []
    clk[0] = 2.0
    fired = inj.due()
    assert [(f.kind, f.arg) for f in fired] == [("device_loss", 2)]
    assert inj.due() == []               # exactly once
    clk[0] = 10.0
    assert [f.kind for f in inj.due()] == ["host_death"]
    assert len(inj.fired()) == 2 and inj.pending() == []


def test_due_kind_filter_leaves_other_kinds_pending():
    clk = [5.0]
    inj = FaultInjector(clock=lambda: clk[0])
    inj.schedule(1.0, "stall", 7)
    inj.schedule(2.0, "preempt_save")
    assert [f.arg for f in inj.due(kinds=("stall",))] == [7]
    assert [f.kind for f in inj.pending()] == ["preempt_save"]
    assert [f.kind for f in inj.due()] == ["preempt_save"]


def test_schedule_keeps_time_order():
    clk = [100.0]
    inj = FaultInjector(clock=lambda: clk[0])
    inj.schedule(9.0, "b")
    inj.schedule(1.0, "a")
    inj.schedule(5.0, "c")
    assert [f.kind for f in inj.due()] == ["a", "c", "b"]


def test_device_loss_error_carries_count():
    err = DeviceLossError(3)
    assert err.n_lost == 3
    assert "3 device" in str(err)
    assert isinstance(err, RuntimeError)


# -- straggler telemetry through HostPool --------------------------------------

class _ClockedEnv:
    """PythonRunner-contract env whose step() advances the scripted clock by
    a per-instance amount — a deterministic slow lane."""

    def __init__(self, clk, cost):
        self.clk, self.cost = clk, cost

    def seed(self, s):
        pass

    def reset(self):
        return np.zeros(2, np.float32)

    def step(self, action):
        self.clk[0] += self.cost
        return np.zeros(2, np.float32), 1.0, False, {}

    def action_space_sample(self):
        return 0


def test_hostpool_times_lanes_and_flags_stragglers():
    """Every worker step is timed into the tracker; the lane that takes 4x
    the median gets profile->demote advice. num_workers=1 + scripted clock
    keeps the EWMAs exactly reproducible."""
    clk = [0.0]
    costs = [1.0, 1.0, 4.0, 1.0]
    made = iter(costs)
    pool = HostPool(lambda: _ClockedEnv(clk, next(made)), num_envs=4,
                    num_workers=1, clock=lambda: clk[0])
    pool.reset()
    for _ in range(4):                   # poll after each step, like a
        pool.step(np.zeros(4, np.int32))  # monitoring loop: strikes accrue
        reports = pool.stragglers()       # per evaluation
    assert [r.host_id for r in reports] == [2]
    assert reports[0].advice == "demote"        # patience=3 strikes hit
    assert reports[0].ewma_s == pytest.approx(4.0)
    assert reports[0].median_s == pytest.approx(1.0)
    assert pool.tracker.hosts_to_demote() == [2]
    pool.close()


def test_hostpool_accepts_external_tracker():
    clk = [0.0]
    tr = StragglerTracker(threshold=2.0, patience=1)
    pool = HostPool(lambda: _ClockedEnv(clk, 1.0), num_envs=2,
                    num_workers=1, tracker=tr, clock=lambda: clk[0])
    pool.reset()
    pool.step(np.zeros(2, np.int32))
    assert set(tr.ewma) == {0, 1}               # lanes registered lazily
    assert pool.stragglers() == []              # equal lanes: nobody flagged
    pool.close()
