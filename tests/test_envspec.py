"""EnvSpec pipeline + derived-layout coverage (the api_redesign contract).

Three groups:

  - layout derivation: for every fused base env, the auto-derived
    `FusedSpec` must reproduce the hand-written row layout that
    kernels/envstep/specs.py used to carry as per-env field tables
    (`_LEGACY_LAYOUT` below is that table, captured verbatim from the old
    code before deletion), and flatten/unflatten must be exact inverses
    including dtypes.
  - golden traces through `make_vec`: the 32-step checksums committed under
    tests/golden/ must be *bit-identical* through the new frontend's vmap
    path, and within golden tolerance through backend="auto".
  - registry API: `register_family` id generation, the legacy
    `register(name, factory)` shim round-trip, and the helpful
    unknown-kwargs error from `make()`.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_leaves_match

from repro.core import (EnvSpec, declared_pipeline, make, pipeline, register,
                        registered, spec, spec_of)
from repro.core.registry import _REGISTRY
from repro.core.spaces import sample_batch
from repro.core.wrappers import TimeLimit, Vec
from repro.envs.classic import CartPole
from repro.kernels.envstep import spec_for
from repro.kernels.envstep.specs import derive_layout

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

#: the hand-written layout table the old specs.py carried, captured from the
#: per-env `FusedSpec(name, state_size, obs_size, ...)` rows (plus the row
#: order the dynamics index) before the table was deleted. The derived
#: layout must keep reproducing it — bit-compatibility of every fused
#: kernel depends on the row order.
_LEGACY_LAYOUT = {
    # id of a registry entry whose core is the env: (S, O, obs_is_state,
    #                                               row order of fields)
    "CartPole-raw": (4, 4, True, ("x", "x_dot", "theta", "theta_dot")),
    "MountainCar-raw": (2, 2, True, ("position", "velocity")),
    "Pendulum-raw": (2, 3, False, ("theta", "theta_dot")),
    "Acrobot-raw": (4, 6, False, ("theta1", "theta2", "dtheta1", "dtheta2")),
    "LightsOut-raw": (26, 25, False, ("board", "t")),
    "Pong-raw": (6, 6, True, ("ball_x", "ball_y", "ball_vx", "ball_vy",
                              "player_y", "opp_y")),
    "Breakout-raw": (29, 29, True, ("ball_x", "ball_y", "ball_vx", "ball_vy",
                                    "paddle_x", "bricks")),
    "FrozenLake-raw": (17, 16, False, ("pos", "holes")),
    "CliffWalk-raw": (49, 48, False, ("pos", "cliff")),
    "Maze-raw": (66, 64, False, ("pos", "goal", "walls")),
    "Snake-raw": (76, 36, False, ("head", "food", "length", "eaten",
                                  "ages", "prio")),
}


@pytest.mark.parametrize("name", sorted(_LEGACY_LAYOUT))
def test_derived_layout_matches_legacy_table(name):
    """Auto-derived FusedSpec == the deleted hand-written layout, row for row."""
    s, o, obs_is_state, order = _LEGACY_LAYOUT[name]
    env = make(name)
    fs = spec_for(env)
    assert fs is not None, name
    assert (fs.state_size, fs.obs_size, fs.obs_is_state) == (s, o, obs_is_state)
    # Row order: flatten a batched reset state and check each field lands in
    # the block the legacy layout assigned it.
    venv = Vec(env, 3)
    state, _ = venv.reset(jax.random.PRNGKey(0))
    rows = fs.flatten(state)
    assert rows.shape == (s, 3) and rows.dtype == jnp.float32
    offset = 0
    for field in order:
        leaf = np.asarray(getattr(state, field), np.float32)
        block = leaf.reshape(3, -1).T          # (size, B), row-major
        np.testing.assert_array_equal(
            np.asarray(rows[offset:offset + block.shape[0]]), block,
            err_msg=f"{name}.{field} rows")
        offset += block.shape[0]
    assert offset == s


@pytest.mark.parametrize("name", sorted(_LEGACY_LAYOUT))
def test_flatten_unflatten_round_trip(name):
    """unflatten(flatten(state)) == state exactly, dtypes included."""
    env = make(name)
    fs = spec_for(env)
    venv = Vec(env, 4)
    state, _ = venv.reset(jax.random.PRNGKey(1))
    back = fs.unflatten(fs.flatten(state))
    assert type(back) is type(state)
    assert_leaves_match(state, back, f"{name} roundtrip")


def test_derive_layout_rejects_bad_field_order():
    with pytest.raises(ValueError, match="field_order"):
        derive_layout(CartPole(), field_order=("x", "x_dot"))


# -- golden traces through the make_vec frontend ------------------------------

def _golden_params():
    out = []
    for name in registered():
        marks = [pytest.mark.slow] if spec(name).pixels else []
        out.append(pytest.param(name, marks=marks))
    return out


def _pool_trace(name: str, backend: str):
    """test_golden.trace, but driven through `make_vec(...).xla()`."""
    from repro.pool import make_vec

    want = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    batch, steps = want["batch"], want["steps"]
    env = make(name)
    handle = make_vec(name, batch, backend=backend).xla()
    key = jax.random.PRNGKey(sum(map(ord, name)))
    ps = handle.init(key)
    rows = []
    for t in range(steps):
        a = sample_batch(env.action_space, jax.random.fold_in(key, 1000 + t),
                         batch)
        ps, out = handle.step(ps, a, jax.random.fold_in(key, t))
        rows.append([float(np.asarray(out.obs, np.float64).sum()),
                     float(np.asarray(out.reward, np.float64).sum()),
                     int(np.asarray(out.done).sum())])
    return want, rows


@pytest.mark.parametrize("name", _golden_params())
def test_golden_bit_identical_through_make_vec(name):
    """The committed checksums hold *bit for bit* through the new frontend:
    `make_vec(id, B, backend="vmap").xla()` is the same computation the
    golden generator ran, so equality is exact, not allclose."""
    want, rows = _pool_trace(name, "vmap")
    assert rows == want["rows"], f"{name}: make_vec(vmap) trace diverged"


@pytest.mark.slow
@pytest.mark.parametrize("name", _golden_params())
def test_golden_through_auto_backend(name):
    """backend="auto" (fused megastep where supported) reproduces the same
    committed checksums within golden tolerance."""
    want, rows = _pool_trace(name, "auto")
    np.testing.assert_allclose(
        np.asarray(rows, np.float64), np.asarray(want["rows"], np.float64),
        rtol=1e-4, atol=1e-4,
        err_msg=f"{name}: make_vec(auto) drifted from the golden trace")


# -- registry API -------------------------------------------------------------

def test_register_family_generated_ids():
    """One family entry -> the declared -v/-px/-raw trio, with pipelines."""
    s = spec("FrozenLake-v0")
    assert s.transforms == (pipeline.TimeLimit(100),)
    assert s.max_steps == 100 and not s.pixels and "grid" in s.tags
    px = spec("FrozenLake-px")
    assert px.transforms == (pipeline.TimeLimit(100), pipeline.ObsToPixels(),
                             pipeline.FrameStack(4))
    assert px.pixels and "pixels" in px.tags
    raw = spec("FrozenLake-raw")
    assert raw.transforms == () and raw.max_steps is None
    assert "raw" in raw.tags
    arcade = spec("Pong-v0")
    assert arcade.pixels and arcade.max_steps == 1000


def test_third_party_register_round_trips():
    """The legacy `register(name, factory)` shim: an opaque wrapper-stack
    factory still registers, builds, and answers the spec API."""
    name = "ThirdParty-test-v0"
    register(name, lambda **kw: TimeLimit(CartPole(**kw), 7))
    try:
        assert name in registered()
        s = spec(name)
        assert isinstance(s, EnvSpec) and s.transforms == ()
        env = make(name)
        assert env.spec is s and spec_of(env) is s
        assert isinstance(env, TimeLimit) and env.max_steps == 7
        # opaque stacks still walk back through their reconstructible wrappers
        core, transforms = declared_pipeline(env)
        assert isinstance(core, CartPole)
        assert transforms == (pipeline.TimeLimit(7),)
        with pytest.raises(ValueError, match="already registered"):
            register(name, CartPole)
    finally:
        _REGISTRY.pop(name, None)


def test_make_unknown_kwargs_error_is_helpful():
    with pytest.raises(TypeError, match=r"gravity.*CartPole-v1|CartPole-v1.*gravity"):
        make("CartPole-v1", gravity=9.8)
    with pytest.raises(TypeError, match=r"scramble_presses"):
        # the error names what IS accepted
        make("LightsOut-v0", bogus=1)
    # opaque factory: the id is still named even though the TypeError comes
    # from inside the factory
    name = "ThirdParty-test-v1"
    register(name, lambda: CartPole())
    try:
        with pytest.raises(TypeError, match=name.replace("-", "[-]")):
            make(name, whatever=3)
    finally:
        _REGISTRY.pop(name, None)


def test_spec_unknown_id_error():
    with pytest.raises(KeyError, match="Nope-v0"):
        spec("Nope-v0")
