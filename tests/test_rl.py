"""RL stack: replay semantics, DQN/PPO mechanics, host-mode parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make
from repro.rl.replay import replay_add_batch, replay_init, replay_sample


def test_replay_ring_wraps():
    st = replay_init(8, (2,))
    for i in range(3):
        obs = jnp.full((4, 2), float(i))
        st = replay_add_batch(st, obs, jnp.zeros((4,), jnp.int32),
                              jnp.zeros((4,)), obs, jnp.zeros((4,)))
    assert int(st.size) == 8
    assert int(st.ptr) == 4
    # oldest batch (i=0) was overwritten by i=2
    vals = set(np.unique(np.asarray(st.obs)).tolist())
    assert 0.0 not in vals and {1.0, 2.0} <= vals


def test_replay_batch_larger_than_capacity_keeps_latest():
    """Regression: a batch wider than the ring must behave like sequential
    insertion (later transitions win), not scatter with duplicate indices
    (unspecified order). With cap=4, ptr=0 and values 0..5, slot j must hold
    the last i with i % 4 == j: [4, 5, 2, 3]."""
    st = replay_init(4, (1,))
    batch = jnp.arange(6, dtype=jnp.float32)[:, None]
    st = replay_add_batch(st, batch, jnp.arange(6, dtype=jnp.int32),
                          jnp.arange(6, dtype=jnp.float32), batch,
                          jnp.zeros((6,)))
    assert np.asarray(st.obs)[:, 0].tolist() == [4.0, 5.0, 2.0, 3.0]
    assert np.asarray(st.action).tolist() == [4, 5, 2, 3]
    assert int(st.ptr) == 2 and int(st.size) == 4
    # and the pointer keeps ring semantics for the next (normal) insert
    st = replay_add_batch(st, jnp.full((1, 1), 9.0),
                          jnp.asarray([9], jnp.int32), jnp.asarray([9.0]),
                          jnp.full((1, 1), 9.0), jnp.zeros((1,)))
    assert np.asarray(st.obs)[:, 0].tolist() == [4.0, 5.0, 9.0, 3.0]


def test_replay_sample_only_valid():
    st = replay_init(16, (1,))
    st = replay_add_batch(st, jnp.ones((4, 1)), jnp.zeros((4,), jnp.int32),
                          jnp.ones((4,)), jnp.ones((4, 1)), jnp.zeros((4,)))
    obs, a, r, no, d = replay_sample(st, jax.random.PRNGKey(0), 32)
    assert np.all(np.asarray(obs) == 1.0)  # never samples unwritten slots


def test_dqn_host_mode_runs():
    from repro.envs.baseline_python import BASELINES
    from repro.rl.dqn import DQNConfig, train_host

    env = make("CartPole-v1")
    cfg = DQNConfig(learn_start=50)
    params, returns = train_host(BASELINES["CartPole-v1"], env, cfg, 300,
                                 jax.random.PRNGKey(0))
    assert len(returns) >= 1
    assert all(np.isfinite(r) for r in returns)


def test_ppo_improves_on_cartpole():
    from repro.rl.ppo import PPOConfig, train

    env = make("CartPole-v1")
    cfg = PPOConfig(num_envs=8, rollout_len=64, epochs=2, minibatches=2)
    state, metrics = train(env, cfg, 12, jax.random.PRNGKey(0))
    rets = np.asarray(metrics["return"])
    assert rets[-1] > rets[0]
    assert np.isfinite(np.asarray(metrics["loss"])).all()


def test_gradient_compression_roundtrip_and_feedback():
    from repro.train.compression import (
        compress_decompress, compress_with_feedback, residual_init)

    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (32, 32)), "b": jax.random.normal(key, (32,)) * 10}
    out = compress_decompress(grads)
    for g, o in zip(jax.tree.leaves(grads), jax.tree.leaves(out)):
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(g - o))) <= scale * 0.5 + 1e-6

    res = residual_init(grads)
    out1, res = compress_with_feedback(grads, res)
    out2, res = compress_with_feedback(grads, res)
    # over two steps the accumulated output approaches 2x the true gradient
    err0 = float(jnp.max(jnp.abs(grads["w"] * 2 - (out1["w"] + out2["w"]))))
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127.0
    assert err0 <= scale + 1e-6  # error feedback bounds the accumulated error


def test_optimizer_converges_quadratic():
    from repro.train.optim import Adam

    opt = Adam(lr=0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: (p["x"] - 2.0) ** 2)(params)
        params, state = opt.update(grads, state, params)
    assert abs(float(params["x"]) - 2.0) < 1e-2
