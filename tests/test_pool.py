"""Pool engines: parity contracts across all three backends (docs/pool.md).

  - EnvPool.rollout ≡ runner.rollout_random_fast (same RNG scheme, bit-exact)
  - EnvPool stateful reset/step ≡ the pure xla() path it wraps
  - ShardedEnvPool ≡ EnvPool on a 1-device mesh (bit-exact), and genuinely
    shards state across devices on a multi-device mesh (subprocess, 8 fake)
  - HostPool ≡ PythonRunner on the interpreted baselines (bit-exact)
  - the compiled step loop contains zero host transfers (HLO-verified)
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make
from repro.core.runner import PythonRunner, rollout_random, rollout_random_fast
from repro.envs.baseline_python import BASELINES
from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import EnvPool, HostPool, ShardedEnvPool, default_pool_mesh, make_pool


@pytest.mark.slow
def test_envpool_rollout_matches_runner():
    """The pool's compiled rollout is the runner fast path, bit-exact."""
    env = make("CartPole-v1")
    key = jax.random.PRNGKey(3)
    rew_p, eps_p, _ = EnvPool(env, 8).rollout(300, key)
    rew_r, eps_r, _ = rollout_random_fast(env, key, 300, 8)
    np.testing.assert_array_equal(np.asarray(rew_p), np.asarray(rew_r))
    np.testing.assert_array_equal(np.asarray(eps_p), np.asarray(eps_r))
    # and behaves like the reference rollout_random loop (episodes complete)
    _, eps_ref, _ = rollout_random(env, key, 300, 8)
    assert int(np.asarray(eps_p).sum()) > 0 and int(np.asarray(eps_ref).sum()) > 0


def test_envpool_stateful_matches_xla_path():
    """Gym-style reset/step is the pure xla() program driven statefully."""
    env = make("CartPole-v1")
    pool = EnvPool(env, 4)
    h = pool.xla()
    jit_step = jax.jit(h.step)  # same program as the stateful fast path

    obs = pool.reset(seed=0)
    ps = h.init(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(obs), np.asarray(ps.obs))

    outs = []
    for i in range(20):
        actions = pool.sample_actions(i)
        obs, rew, done, info = pool.step(actions)
        ps, out = jit_step(ps, actions)
        outs.append((np.asarray(obs), np.asarray(rew), np.asarray(done)))
        np.testing.assert_array_equal(outs[-1][0], np.asarray(out.obs))
        np.testing.assert_array_equal(outs[-1][1], np.asarray(out.reward))
        np.testing.assert_array_equal(outs[-1][2], np.asarray(out.done))
    # donated state buffers must not invalidate previously returned outputs
    assert all(np.isfinite(o).all() for o, _, _ in outs)


def test_envpool_autoresets_and_reports_terminal_obs():
    pool = EnvPool("MountainCar-v0", 4)  # TimeLimit 200 forces dones
    pool.reset(seed=0)
    done_seen = False
    for i in range(210):
        obs, rew, done, info = pool.step(jnp.zeros((4,), jnp.int32))
        if bool(np.asarray(done).any()):
            done_seen = True
            assert "terminal_obs" in info
    assert done_seen
    assert np.isfinite(np.asarray(obs)).all()  # kept running past the limit


@pytest.mark.slow
def test_sharded_pool_matches_unsharded_on_one_device_mesh():
    env = make("CartPole-v1")
    key = jax.random.PRNGKey(5)
    mesh = default_pool_mesh(1)
    sharded = ShardedEnvPool(env, 8, mesh=mesh)
    plain = EnvPool(env, 8)

    rew_s, eps_s, _ = sharded.rollout(250, key)
    rew_u, eps_u, _ = plain.rollout(250, key)
    np.testing.assert_array_equal(np.asarray(rew_s), np.asarray(rew_u))
    np.testing.assert_array_equal(np.asarray(eps_s), np.asarray(eps_u))

    obs_s, obs_u = sharded.reset(seed=1), plain.reset(seed=1)
    np.testing.assert_array_equal(np.asarray(obs_s), np.asarray(obs_u))
    for i in range(5):
        a = plain.sample_actions(i)
        out_s = sharded.step(a)
        out_u = plain.step(a)
        for s, u in zip(out_s[:3], out_u[:3]):
            np.testing.assert_array_equal(np.asarray(s), np.asarray(u))


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import numpy as np
from repro.core import make
from repro.pool import ShardedEnvPool, default_pool_mesh

pool = ShardedEnvPool(make("CartPole-v1"), 64, mesh=default_pool_mesh())
rew, eps, _ = pool.rollout(200, jax.random.PRNGKey(0))
obs = pool.reset(seed=0)
n_dev = len(set(obs.sharding.device_set))
o, r, d, info = pool.step(pool.sample_actions(1))
print(json.dumps({
    "n_shards": pool.n_shards,
    "devices_holding_obs": n_dev,
    "episodes": int(np.asarray(eps).sum()),
    "finite": bool(np.isfinite(np.asarray(rew)).all()
                   and np.isfinite(np.asarray(o)).all()),
}))
"""


@pytest.mark.slow
def test_sharded_pool_spans_devices():
    """On an 8-device mesh the batch is physically distributed."""
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, timeout=600, env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_shards"] == 8
    assert res["devices_holding_obs"] == 8
    assert res["episodes"] > 0
    assert res["finite"]


def test_hostpool_matches_python_runner():
    """1-env HostPool reproduces PythonRunner bit-exactly (same seed, rng)."""
    for name in ("CartPole-v1", "Pendulum-v1"):
        runner_r, runner_e = PythonRunner(BASELINES[name]).run(400, seed=7)
        pool_r, pool_e = HostPool(name, num_envs=1).run_random(400, seed=7)
        assert runner_r == pytest.approx(float(pool_r[0]))
        assert runner_e == int(pool_e[0])


def test_hostpool_batched_step_semantics():
    pool = HostPool("CartPole-v1", num_envs=4)
    obs = pool.reset(seed=0)
    assert obs.shape == (4, 4)
    any_done = False
    for _ in range(60):
        pool.send(np.zeros((4,), np.int64))  # async: dispatch, then join
        obs, rew, done, info = pool.recv()
        any_done = any_done or bool(done.any())
    assert obs.shape == (4, 4) and rew.shape == (4,) and done.shape == (4,)
    assert info["terminal_obs"].shape == (4, 4)
    assert any_done  # always-left policy falls over well within 60 steps
    pool.close()


def test_make_pool_backends():
    assert isinstance(make_pool("CartPole-v1", 4), EnvPool)
    assert isinstance(make_pool("CartPole-v1", 4, backend="sharded"), ShardedEnvPool)
    assert isinstance(make_pool("CartPole-v1", 4, backend="host"), HostPool)
    with pytest.raises(ValueError):
        make_pool("CartPole-v1", 4, backend="jvm")


def test_make_vec_frontend_dispatch():
    """One constructor, the right pool: default EnvPool, mesh -> sharded,
    host=True -> HostPool; backend="auto" resolves per fused support."""
    from repro.core import make
    from repro.pool import make_vec

    pool = make_vec("CartPole-v1", 4)
    assert type(pool) is EnvPool
    assert pool.backend == "pallas"          # auto: CartPole fuses
    assert make_vec("Multitask-v0", 4).backend == "vmap"  # auto: no spec
    assert make_vec("CartPole-v1", 4, backend="vmap").backend == "vmap"
    sharded = make_vec("CartPole-v1", 4, mesh=default_pool_mesh(1), unroll=3)
    assert isinstance(sharded, ShardedEnvPool) and sharded.unroll == 3
    host = make_vec("CartPole-v1", 2, host=True)
    assert isinstance(host, HostPool) and len(host) == 2
    # an Env instance works too (the rl/ learners construct this way)
    assert type(make_vec(make("CartPole-v1"), 4)) is EnvPool


def test_make_vec_frontend_errors():
    from repro.core import make
    from repro.pool import make_vec

    with pytest.raises(ValueError, match="backend"):
        make_vec("CartPole-v1", 4, backend="jvm")
    with pytest.raises(ValueError, match="registry id"):
        make_vec(make("CartPole-v1"), 4, host=True)
    with pytest.raises(ValueError, match="env_kwargs"):
        make_vec(make("CartPole-v1"), 4, n=5)
    with pytest.raises(TypeError, match="bogus"):
        make_vec("CartPole-v1", 4, bogus=1)  # registry names the bad kwarg
    with pytest.raises(ValueError, match="host=True"):
        # baselines are fixed-config ports; kwargs must not be dropped
        make_vec("LightsOut-v0", 2, host=True, n=4)


def test_make_vec_rollout_matches_envpool():
    """The frontend is construction sugar only: same engine, same numbers."""
    from repro.pool import make_vec

    key = jax.random.PRNGKey(11)
    rew_f, eps_f, _ = make_vec("Pendulum-v1", 4, backend="vmap").rollout(20, key)
    rew_e, eps_e, _ = EnvPool("Pendulum-v1", 4).rollout(20, key)
    np.testing.assert_array_equal(np.asarray(rew_f), np.asarray(rew_e))
    np.testing.assert_array_equal(np.asarray(eps_f), np.asarray(eps_e))


def test_pool_step_loop_is_device_resident():
    """Acceptance: no host transfers inside the compiled step loop (fig4)."""
    pool = EnvPool("CartPole-v1", 16)
    hlo = pool.rollout_lowered(64).compile().as_text()
    assert host_transfer_ops(hlo) == []


def _env():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return env
