"""End-to-end behaviour tests for the paper's core claims.

C4/C5 (drop-in API + compiled run fast-path), C1 directionally (compiled
rollouts beat the interpreted baseline), and the learner integration.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make, make_compat, registered, rollout, rollout_random, PythonRunner
from repro.envs.baseline_python import BASELINES

ALL_ENVS = ["CartPole-v1", "Acrobot-v1", "MountainCar-v0", "Pendulum-v1",
            "Multitask-v0", "LightsOut-v0"]


def test_registry_lists_gym_names():
    names = registered()
    for n in ALL_ENVS:
        assert n in names


@pytest.mark.parametrize("name", ALL_ENVS)
def test_make_reset_step_render(name):
    env = make(name)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    action = env.action_space.sample(jax.random.PRNGKey(1))
    ts = env.step(state, action, jax.random.PRNGKey(2))
    assert ts.obs.shape == env.observation_space.shape
    assert np.isfinite(float(ts.reward))
    frame = env.render(ts.state)
    assert frame.shape == (84, 84)
    assert float(frame.max()) <= 1.0 and float(frame.min()) >= 0.0


def test_gym_compat_is_drop_in():
    """Paper Listing 2: the exact Gym loop runs unchanged."""
    e = make_compat("CartPole-v1", seed=3)
    for _ in range(3):
        e.reset()
        term, steps = False, 0
        while not term and steps < 50:
            steps += 1
            s1, r, term, info = e.step(e.action_space.sample())
            obs = e.render()
        assert steps > 1
        assert obs.shape == (84, 84)


def test_compiled_rollout_runs_episodes():
    env = make("CartPole-v1")
    rew, eps, _ = rollout_random(env, jax.random.PRNGKey(0), 500, 32)
    assert int(eps.sum()) > 0          # episodes complete inside the program
    assert rew.shape == (32,)


def test_compiled_beats_interpreted_baseline():
    """Fig. 1 direction: compiled env throughput > interpreted baseline."""
    env = make("CartPole-v1")
    steps, batch = 1000, 32
    # warm up compile
    jax.block_until_ready(rollout_random(env, jax.random.PRNGKey(0), steps, batch)[0])
    t0 = time.perf_counter()
    jax.block_until_ready(rollout_random(env, jax.random.PRNGKey(1), steps, batch)[0])
    cairl_sps = steps * batch / (time.perf_counter() - t0)

    runner = PythonRunner(BASELINES["CartPole-v1"])
    t0 = time.perf_counter()
    runner.run(2000)
    py_sps = 2000 / (time.perf_counter() - t0)
    assert cairl_sps > py_sps, (cairl_sps, py_sps)


def test_policy_rollout_shapes():
    env = make("CartPole-v1")

    def policy(params, obs, key):
        return jax.random.randint(key, (), 0, 2)

    traj = rollout(env, policy, None, 16, 8, jax.random.PRNGKey(0))
    assert traj.obs.shape == (16, 8, 4)
    assert traj.reward.shape == (16, 8)
    assert traj.done.dtype == jnp.bool_


@pytest.mark.slow
def test_dqn_short_run_improves_over_random():
    from repro.rl.dqn import DQNConfig, train_compiled, greedy_returns

    env = make("CartPole-v1")
    cfg = DQNConfig(num_envs=4, exploration_steps=3000, learn_start=200,
                    lr=1e-3, batch_size=64, target_update_freq=250, units=(64, 64))
    state, apply_fn, metrics = train_compiled(env, cfg, 8000, jax.random.PRNGKey(0))
    rets = np.asarray(greedy_returns(env, apply_fn, state.params, jax.random.PRNGKey(7)))
    assert np.isfinite(metrics["loss"][-1])
    assert rets.mean() > 15.0  # random policy averages ~9.3
