"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.raster import rasterize_pallas, rasterize_ref


@pytest.mark.parametrize("b,s,h,w", [(1, 1, 16, 16), (4, 6, 84, 84), (8, 3, 32, 130)])
def test_raster_matches_ref(b, s, h, w):
    key = jax.random.PRNGKey(b * 100 + s)
    segs = jax.random.uniform(key, (b, s, 5)) * jnp.asarray([1, 1, 1, 1, 0.1])
    intens = jax.random.uniform(jax.random.fold_in(key, 1), (b, s))
    ref = rasterize_ref(segs, intens, h, w)
    out = rasterize_pallas(segs, intens, h, w, batch_block=min(4, b), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_raster_dtype_robust():
    segs = jnp.zeros((2, 1, 5), jnp.float64) if jax.config.jax_enable_x64 else jnp.zeros((2, 1, 5))
    segs = segs.at[:, 0].set(jnp.asarray([0.2, 0.5, 0.8, 0.5, 0.05]))
    intens = jnp.ones((2, 1), jnp.float32)
    out = rasterize_pallas(segs, intens, 16, 16, batch_block=2, interpret=True)
    ref = rasterize_ref(segs.astype(jnp.float32), intens, 16, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("hq,hkv,l,d", [(4, 4, 32, 16), (4, 2, 64, 32), (8, 1, 32, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
def test_flash_attention_sweep(hq, hkv, l, d, causal, window):
    key = jax.random.PRNGKey(hq * 1000 + l)
    q = jax.random.normal(key, (2, hq, l, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, hkv, l, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, hkv, l, d), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 32, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 32, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 32, 32), jnp.bfloat16)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_block_shape_independence():
    """Different BlockSpec tilings must give identical results."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    a = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    b = flash_attention(q, k, v, block_q=32, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
