import jax


def _step_impl(carry, actions):
    return carry, actions


_step = jax.jit(_step_impl, donate_argnums=(0,))
_pair = jax.jit(lambda a, b: (a, b), donate_argnums=(0, 1))


def advance(carry, actions):
    """Forwards its parameter into the donated position: the *caller's*
    binding dies when this returns."""
    return _step(carry, actions)


def alias_read(carry, actions):
    stale = carry
    new_carry, out = _step(carry, actions)
    return new_carry, out, stale[0]  # alias of the donated carry


def helper_boundary(carry, actions):
    new_carry, out = advance(carry, actions)
    return new_carry, out, carry[0]  # donated through advance()


def double_donation(carry):
    twin = carry
    return _pair(carry, twin)  # one buffer in two donated positions
