import time


def timed(fn):
    t0 = time.time()  # repro: allow[wallclock] typo'd rule id: allowlists nothing, and is itself reported
    return fn(), t0
