import time


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, time.time() - t0
