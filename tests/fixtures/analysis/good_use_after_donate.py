import jax


class Pool:
    def __init__(self, fn):
        self._step = jax.jit(fn, donate_argnums=(0,))

    def run(self, carry, actions):
        new_carry, out = self._step(carry, actions)
        fresh = new_carry[0] + 1
        return new_carry, out, fresh
