import threading


class Refiller:
    """Two lock-discipline breaks: `drain` writes self._pending bare even
    though `admit` writes it under the condition, and `snapshot` calls the
    `_advance` helper — whose bare writes are only safe under the callers'
    lock — without holding it."""

    def __init__(self):
        self._cond = threading.Condition()
        self._pending = 0
        self._tick = 0

    def admit(self, n):
        with self._cond:
            self._pending += n
            self._advance()
            self._cond.notify_all()

    def drain(self):
        self._pending = 0  # guarded field written without the lock

    def _advance(self):
        self._tick += 1

    def snapshot(self):
        self._advance()  # helper relies on the caller's lock; none held
        return self._tick
