import jax


def rollout(key, obs):
    k = jax.random.split(key, 2)[0]
    action = jax.random.categorical(k, obs)
    noise = jax.random.normal(k, obs.shape)  # same k consumed twice
    return action, noise
