import random
import time

import jax


def step(state):
    jitter = random.random() + time.monotonic()
    return state + jitter


compiled_step = jax.jit(step)
