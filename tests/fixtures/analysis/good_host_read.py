import time

import jax


def step(state, jitter):
    return state + jitter


def host_loop(state):
    # host-side wall clock is fine: this function is never jitted
    t0 = time.perf_counter()
    out = jax.jit(step)(state, 0.0)
    return out, time.perf_counter() - t0
