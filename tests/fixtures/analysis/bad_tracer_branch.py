import jax
import jax.numpy as jnp


@jax.jit
def clip_reward(reward):
    total = jnp.sum(reward)
    if total > 10.0:  # Python branch on a tracer
        return reward / total
    return reward
