def load(path):
    try:
        with open(path) as f:
            return f.read()
    except Exception:
        return None
