import jax


def rollout(key, obs):
    ka, kn = jax.random.split(key)
    action = jax.random.categorical(ka, obs)
    noise = jax.random.normal(kn, obs.shape)
    return action, noise
