import threading


class SlotTable:
    def __init__(self, n):
        self._lock = threading.Lock()
        self._active = [False] * n
        self._epoch = 0

    def activate(self, i):
        self._active[i] = True  # racing with reads under self._lock
        self._epoch += 1
