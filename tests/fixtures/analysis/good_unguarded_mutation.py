import threading


class SlotTable:
    def __init__(self, n):
        self._lock = threading.Lock()
        self._active = [False] * n
        self._epoch = 0

    def activate(self, i):
        with self._lock:
            self._active[i] = True
            self._epoch += 1
