def load(path):
    try:
        with open(path) as f:
            return f.read()
    except FileNotFoundError:
        return None
