import time


def timed(fn):
    t0 = time.time()  # repro: allow[wall-clock] exercising the pragma path
    out = fn()
    # repro: allow[wall-clock] pragma on the line above a violation
    t1 = time.time()
    return out, t1 - t0
