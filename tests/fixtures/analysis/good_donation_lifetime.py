import jax


def _step_impl(carry, actions):
    return carry, actions


_step = jax.jit(_step_impl, donate_argnums=(0,))
_pair = jax.jit(lambda a, b: (a, b), donate_argnums=(0, 1))


def advance(carry, actions):
    return _step(carry, actions)


def alias_rebound(carry, actions):
    stale = carry
    new_carry, out = _step(carry, actions)
    stale = new_carry  # retargeted before any read
    return new_carry, out, stale[0]


def helper_boundary(carry, actions):
    carry, out = advance(carry, actions)  # rebinding resurrects the name
    return carry, out, carry[0]


def double_donation(left, right):
    return _pair(left, right)  # two distinct buffers
