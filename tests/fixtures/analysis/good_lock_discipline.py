import threading


class Refiller:
    def __init__(self):
        self._cond = threading.Condition()
        self._pending = 0
        self._tick = 0

    def admit(self, n):
        with self._cond:
            self._pending += n
            self._advance()
            self._cond.notify_all()

    def drain(self):
        with self._cond:
            self._pending = 0

    def _advance(self):
        self._tick += 1

    def snapshot(self):
        with self._cond:
            self._advance()
            return self._tick
