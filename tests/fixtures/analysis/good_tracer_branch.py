import jax
import jax.numpy as jnp


@jax.jit
def clip_reward(reward):
    total = jnp.sum(reward)
    if reward.ndim > 1:  # static under tracing: shape metadata
        reward = reward.reshape(-1)
    return jnp.where(total > 10.0, reward / total, reward)
