"""Serving engine: continuous batching, per-slot decode correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import lm
from repro.serving.engine import Request, ServeEngine


def _setup(arch="yi-6b"):
    cfg = get_config(arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests():
    cfg, params = _setup()
    eng = ServeEngine(cfg, params, slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + i), max_new_tokens=6)
            for i in range(7)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=300)
    for r in reqs:
        assert r.output is not None and len(r.output) >= 6


def test_engine_matches_sequential_decode():
    """A request served through slot batching == the same request decoded alone."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6)

    # engine path (mixed with another request of different length)
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    target = Request(rid=0, prompt=prompt, max_new_tokens=5)
    other = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 9), max_new_tokens=5)
    eng.submit(target)
    eng.submit(other)
    eng.run(max_ticks=100)

    # reference path: greedy decode alone
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, caches = lm.prefill(cfg, params, batch, max_seq=64)
    toks = [int(jnp.argmax(logits[:, -1], -1)[0])]
    pos = len(prompt)
    for _ in range(4):
        lgt, caches = lm.decode_step(cfg, params, caches,
                                     jnp.asarray([[toks[-1]]], jnp.int32), pos)
        toks.append(int(jnp.argmax(lgt[0])))
        pos += 1
    assert target.output[:5] == toks[:5]


def test_decode_scalar_vs_vector_pos():
    cfg, params = _setup("h2o-danube-1.8b")  # exercises the SWA ring path
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 7)), jnp.int32)}
    logits, caches = lm.prefill(cfg, params, batch, max_seq=32)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    l1, _ = lm.decode_step(cfg, params, caches, tok, 7)
    l2, _ = lm.decode_step(cfg, params, caches, tok, jnp.asarray([7, 7]))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)
