"""API-surface snapshot: the drop-in surface cannot shrink silently.

The paper's claim is a *drop-in* toolkit (Listing 2), so the public
exports of the entry-point modules are part of the contract. This test
pins them against the checked-in snapshot below; `make api-check` runs it
standalone and `make test-fast` includes it. A deliberate surface change
updates the snapshot in the same PR — the diff is the review artifact
(same policy as tests/golden/).
"""
import pytest

#: module -> exact public surface (__all__ where defined, else public attrs)
API_SURFACE = {
    "repro": [
        "cairl", "make", "make_compat", "make_vec", "registered", "spec",
    ],
    "repro.core": [
        "AutoReset", "Box", "Discrete", "Env", "EnvSpec", "FlattenObs",
        "FrameStack", "MultiDiscrete", "ObsToPixels", "PythonRunner",
        "RewardScale", "Space", "TimeLimit", "Timestep", "Trajectory",
        "Transform", "Vec", "Wrapper", "build_pipeline", "declared_pipeline",
        "episode_return", "make", "make_compat", "pipeline", "register",
        "register_family", "register_spec", "registered", "rollout",
        "rollout_random", "spec", "spec_of", "specs",
    ],
    "repro.pool": [
        "AsyncEnvPool", "AsyncUnsupportedError", "EnvPool", "FUSED_BACKENDS",
        "HostPool", "PoolState", "PoolStep", "STEP_BACKENDS", "ShardedEnvPool",
        "XlaPool", "default_pool_mesh", "make_pool", "make_vec",
        "sample_batch",
    ],
    "repro.cairl": [
        "EnvPool", "HostPool", "ShardedEnvPool", "make", "make_functional",
        "make_pool", "make_vec", "registered", "rollout", "rollout_random",
        "spec", "spec_of",
    ],
    "repro.kernels.envstep": [
        "FusedSpec", "derive_layout", "env_megastep", "fused_step",
        "fused_transition", "lookup", "megastep_pallas", "megastep_ref",
        "spec_for", "supports",
    ],
    "repro.train": [
        "Fleet", "GOLDEN_TRAIN_IDS", "fleet", "fleet_grid",
        "fused_train_chunk", "golden_train_setup", "lower_train_chunk",
        "run_fused",
    ],
}


def _surface(module) -> list:
    if hasattr(module, "__all__"):
        return sorted(module.__all__)
    return sorted(n for n in vars(module)
                  if not n.startswith("_") and not _is_module(module, n))


def _is_module(module, name) -> bool:
    import types

    return isinstance(getattr(module, name), types.ModuleType)


@pytest.mark.parametrize("modname", sorted(API_SURFACE))
def test_public_surface_matches_snapshot(modname):
    import importlib

    module = importlib.import_module(modname)
    got = _surface(module)
    want = sorted(API_SURFACE[modname])
    missing = sorted(set(want) - set(got))
    added = sorted(set(got) - set(want))
    assert got == want, (
        f"{modname} public surface drifted — missing={missing} added={added}. "
        "If intentional, update tests/test_api_surface.py in the same PR.")


@pytest.mark.parametrize("modname", sorted(API_SURFACE))
def test_exports_resolve(modname):
    """Every snapshotted name actually resolves (no stale __all__)."""
    import importlib

    module = importlib.import_module(modname)
    for name in API_SURFACE[modname]:
        assert getattr(module, name, None) is not None, f"{modname}.{name}"