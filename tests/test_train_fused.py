"""Training-parity harness for the fused on-device trainer (repro.train.fused).

The contract under test, per golden id (dqn/CartPole-v1, dqn/FrozenLake-v0,
ppo/CartPole-v1):

  goldens    : a 64-env-step seeded training run reduced to checksums
               (params, replay cursor + ring content, final key chain, eval
               return) and committed under tests/golden/train_<algo>_<env>.json.
               The HOST-ALTERNATING path owns the files (`--regen-golden`
               rewrites them); every fused/fleet execution mode answers to
               the same committed numbers — no parallel trace set to drift.
  bit-parity : fused=True (one donated jit per chunk) reproduces fused=False
               (undonated per-chunk dispatch) bit for bit — DQN asserted
               exactly; PPO through the standard parity contract
               (`assert_leaves_match`: ints/keys exact, floats 1e-5).
  chunk seam : the RNG chain lives in the donated carry, so neither `chunk`
               nor `fused` can shift the trajectory (the regression the
               fused path's design pins — a fold_in(key, step)-per-chunk
               scheme would fail here).
  fleets     : `fleet()` rows are bit-identical (DQN) / parity-contract
               equal (PPO, float rounding under vmap) to the solo run with
               that row's (seed, lr).
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_leaves_match
from repro.core import make
from repro.rl import dqn, ppo
from repro.train import fused as F

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
EVAL_KEY = jax.random.PRNGKey(123)


def _golden_path(gid: str) -> pathlib.Path:
    return GOLDEN_DIR / f"train_{gid.replace('/', '_')}.json"


def _train(gid: str, fused: bool = False, chunk: int = 0):
    """One golden-config training run -> (cfg, final state, eval apply_fn)."""
    algo, env_id, cfg, steps = F.golden_train_setup(gid)
    env = make(env_id)
    key = jax.random.PRNGKey(sum(map(ord, gid)))
    if algo == "dqn":
        state, apply_fn, _ = dqn.train_compiled(env, cfg, steps, key,
                                                chunk=chunk, fused=fused)
        return env, cfg, state, apply_fn
    state, _ = ppo.train(env, cfg, steps, key, fused=fused, chunk=chunk)
    apply_fn = lambda p, o: ppo.ac_apply(p, o, cfg.activation)[0]
    return env, cfg, state, apply_fn


def _checksums(gid: str, env, state, apply_fn) -> dict:
    """Reduce a final training state to the committed golden fields."""
    f64sum = lambda x: float(np.asarray(jax.device_get(x), np.float64).sum())
    params = state.params
    got = {
        "id": gid,
        "param_sum": sum(f64sum(l) for l in jax.tree.leaves(params)),
        "param_abs_sum": sum(float(np.abs(np.asarray(l, np.float64)).sum())
                             for l in jax.tree.leaves(params)),
        "final_key": np.asarray(state.key).tolist(),
        "last_return_mean": f64sum(state.last_return) / state.last_return.size,
        "eval_return_mean": float(np.mean(np.asarray(dqn.greedy_returns(
            env, apply_fn, params, EVAL_KEY, episodes=4, max_steps=100)))),
    }
    if hasattr(state, "replay"):
        r = state.replay
        got.update(replay_ptr=int(r.ptr), replay_size=int(r.size),
                   replay_obs_sum=f64sum(r.obs),
                   replay_reward_sum=f64sum(r.reward),
                   replay_done_sum=f64sum(r.done))
    return got


def _assert_states_equal_exactly(a, b, what: str):
    """Bit-parity: every leaf identical, floats included."""
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape, what
        np.testing.assert_array_equal(x, y, err_msg=what)


# -- golden training traces ---------------------------------------------------

@pytest.mark.parametrize("gid", F.GOLDEN_TRAIN_IDS)
def test_train_golden_trace(gid, regen_golden):
    """The host-alternating path answers to (and owns) the committed trace."""
    env, cfg, state, apply_fn = _train(gid, fused=False)
    got = _checksums(gid, env, state, apply_fn)
    path = _golden_path(gid)
    if regen_golden:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
        return
    assert path.exists(), (
        f"no committed training golden for {gid!r} — run `python -m pytest "
        "tests/test_train_fused.py --regen-golden` and review the JSON diff")
    want = json.loads(path.read_text())
    assert got["final_key"] == want["final_key"], (
        f"{gid}: the threefry key chain drifted — some RNG consumer moved")
    for k in ("replay_ptr", "replay_size"):
        if k in want:
            assert got[k] == want[k], f"{gid}: replay cursor drifted ({k})"
    for k, v in want.items():
        if isinstance(v, float):
            np.testing.assert_allclose(
                got[k], v, rtol=1e-4, atol=1e-4,
                err_msg=f"{gid}.{k}: training dynamics drifted from the "
                        "committed golden (tests/golden/) — if intentional, "
                        "rerun with --regen-golden and review the diff")


@pytest.mark.parametrize("gid", F.GOLDEN_TRAIN_IDS)
def test_fused_answers_to_the_same_golden(gid, regen_golden):
    """The fused trainer is judged against the SAME committed file (it never
    regenerates — the host-alternating path owns the goldens)."""
    if regen_golden:
        pytest.skip("goldens are regenerated by the host-alternating path only")
    env, cfg, state, apply_fn = _train(gid, fused=True, chunk=13)
    got = _checksums(gid, env, state, apply_fn)
    want = json.loads(_golden_path(gid).read_text())
    assert got["final_key"] == want["final_key"], gid
    for k, v in want.items():
        if isinstance(v, float):
            np.testing.assert_allclose(got[k], v, rtol=1e-4, atol=1e-4,
                                       err_msg=f"{gid}.{k} (fused)")


# -- fused ≡ host-alternating bit-parity --------------------------------------

@pytest.mark.parametrize("gid", [g for g in F.GOLDEN_TRAIN_IDS
                                 if g.startswith("dqn/")])
def test_fused_matches_host_alternating_bitwise_dqn(gid):
    _, _, host, _ = _train(gid, fused=False, chunk=16)
    _, _, fused, _ = _train(gid, fused=True)
    _assert_states_equal_exactly(host._asdict(), fused._asdict(),
                                 f"{gid}: fused vs host-alternating")


def test_fused_matches_host_alternating_ppo():
    """PPO: one scanned program vs U jitted dispatches gives XLA different
    fusion freedom, so parity is the standard contract (ints/keys exact,
    floats 1e-5) rather than a bit-equality claim."""
    _, _, host, _ = _train("ppo/CartPole-v1", fused=False)
    _, _, fused, _ = _train("ppo/CartPole-v1", fused=True)
    assert_leaves_match(host._asdict(), fused._asdict(),
                        "ppo fused vs host-alternating")


# -- the chunk seam: chunk size must not change the trajectory ----------------

def test_chunk_size_does_not_change_trajectory():
    """Regression for the fused path's key-chain pinning: the RNG chain
    rides the donated carry, so any (fused, chunk) combination replays the
    identical threefry chain — a per-chunk host-side fold_in would fail
    this bitwise."""
    gid = "dqn/CartPole-v1"
    _, _, ref, _ = _train(gid, fused=False, chunk=0)       # one program
    for fused, chunk in ((False, 9), (True, 64), (True, 7), (True, 1)):
        _, _, got, _ = _train(gid, fused=fused, chunk=chunk)
        _assert_states_equal_exactly(
            ref._asdict(), got._asdict(),
            f"{gid}: fused={fused} chunk={chunk} shifted the trajectory")


def test_ppo_chunk_size_does_not_change_trajectory():
    _, _, a, _ = _train("ppo/CartPole-v1", fused=True, chunk=0)
    _, _, b, _ = _train("ppo/CartPole-v1", fused=True, chunk=3)
    _assert_states_equal_exactly(a._asdict(), b._asdict(),
                                 "ppo fused chunk=0 vs chunk=3")


# -- megastep rollout inside the fused train program --------------------------

@pytest.mark.slow
def test_fused_trainer_through_megastep_backend():
    """env_backend='jnp' routes every env transition inside the fused train
    scan through the megastep kernel path (kernels/envstep row dynamics) —
    the learner and the fused rollout share one compiled program, and the
    trajectory still matches the vmap backend."""
    algo, env_id, cfg, steps = F.golden_train_setup("dqn/CartPole-v1")
    env = make(env_id)
    key = jax.random.PRNGKey(3)
    sv, _, _ = dqn.train_compiled(env, cfg, steps, key, fused=True)
    cfg_j = dataclasses.replace(cfg, env_backend="jnp")
    sj, _, _ = dqn.train_compiled(env, cfg_j, steps, key, fused=True)
    assert_leaves_match(sv._asdict(), sj._asdict(),
                        "fused trainer: megastep(jnp) vs vmap env backend")


# -- fleets -------------------------------------------------------------------

def test_fleet_rows_match_solo():
    """Fleet determinism (DQN): each vmapped row is bit-identical to the
    solo run with that row's (seed, lr)."""
    algo, env_id, cfg, _ = F.golden_train_setup("dqn/CartPole-v1")
    env = make(env_id)
    grid = F.Fleet(jnp.asarray([5, 9], jnp.int32),
                   jnp.asarray([3e-4, 1e-3], jnp.float32))
    states, metrics = F.fleet(env, grid, 32, algo="dqn", cfg=cfg)
    assert jax.tree.leaves(metrics)[0].shape[:2] == (2, 32)
    for f in range(grid.width):
        solo_cfg = dataclasses.replace(cfg, lr=float(grid.lr[f]))
        solo, _, _ = dqn.train_compiled(env, solo_cfg, 32,
                                        jax.random.PRNGKey(int(grid.seed[f])))
        row = jax.tree.map(lambda x: x[f], states)
        _assert_states_equal_exactly(solo._asdict(), row._asdict(),
                                     f"fleet row {f} vs solo")


@pytest.mark.slow
def test_fleet_ppo_row_matches_solo():
    """PPO fleet rows: parity contract (vmap batching reassociates floats;
    ints and the key chain stay exact)."""
    algo, env_id, cfg, _ = F.golden_train_setup("ppo/CartPole-v1")
    env = make(env_id)
    states, _ = F.fleet(env, {"seeds": [7]}, 2, algo="ppo", cfg=cfg)
    solo, _ = ppo.train(env, cfg, 2, jax.random.PRNGKey(7))
    assert_leaves_match(solo._asdict(),
                        jax.tree.map(lambda x: x[0], states)._asdict(),
                        "ppo fleet row vs solo")


def test_fleet_grid_and_specs():
    g = F.fleet_grid([0, 1], [1e-3, 3e-4])
    assert g.width == 4
    assert np.asarray(g.seed).tolist() == [0, 0, 1, 1]
    np.testing.assert_allclose(np.asarray(g.lr), [1e-3, 3e-4, 1e-3, 3e-4])
    with pytest.raises(TypeError, match="unknown fleet grid"):
        F._as_fleet({"seeds": [0], "learning_rates": [1e-3]}, 3e-4)
    fl = F._as_fleet([3, 4, 5], 2e-4)
    assert fl.width == 3
    np.testing.assert_allclose(np.asarray(fl.lr), [2e-4] * 3)
    with pytest.raises(ValueError, match="unknown fleet algo"):
        F.fleet("CartPole-v1", [0], 1, algo="a2c")


# -- property checks ----------------------------------------------------------
# Core checkers shared by two drivers: the seeded-fuzz tests below (always
# run) and the hypothesis `@given` drivers in tests/test_train_property.py
# (skipped when hypothesis is absent — it is an optional dep).

def check_replay_chunking(cap, batches, regroup):
    """The ring is a pure function of the transition STREAM, not of how the
    stream is chunked into add calls: any regrouping of the same
    transitions produces an identical ReplayState (no transition lost or
    duplicated at a chunk boundary), and the final ring equals the
    per-transition oracle (later writes win, ptr advances by the full
    stream length)."""
    from repro.rl.replay import replay_add_batch, replay_init

    tags = np.arange(sum(batches), dtype=np.float32)
    assert sum(regroup) == len(tags)

    def add_stream(groups):
        state, i = replay_init(cap, (1,)), 0
        for g in groups:
            chunk = tags[i:i + g]
            state = replay_add_batch(
                state, jnp.asarray(chunk)[:, None],
                jnp.asarray(chunk, jnp.int32), jnp.asarray(chunk),
                jnp.asarray(chunk)[:, None], jnp.zeros_like(chunk))
            i += g
        return state

    a = add_stream(batches)
    # regroup the same stream: fully flat (one transition per call) and the
    # caller's alternative grouping
    for groups, what in (([1] * len(tags), "flat"), (regroup, "regroup")):
        other = add_stream(groups)
        for x, y in zip(jax.tree.leaves(a._asdict()),
                        jax.tree.leaves(other._asdict())):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"chunking changed the ring ({what}={groups})")
    # per-transition oracle: slot j holds the LAST tag t with write index
    # ≡ j (mod cap); ptr advanced by the full stream length
    T = len(tags)
    assert int(a.ptr) == T % cap
    assert int(a.size) == min(T, cap)
    slots = np.full((cap,), np.nan)
    for t in range(T):
        slots[t % cap] = tags[t]
    written = ~np.isnan(slots)
    np.testing.assert_array_equal(np.asarray(a.obs)[written, 0],
                                  slots[written], err_msg="oracle ring")


def check_fused_interleaving(chunk, cap, batch, width, seed, steps=12):
    """Random (chunk, replay capacity, learn batch, fleet width)
    interleavings through the REAL fused trainer: the donated chunked run
    is bit-identical to the monolithic host-alternating program (replay
    ring included — nothing lost or duplicated at chunk boundaries), the
    cursor lands where the stream length says it must, and every fleet row
    reproduces its solo run."""
    env = make("CartPole-v1")
    cfg = dqn.DQNConfig(num_envs=2, memory_size=cap, learn_start=8,
                        batch_size=batch, exploration_steps=10,
                        target_update_freq=5)
    key = jax.random.PRNGKey(seed)
    ref, _, _ = dqn.train_compiled(env, cfg, steps, key)
    got, _, _ = dqn.train_compiled(env, cfg, steps, key, fused=True,
                                   chunk=chunk)
    _assert_states_equal_exactly(ref._asdict(), got._asdict(),
                                 f"fused chunk={chunk} cap={cap}")
    written = steps * cfg.num_envs
    assert int(got.replay.ptr) == written % cap
    assert int(got.replay.size) == min(written, cap)
    seeds = jnp.arange(seed, seed + width, dtype=jnp.int32)
    states, _ = F.fleet(env, F.Fleet(seeds, jnp.full((width,), cfg.lr,
                                                     jnp.float32)),
                        steps, algo="dqn", cfg=cfg, chunk=chunk)
    for f in range(width):
        solo, _, _ = dqn.train_compiled(env, cfg, steps,
                                        jax.random.PRNGKey(int(seeds[f])))
        _assert_states_equal_exactly(
            solo._asdict(), jax.tree.map(lambda x: x[f], states)._asdict(),
            f"fleet row {f} (width={width}, chunk={chunk})")


def _random_regroup(rng, total):
    """A random partition of `total` stream positions into contiguous groups."""
    if total <= 1:
        return [total] if total else []
    n_cuts = int(rng.integers(0, total))
    cuts = sorted(rng.choice(np.arange(1, total),
                             size=min(n_cuts, total - 1),
                             replace=False).tolist())
    return [b - a for a, b in zip([0] + cuts, cuts + [total])]


@pytest.mark.parametrize("seed", range(6))
def test_replay_ring_chunking_fuzz(seed):
    rng = np.random.default_rng(seed)
    batches = rng.integers(1, 16, size=int(rng.integers(1, 7))).tolist()
    check_replay_chunking(int(rng.integers(1, 13)), batches,
                          _random_regroup(rng, sum(batches)))


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(3))
def test_fused_interleaving_fuzz(seed):
    rng = np.random.default_rng(100 + seed)
    check_fused_interleaving(chunk=int(rng.integers(1, 17)),
                             cap=int(rng.choice([24, 48, 96])),
                             batch=int(rng.choice([4, 8])),
                             width=int(rng.integers(1, 3)),
                             seed=int(rng.integers(0, 2**16)))
