"""Per-arch smoke tests (deliverable f): reduced config, one forward/train
step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_supported, get_config, input_specs
from repro.models import lm


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    b, l = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (b, l), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, l), 0, cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_len, cfg.d_model))

    hidden, aux = lm.forward(cfg, params, batch)
    assert hidden.shape == (b, l, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(hidden)))
    logits = lm.logits_for(cfg, params, hidden[:, -1:])
    assert logits.shape == (b, 1, cfg.vocab_size)

    # one gradient step
    loss, grads = jax.value_and_grad(lambda p: lm.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (arch, got, expect)
    if arch == "olmoe-1b-7b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (64, 8)
    if arch == "granite-moe-1b-a400m":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (32, 8)
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if cell_supported(arch, shape.name):
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            tok = specs["tokens"]
            if shape.kind == "decode":
                assert tok.shape == (shape.global_batch, 1)
            else:
                assert tok.shape == (shape.global_batch, shape.seq_len)
            if cfg.is_encoder_decoder and shape.kind != "decode":
                assert specs["frames"].shape[0] == shape.global_batch


def test_long_context_skips_documented():
    skips = [a for a in ARCH_IDS if cell_supported(a, "long_500k")]
    assert sorted(skips) == sorted(
        ["yi-6b", "minicpm3-4b", "chameleon-34b", "whisper-base",
         "olmoe-1b-7b", "granite-moe-1b-a400m"])
