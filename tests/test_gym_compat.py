"""GymCompat shim semantics: reseeding, the 5-tuple API, shim copyability,
and modern-Gym drop-in parity (`.spec`, `render_mode=`)."""
import copy
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_compat, spec
from repro.core.gym_compat import GymCompat, _SpaceShim
from repro.core.wrappers import TimeLimit
from repro.envs.classic import CartPole, Pendulum


def test_seed_mid_episode_forces_reset():
    """Regression: reseeding used to keep the old `_state`, so the next
    step() silently continued an episode begun under the previous seed."""
    e = make_compat("CartPole-v1", seed=3)
    e.reset()
    e.step(1)
    e.seed(7)
    with pytest.raises(RuntimeError, match="reset"):
        e.step(1)
    obs = e.reset()  # fresh episode from the new seed works
    assert np.isfinite(obs).all()


def test_seed_makes_episodes_reproducible():
    e = make_compat("CartPole-v1", seed=0)
    e.seed(42)
    traj1 = [e.reset()] + [e.step(i % 2)[0] for i in range(5)]
    e.seed(42)
    traj2 = [e.reset()] + [e.step(i % 2)[0] for i in range(5)]
    np.testing.assert_array_equal(np.stack(traj1), np.stack(traj2))


def test_new_step_api_truncation_five_tuple():
    e = GymCompat(TimeLimit(Pendulum(), 3), seed=0, new_step_api=True)
    e.reset()
    for _ in range(2):
        obs, rew, terminated, truncated, info = e.step([0.0])
        assert not terminated and not truncated
    obs, rew, terminated, truncated, info = e.step([0.0])
    assert truncated and not terminated  # time-limit cut, not env-terminal
    assert "truncated" not in info       # mapped into the tuple, not the dict


def test_new_step_api_terminal_five_tuple():
    e = GymCompat(TimeLimit(CartPole(), 500), seed=0, new_step_api=True)
    e.reset()
    for _ in range(60):  # constant push falls over well inside the limit
        obs, rew, terminated, truncated, info = e.step(1)
        if terminated:
            break
    assert terminated and not truncated


def test_old_step_api_unchanged():
    e = make_compat("Pendulum-v1", seed=0)
    e.reset()
    out = e.step([0.0])
    assert len(out) == 4
    obs, rew, done, info = out
    assert isinstance(done, bool) and "truncated" not in info


def test_space_shim_copy_deepcopy_pickle():
    """Regression: copy/pickle used to recurse forever — __getattr__
    dereferenced self._space before __init__ populated it."""
    e = make_compat("CartPole-v1")
    for shim in (e.action_space, e.observation_space):
        for clone in (copy.copy(shim), copy.deepcopy(shim),
                      pickle.loads(pickle.dumps(shim))):
            assert isinstance(clone, _SpaceShim)
            s = clone.sample()
            assert np.asarray(s).shape == np.asarray(shim.sample()).shape
    assert e.action_space.n == 2  # attribute passthrough still works


def test_spec_exposed_like_modern_gym():
    """`e.spec` is the declarative EnvSpec of the registered id (modern
    `gym.Env.spec` parity); hand-composed stacks report None."""
    e = make_compat("CartPole-v1")
    assert e.spec is spec("CartPole-v1")
    assert e.spec.id == "CartPole-v1" and e.spec.max_steps == 500
    hand = GymCompat(TimeLimit(CartPole(), 10))
    assert hand.spec is None


def test_render_mode_accepted_and_ignored():
    """Modern Gym call-sites pass render_mode=; the shim accepts it, stores
    it, and renders on device regardless."""
    e = make_compat("CartPole-v1", render_mode="rgb_array")
    assert e.render_mode == "rgb_array"
    e.reset()
    frame = e.render()
    assert frame.shape == (84, 84)
    assert make_compat("CartPole-v1").render_mode is None


def test_render_mode_and_env_kwargs_coexist():
    e = make_compat("LightsOut-v0", render_mode="human", n=4)
    assert e.observation_space.shape == (16,)
    with pytest.raises(TypeError, match="bogus"):
        make_compat("CartPole-v1", render_mode="human", bogus=1)


def test_space_shim_raises_attribute_error_for_missing():
    e = make_compat("CartPole-v1")
    with pytest.raises(AttributeError):
        e.action_space.definitely_not_an_attribute
    with pytest.raises(AttributeError):
        e.action_space.__wrapped__  # dunder probes must not recurse
