"""Async pool + env service: the traffic-replay determinism harness.

The load-bearing claim of `repro.pool.AsyncEnvPool` is that slot recycling
is *invisible* to every other session: admitting, stepping and retiring
sessions in any interleaving must leave each session's trajectory
bit-identical to the same seed run ALONE through a 1-env lock-step
EnvPool. The tests here prove it by replaying scripted traffic — a
deterministic clock plus a scripted session arrival/departure schedule —
against that solo oracle, for one env family per suite tier (classic
control, procedural grid, arcade).

Also here: the lock-step facade's bit-equivalence to
`EnvPool(backend="vmap")` (including the key-dependent Multitask env —
the strongest RNG-plumbing check we have), masked-step lane invariance,
send/recv protocol errors, the EnvService scheduler end-to-end (budgets,
drain, straggler wiring) and device residency of the compiled masked step.
"""
import threading

import jax
import numpy as np
import pytest
from conftest import assert_leaves_match

from repro.core import make
from repro.core.spaces import sample_batch
from repro.core.wrappers import TimeLimit
from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import AsyncEnvPool, AsyncUnsupportedError, EnvPool, make_vec
from repro.serving.env_service import EnvService, Session

#: one id per suite tier — classic control, procedural grid, arcade; the
#: grid/arcade replays ride in the `slow` sweep (9 solo-oracle compiles each)
REPLAY_IDS = [
    pytest.param("CartPole-v1"),
    pytest.param("FrozenLake-v0", marks=pytest.mark.slow),
    pytest.param("Pong-raw", marks=pytest.mark.slow),
]


def _solo_oracle(name: str, seed: int, actions):
    """The session's ground truth: same seed, alone, lock-step EnvPool."""
    pool = EnvPool(make(name), 1, backend="vmap")
    first_obs = np.asarray(pool.reset(seed=seed))[0]
    rows = []
    for a in actions:
        obs, rew, done, _ = pool.step(np.asarray(a)[None])
        rows.append((np.asarray(obs)[0], np.asarray(rew)[0],
                     np.asarray(done)[0]))
    return first_obs, rows


def _session_actions(name: str, sid: int, budget: int):
    env = make(name)
    key = jax.random.PRNGKey(9000 + sid)
    return [np.asarray(sample_batch(env.action_space,
                                    jax.random.fold_in(key, t), 1))[0]
            for t in range(budget)]


# -- tentpole: traffic replay vs the solo oracle ------------------------------

@pytest.mark.parametrize("name", REPLAY_IDS)
def test_traffic_replay_bit_parity_vs_solo(name):
    """Scripted arrival/departure traffic: 9 sessions through 3 slots, with
    staggered arrivals, early departures and slot reuse. Every session's
    (first_obs, obs, reward, done) stream must be bit-identical to its solo
    lock-step run — slot recycling must not perturb anyone's key chain."""
    num_slots = 3
    budgets = [4, 2, 6, 3, 5, 1, 4, 2, 3]
    sessions = {sid: {"seed": 50 + sid,
                      "acts": _session_actions(name, sid, b),
                      "rows": [], "first_obs": None, "t": 0}
                for sid, b in enumerate(budgets)}

    pool = AsyncEnvPool(name, num_slots, backend="auto")
    queue = list(sessions)         # arrival order = sid order
    slot_sid = {}                  # slot -> sid currently hosted
    rng = np.random.default_rng(0)  # scheduling noise ONLY (which lanes send)

    while queue or slot_sid:
        # arrivals: fill free slots from the queue (scripted FIFO)
        while queue and len(slot_sid) < num_slots:
            sid = queue.pop(0)
            slot, obs = pool.admit(seed=sessions[sid]["seed"])
            slot_sid[slot] = sid
            sessions[sid]["first_obs"] = np.asarray(obs)
        # a deterministic-but-adversarial subset of lanes sends this tick
        ready = sorted(slot_sid)
        if len(ready) > 1 and rng.random() < 0.5:
            ready = sorted(rng.choice(ready, size=len(ready) - 1,
                                      replace=False).tolist())
        acts = np.stack([sessions[slot_sid[s]]["acts"]
                         [sessions[slot_sid[s]]["t"]] for s in ready])
        pool.send(acts, np.asarray(ready))
        obs, rew, done, _, ids = pool.recv()
        for i, slot in enumerate(ids):
            sess = sessions[slot_sid[int(slot)]]
            sess["rows"].append((obs[i], rew[i], done[i]))
            sess["t"] += 1
        # departures: budget spent -> release the slot for refill
        for slot in [s for s, sid in slot_sid.items()
                     if sessions[sid]["t"] >= len(sessions[sid]["acts"])]:
            pool.release(slot)
            del slot_sid[slot]

    for sid, sess in sessions.items():
        ref_first, ref_rows = _solo_oracle(name, sess["seed"], sess["acts"])
        assert_leaves_match(ref_first, sess["first_obs"],
                            f"{name} sid{sid} first_obs")
        assert len(sess["rows"]) == len(ref_rows)
        for t, (got, ref) in enumerate(zip(sess["rows"], ref_rows)):
            assert_leaves_match(ref, got, f"{name} sid{sid} step{t}")


# -- lock-step facade == EnvPool(backend="vmap"), bit for bit -----------------

@pytest.mark.parametrize("name", ["CartPole-v1", "Multitask-v0"])
def test_facade_bit_equivalent_to_vmap_envpool(name):
    """With every slot active the async pool IS the lock-step pool: same
    reset split, same carry-key chain, same per-step splits. Multitask's
    dynamics consume the per-step keys, so this would fail on any RNG
    plumbing difference — not just on state divergence."""
    n, steps = 4, 8
    apool = make_vec(name, n, backend="async")
    vpool = make_vec(name, n, backend="vmap")
    assert_leaves_match(vpool.reset(seed=123), apool.reset(seed=123),
                        f"{name} reset")
    for t in range(steps):
        a = np.asarray(vpool.sample_actions(seed=t))
        ref = vpool.step(a)
        got = apool.step(a)
        assert_leaves_match(ref[:3], got[:3], f"{name} step{t}")
        assert_leaves_match(dict(ref[3]), dict(got[3]), f"{name} info{t}")


def test_fused_backend_matches_vmap_backend():
    """The masked fused step (kernels/envstep active=) and the masked vmap
    step agree lane for lane under partial activity."""
    n = 4
    fused = AsyncEnvPool("CartPole-v1", n, backend="jnp")
    ref = AsyncEnvPool("CartPole-v1", n, backend="vmap")
    for pool in (fused, ref):
        for sid in range(3):          # slot 3 stays empty
            pool.admit(seed=sid)
    for t in range(6):
        ready = [0, 2] if t % 2 else [0, 1, 2]
        acts = np.zeros(len(ready), np.int32)
        for pool in (fused, ref):
            pool.send(acts, np.asarray(ready))
        out_f, out_r = fused.recv(), ref.recv()
        assert list(out_f[4]) == list(out_r[4]) == ready
        assert_leaves_match(out_r[:3], out_f[:3], f"tick{t}")


def test_inactive_lanes_keep_state_and_report_zero():
    pool = AsyncEnvPool("CartPole-v1", 4, backend="auto")
    for sid in range(4):
        pool.admit(seed=sid)
    before = jax.tree.map(np.asarray, pool._carry[0])
    pool.send(np.ones(2, np.int32), np.asarray([1, 3]))
    obs, rew, done, _, ids = pool.recv()
    assert list(ids) == [1, 3]
    after = jax.tree.map(np.asarray, pool._carry[0])
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a[0], b[0])  # lane 0 untouched, bit-for-bit
        np.testing.assert_array_equal(a[2], b[2])  # lane 2 untouched


def test_slot_recycle_does_not_perturb_neighbours():
    """Run lane 0 with and without a churning neighbour in lane 1; lane 0's
    trajectory must be identical."""
    acts = _session_actions("CartPole-v1", 0, 6)

    def lane0_rows(churn: bool):
        pool = AsyncEnvPool("CartPole-v1", 2, backend="auto")
        pool.admit(seed=7, slot=0)
        if churn:
            pool.admit(seed=1, slot=1)
        rows = []
        for t, a in enumerate(acts):
            if churn and t in (2, 4):   # retire + replace the neighbour
                pool.release(1)
                pool.admit(seed=100 + t, slot=1)
            ids = [0, 1] if churn else [0]
            batch = np.stack([a] * len(ids))
            pool.send(batch, np.asarray(ids))
            obs, rew, done, _, out = pool.recv()
            rows.append((obs[0], rew[0], done[0]))
        return rows

    for quiet, churned in zip(lane0_rows(False), lane0_rows(True)):
        assert_leaves_match(quiet, churned, "lane0")


# -- protocol errors ----------------------------------------------------------

def test_send_recv_protocol_errors():
    pool = AsyncEnvPool("CartPole-v1", 2, backend="auto")
    with pytest.raises(RuntimeError, match="no actions in flight"):
        pool.recv()
    sid, _ = pool.admit(seed=0)
    with pytest.raises(ValueError, match="no running session"):
        pool.send(np.zeros(1, np.int32), [1 - sid])
    pool.send(np.zeros(1, np.int32), [sid])
    with pytest.raises(ValueError, match="already in flight"):
        pool.send(np.zeros(1, np.int32), [sid])
    pool.recv()
    with pytest.raises(ValueError, match="exactly one of"):
        pool.admit(seed=1, key=jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="already hosts"):
        pool.admit(seed=1, slot=sid)
    pool.admit(seed=1)
    with pytest.raises(RuntimeError, match="no free slot"):
        pool.admit(seed=2)
    pool.release(sid)
    with pytest.raises(ValueError, match="no running session"):
        pool.release(sid)
    with pytest.raises(ValueError, match="batch"):
        pool.send(np.zeros(2, np.int32), [1 - sid])


def test_unsupported_backend_raises_named_error():
    with pytest.raises(AsyncUnsupportedError, match="fused megastep"):
        AsyncEnvPool("Multitask-v0", 2, backend="jnp")
    # "auto" degrades to the masked vmap step instead
    assert AsyncEnvPool("Multitask-v0", 2).backend == "vmap"


def test_recv_blocks_for_min_ready_across_threads():
    pool = AsyncEnvPool("CartPole-v1", 2, backend="auto")
    for sid in range(2):
        pool.admit(seed=sid)
    pool.send(np.zeros(1, np.int32), [0])

    def late_client():
        pool.send(np.ones(1, np.int32), [1])

    t = threading.Timer(0.05, late_client)
    t.start()
    try:
        obs, rew, done, _, ids = pool.recv(max_wait=5.0, min_ready=2)
    finally:
        t.join()
    assert list(ids) == [0, 1]


# -- EnvService scheduler end-to-end ------------------------------------------

def test_env_service_serves_all_budgets():
    svc = EnvService("CartPole-v1", num_slots=4, backend="auto")
    budgets = [8 + (i % 5) for i in range(11)]
    for i, b in enumerate(budgets):
        svc.submit(Session(sid=i, seed=100 + i, num_steps=b))
    svc.run()
    st = svc.stats()
    assert st["released"] == 11 and st["running"] == 0 and st["queued"] == 0
    assert svc.steps_served == sum(budgets)
    for i, b in enumerate(budgets):
        sess = svc._sessions[i]
        assert sess.steps == b
        assert sess.first_obs is not None and sess.first_obs.shape == (4,)
    assert st["recv_p99_s"] >= st["recv_p50_s"] > 0


def test_env_service_drain_finishes_running_only():
    svc = EnvService("CartPole-v1", num_slots=4, backend="auto")
    for i in range(8):
        svc.submit(Session(sid=i, seed=i, num_steps=5))
    svc.tick()                      # admits 4, steps once
    svc.drain()
    st = svc.stats()
    assert st["running"] == 0 and st["queued"] == 4 and st["released"] == 4
    with pytest.raises(RuntimeError, match="draining"):
        svc.submit(Session(sid=99, seed=0, num_steps=3))


def test_env_service_flags_slow_consumer():
    """Straggler wiring: a client whose action round-trip dominates the
    fleet median gets profile→demote advice, on the scripted clock."""
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    def slow_policy(obs, step):
        t[0] += 0.5
        return np.int32(0)

    svc = EnvService("CartPole-v1", num_slots=4, backend="auto", clock=clock)
    for i in range(4):
        pol = slow_policy if i == 3 else (lambda obs, step: np.int32(0))
        svc.submit(Session(sid=i, seed=i, num_steps=6, policy=pol))
    svc.run()
    flagged = svc.stats()["stragglers"]
    assert [r["host_id"] for r in flagged] == [3]
    assert flagged[0]["advice"] in ("profile", "demote")


def test_env_service_session_equals_solo_run():
    """End to end through the scheduler: a scripted-policy session's reward
    stream equals its solo lock-step run (the service-level replay claim)."""
    acts = _session_actions("CartPole-v1", 3, 7)
    _, ref_rows = _solo_oracle("CartPole-v1", 42, acts)

    svc = EnvService("CartPole-v1", num_slots=2, backend="auto")
    svc.submit(Session(sid=0, seed=42, num_steps=7,
                       policy=lambda obs, step: acts[step]))
    svc.submit(Session(sid=1, seed=5, num_steps=11))
    svc.submit(Session(sid=2, seed=6, num_steps=3))
    svc.run()
    total_ref = float(np.sum([r[1] for r in ref_rows], dtype=np.float64))
    assert svc._sessions[0].total_reward == pytest.approx(total_ref)
    assert svc._sessions[0].steps == 7


# -- device residency ---------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "vmap"])
def test_masked_step_core_is_device_resident(backend):
    pool = AsyncEnvPool("CartPole-v1", 8, backend=backend)
    ops = host_transfer_ops(pool.step_lowered().compile().as_text())
    assert ops == [], f"host transfers in async {backend} core: {ops}"
