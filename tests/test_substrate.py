"""Substrate tests: checkpoint, data pipeline, runtime FT, impact tracker."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, batch_at_step
from repro.runtime.elastic import propose_mesh
from repro.runtime.failures import HeartbeatMonitor, plan_recovery
from repro.runtime.straggler import StragglerTracker
from repro.sustainability.impact import Impact, ImpactTracker


# -- checkpoint ----------------------------------------------------------------
def _tree(key):
    return {
        "a": jax.random.normal(key, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.asarray(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(7, tree)
    template = jax.tree.map(jnp.zeros_like, tree)
    restored = mgr.restore(template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(0))
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(jax.random.PRNGKey(1))
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((3, 3))})


def test_checkpoint_restart_training_is_exact(tmp_path):
    """FT contract: save at step k, restart, continue == uninterrupted run."""
    from repro.configs.registry import get_config
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("yi-6b", reduced=True)
    tc = TrainConfig(lr=1e-3, warmup=1, total_steps=20, remat="none")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=4)
    step_fn = jax.jit(make_train_step(cfg, tc))

    def data(step):
        b = batch_at_step(dc, step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    # uninterrupted: 4 steps
    p, o = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    for s in range(4):
        p, o, m = step_fn(p, o, data(s))
    ref_loss = float(m["loss"])

    # interrupted at step 2 + restore + resume from the same data step
    p2, o2 = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    for s in range(2):
        p2, o2, _ = step_fn(p2, o2, data(s))
    mgr.save(2, {"params": p2, "opt": o2})
    restored = mgr.restore({"params": p2, "opt": o2})
    p3, o3 = restored["params"], restored["opt"]
    for s in range(2, 4):
        p3, o3, m3 = step_fn(p3, o3, data(s))
    np.testing.assert_allclose(float(m3["loss"]), ref_loss, rtol=1e-6)


# -- data pipeline --------------------------------------------------------------
def test_data_deterministic():
    dc = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    a = batch_at_step(dc, 5)
    b = batch_at_step(dc, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at_step(dc, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_partitions_batch():
    full = DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1, num_hosts=1, host_id=0)
    h0 = DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1, num_hosts=2, host_id=0)
    h1 = DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1, num_hosts=2, host_id=1)
    b0, b1 = batch_at_step(h0, 0), batch_at_step(h1, 0)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # distinct slices


def test_markov_data_is_learnable_structure():
    dc = DataConfig(vocab_size=64, seq_len=128, global_batch=8, kind="markov")
    b = batch_at_step(dc, 0)
    # each token's successor comes from an 8-way table => strictly less than
    # uniform entropy; verify successors concentrate
    toks = b["tokens"]
    pairs = set(zip(toks[:, :-1].reshape(-1).tolist(), toks[:, 1:].reshape(-1).tolist()))
    per_tok = {}
    for a, s in pairs:
        per_tok.setdefault(a, set()).add(s)
    assert max(len(v) for v in per_tok.values()) <= 8


# -- runtime fault tolerance -----------------------------------------------------
def test_heartbeat_detects_dead_host():
    clock = [0.0]
    mon = HeartbeatMonitor(num_hosts=4, timeout_s=10.0, clock=lambda: clock[0])
    for h in range(4):
        mon.beat(h, step=5)
    clock[0] = 8.0
    for h in (0, 1, 2):
        mon.beat(h, step=6)
    clock[0] = 15.0
    assert mon.dead_hosts() == [3]
    assert mon.quorum_step() == 6


def test_recovery_plan_remeshes():
    clock = [0.0]
    mon = HeartbeatMonitor(num_hosts=8, timeout_s=5.0, clock=lambda: clock[0])
    for h in range(8):
        mon.beat(h, 100)
    clock[0] = 10.0
    for h in range(6):  # hosts 6,7 die
        mon.beat(h, 120)
    plan = plan_recovery(mon, devices_per_host=4, checkpoint_step=110)
    assert plan.surviving_hosts == list(range(6))
    assert plan.new_device_count == 24
    assert np.prod(plan.mesh_shape) == 24
    assert plan.restart_step == 110


def test_propose_mesh_prefers_model_axis():
    assert propose_mesh(512) == ((32, 16), ("data", "model"))
    assert propose_mesh(384) == ((24, 16), ("data", "model"))
    assert propose_mesh(24) == ((3, 8), ("data", "model"))
    assert propose_mesh(7) == ((7, 1), ("data", "model"))


def test_straggler_detection_and_demotion():
    tr = StragglerTracker(num_hosts=4, threshold=1.5, patience=2)
    for step in range(4):
        for h in range(4):
            tr.record(h, 1.0 if h != 2 else 3.0)
        tr.reports()
    assert 2 in tr.hosts_to_demote()


# -- impact tracker ---------------------------------------------------------------
def test_impact_tracker_measures_and_subtracts():
    with ImpactTracker() as t:
        x = 0
        for _ in range(30):
            x += sum(i * i for i in range(100000))
    imp = t.impact
    assert imp.wall_s > 0 and imp.energy_mwh > 0 and imp.co2_kg > 0
    half = Impact(wall_s=imp.wall_s / 2, cpu_s=imp.cpu_s / 2)
    diff = imp.minus(half)
    assert abs(diff.wall_s - imp.wall_s / 2) < 1e-9
