"""Golden-trace regression tests: silent dynamics drift fails loudly.

For every registered id a small seeded 32-step batched rollout is reduced
to per-step (obs, reward, done) checksums and committed under
tests/golden/<id>.json. Any change to dynamics, reset distributions,
procedural level generation, wrapper semantics or the RNG plumbing shifts
the checksums and fails here — the failure is the *intended* signal; after
an intentional change, regenerate with

    python -m pytest tests/test_golden.py --regen-golden

and review the JSON diff. Checksums are float64 sums computed on the host
from the f32 trajectories, so they are deterministic for a given backend.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make, registered
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset, Vec

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
STEPS = 32
BATCH = 2


def _params():
    # Pixel ids render 84×84 frames every step (stepped + autoreset-fresh):
    # real work, so they ride in the `slow` sweep with the other heavy tests.
    out = []
    for name in registered():
        pixel = len(make(name).observation_space.shape) >= 2
        marks = [pytest.mark.slow] if pixel else []
        out.append(pytest.param(name, marks=marks))
    return out


def trace(name: str) -> dict:
    """Seeded rollout -> per-step [obs_sum, reward_sum, done_count]."""
    env = make(name)
    venv = Vec(AutoReset(env), BATCH)
    key = jax.random.PRNGKey(sum(map(ord, name)))
    state, obs = venv.reset(key)
    rows = []
    for t in range(STEPS):
        a = sample_batch(env.action_space, jax.random.fold_in(key, 1000 + t),
                         BATCH)
        ts = venv.step(state, a, jax.random.fold_in(key, t))
        state = ts.state
        rows.append([float(np.asarray(ts.obs, np.float64).sum()),
                     float(np.asarray(ts.reward, np.float64).sum()),
                     int(np.asarray(ts.done).sum())])
    space = env.observation_space
    return {
        "env": name,
        "steps": STEPS,
        "batch": BATCH,
        "obs_shape": list(space.shape),
        "obs_dtype": str(np.dtype(space.dtype)),
        "reset_obs_sum": float(np.asarray(obs, np.float64).sum()),
        "rows": rows,
    }


def _path(name: str) -> pathlib.Path:
    return GOLDEN_DIR / f"{name}.json"


@pytest.mark.parametrize("name", _params())
def test_golden_trace(name, regen_golden):
    got = trace(name)
    path = _path(name)
    if regen_golden:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1) + "\n")
        return
    assert path.exists(), (
        f"no golden trace for {name!r} — a new env id must commit one: "
        "run `python -m pytest tests/test_golden.py --regen-golden`")
    want = json.loads(path.read_text())
    assert got["obs_shape"] == want["obs_shape"], name
    assert got["obs_dtype"] == want["obs_dtype"], name
    np.testing.assert_allclose(got["reset_obs_sum"], want["reset_obs_sum"],
                               rtol=1e-4, atol=1e-4, err_msg=f"{name} reset")
    got_rows = np.asarray(got["rows"], np.float64)
    want_rows = np.asarray(want["rows"], np.float64)
    assert got_rows.shape == want_rows.shape, name
    np.testing.assert_allclose(
        got_rows, want_rows, rtol=1e-4, atol=1e-4,
        err_msg=f"{name}: dynamics drifted from the committed golden trace "
                "(tests/golden/) — if intentional, rerun with --regen-golden "
                "and review the JSON diff")


def test_every_registered_id_has_a_committed_trace():
    """New families cannot ship without goldens (registry-driven, like the
    conformance sweep)."""
    missing = [n for n in registered() if not _path(n).exists()]
    assert not missing, f"golden traces missing for {missing}"


# -- async engine vs the SAME committed traces --------------------------------

def async_trace(name: str) -> dict:
    """The `trace()` rollout, replayed through the async pool's send/recv.

    `reset(seed)` reproduces `Vec.reset(PRNGKey(seed))` and
    `recv(key=fold_in(key, t))` splits per-step keys exactly like
    `Vec.step`, so the async engine is answerable to the *same* committed
    goldens as the lock-step reference — no parallel trace set to drift.
    """
    from repro.pool import make_vec

    env = make(name)
    key = jax.random.PRNGKey(sum(map(ord, name)))
    pool = make_vec(name, BATCH, backend="async")
    obs0 = pool.reset(seed=sum(map(ord, name)))
    rows = []
    for t in range(STEPS):
        a = sample_batch(env.action_space, jax.random.fold_in(key, 1000 + t),
                         BATCH)
        pool.send(np.asarray(a), np.arange(BATCH))
        obs, rew, done, _, _ = pool.recv(key=jax.random.fold_in(key, t))
        rows.append([float(np.asarray(obs, np.float64).sum()),
                     float(np.asarray(rew, np.float64).sum()),
                     int(np.asarray(done).sum())])
    return {"reset_obs_sum": float(np.asarray(obs0, np.float64).sum()),
            "rows": rows}


@pytest.mark.slow
@pytest.mark.parametrize("name", _params())
def test_async_golden_trace(name, regen_golden):
    """The async engine answers to the committed goldens (never regenerates
    them — the lock-step `trace()` path owns the files)."""
    if regen_golden:
        pytest.skip("goldens are regenerated by the lock-step path only")
    path = _path(name)
    assert path.exists(), f"no golden trace for {name!r}"
    want = json.loads(path.read_text())
    got = async_trace(name)
    np.testing.assert_allclose(got["reset_obs_sum"], want["reset_obs_sum"],
                               rtol=1e-4, atol=1e-4,
                               err_msg=f"{name} async reset")
    np.testing.assert_allclose(
        np.asarray(got["rows"], np.float64),
        np.asarray(want["rows"], np.float64), rtol=1e-4, atol=1e-4,
        err_msg=f"{name}: async send/recv trajectory drifted from the "
                "committed golden trace (tests/golden/)")


def test_async_registry_completeness():
    """Every registered id either hosts on the async pool or refuses with
    the *named* error — no silent fallback can shrink async coverage."""
    from repro.pool import AsyncEnvPool, AsyncUnsupportedError

    hosted, refused = [], []
    for name in registered():
        try:
            AsyncEnvPool(name, 1)
            hosted.append(name)
        except AsyncUnsupportedError:
            refused.append(name)
    assert hosted, "async pool hosts nothing"
    assert not refused, (
        f"ids refusing async hosting: {refused} — every current family is "
        "a functional Env; a refusal here means a registration regressed")
