"""Registry-driven cross-backend conformance harness.

Every `EnvSpec` in the registry is swept automatically — new env families
inherit coverage instead of hand-listing it (the EnvPool lesson: the
execution engine must be validated uniformly across every env it hosts).
The matrix iterates the *declarative* specs (`registry.specs()`), so
metadata questions (is there a TimeLimit? is the obs pixels?) are answered
from the declared pipeline, not by crawling built wrapper stacks. Per spec:

  - declared pipeline integrity: the built stack walks back to exactly the
    declared transforms, and carries its `EnvSpec`;
  - space contract: obs/action shapes + dtypes, `contains`, `sample_batch`;
  - `info["truncated"]` contract: present iff a TimeLimit is declared;
  - AutoReset-after-done: episodes keep flowing across the reset boundary;
  - vmap vs fused (`jnp` reference + `pallas_interpret` kernel) bit-parity,
    including autoreset boundaries (grid ids regenerate their *level* there);
  - pool parity: `make_vec` fused rollout == vmap rollout;
  - interpreted-python parity: baselines with `set_state` must reproduce the
    compiled trajectory step for step from a shared state.

The hand-listed per-env parity cases that used to live in
tests/test_envstep_fused.py are folded into this sweep; that module keeps
only the scenario tests (truncation counters, ring semantics, RL parity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_leaves_match, vmap_reference

from repro.core import declared_pipeline, make, registered, spec, specs
from repro.core.env import supports_fused_step
from repro.core.spaces import Box, Discrete, MultiDiscrete, sample_batch
from repro.core.wrappers import AutoReset, TimeLimit
from repro.envs.baseline_python import BASELINES
from repro.kernels.envstep import fused_step
from repro.pool import make_vec

ALL_SPECS = specs()
ALL_IDS = [s.id for s in ALL_SPECS]
FUSED_IDS = [n for n in ALL_IDS if supports_fused_step(make(n))]
#: ids with an interpreted twin that supports `set_state` (trajectory parity
#: needs a shared start state) and a state-vector obs (pixel twins observe
#: the state vector, not frames).
BASELINE_IDS = [n for n in ALL_IDS
                if n in BASELINES and hasattr(BASELINES[n], "set_state")
                and not spec(n).pixels]
BACKENDS = ("jnp", "pallas_interpret")


def _has_time_limit(name) -> bool:
    return spec(name).max_steps is not None


def _action_block(env, key, k: int, num_envs: int):
    return jnp.stack([
        sample_batch(env.action_space, jax.random.fold_in(key, 100 + t),
                     num_envs) for t in range(k)])


def _assert_in_space(space, obs, what=""):
    obs = np.asarray(obs)
    assert obs.shape == tuple(space.shape), (what, obs.shape, space.shape)
    assert obs.dtype == np.dtype(space.dtype), (what, obs.dtype, space.dtype)
    assert bool(np.all(np.asarray(space.contains(obs)))), (what, obs)


# -- fast per-id contract checks ---------------------------------------------

@pytest.mark.parametrize("name", ALL_IDS)
def test_declared_pipeline_round_trips(name):
    """The built stack IS the declared pipeline: walking the wrappers back
    through `declared_pipeline` recovers the spec's transforms exactly, and
    the built env carries its `EnvSpec` (the queryable `.spec` contract)."""
    s = spec(name)
    env = make(name)
    assert env.spec is s
    core, transforms = declared_pipeline(env)
    assert transforms == s.transforms, name
    assert not hasattr(core, "env"), f"{name}: core still wrapped"
    assert isinstance(core, s.core_factory), name


@pytest.mark.parametrize("name", ALL_IDS)
def test_space_contract(name):
    """reset/step outputs live in the declared spaces, right dtypes."""
    env = make(name)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    _assert_in_space(env.observation_space, obs, f"{name} reset obs")
    action = env.action_space.sample(jax.random.fold_in(key, 1))
    assert np.asarray(action).dtype == np.dtype(env.action_space.dtype)
    ts = env.step(state, action, jax.random.fold_in(key, 2))
    _assert_in_space(env.observation_space, ts.obs, f"{name} step obs")
    assert np.asarray(ts.reward).dtype == np.float32
    assert np.asarray(ts.done).dtype == np.bool_
    batch = sample_batch(env.action_space, key, 3)
    assert batch.shape == (3,) + tuple(env.action_space.shape)
    assert batch.dtype == np.dtype(env.action_space.dtype)
    for a in np.asarray(batch):
        assert bool(np.all(np.asarray(env.action_space.contains(a))))


@pytest.mark.parametrize("name", ALL_IDS)
def test_truncated_info_contract(name):
    """`info["truncated"]` is surfaced iff the spec declares a TimeLimit."""
    env = make(name)
    key = jax.random.PRNGKey(3)
    state, _ = env.reset(key)
    ts = env.step(state, env.action_space.sample(jax.random.fold_in(key, 1)),
                  jax.random.fold_in(key, 2))
    if _has_time_limit(name):
        assert "truncated" in ts.info, name
        assert np.asarray(ts.info["truncated"]).dtype == np.bool_
    else:
        assert "truncated" not in ts.info, name


@pytest.mark.parametrize("name", ALL_IDS)
def test_autoreset_after_done(name):
    """Episodes flow across the reset boundary for every id (an outer
    TimeLimit(4) forces `done` even for ids that rarely terminate)."""
    env = AutoReset(TimeLimit(make(name), 4))
    key = jax.random.PRNGKey(4)
    state, obs = env.reset(key)
    dones = 0
    for i in range(9):
        a = env.action_space.sample(jax.random.fold_in(key, i))
        ts = env.step(state, a, jax.random.fold_in(key, 100 + i))
        state = ts.state
        dones += int(np.asarray(ts.done))
        _assert_in_space(env.observation_space, ts.obs, f"{name} step {i}")
        assert "terminal_obs" in ts.info
    assert dones >= 2, name  # at least steps 4 and 8 cut + reset


# -- cross-backend sweep (the heavy part; `make test-conformance`) -----------

@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", ALL_IDS)
def test_backend_parity(name, backend):
    """vmap vs fused megastep bit-parity for every fused-capable id.

    K=16 crosses autoreset boundaries for the fast-terminating ids — for the
    grid suite that means the *level layout* regenerates inside the fused
    chunk and must match the vmap stream bit for bit.
    """
    env = make(name)
    if not supports_fused_step(env):
        pytest.skip(f"{name}: no fused megastep spec")
    num_envs, k = 4, 16
    key = jax.random.PRNGKey(sum(map(ord, name)))
    actions = _action_block(env, key, k, num_envs)
    st0, st_ref, obs_r, rew_r, done_r, tobs_r = vmap_reference(
        env, num_envs, key, actions)
    st_f, ts = fused_step(env, st0, actions, backend=backend)
    assert ts.obs.dtype == obs_r.dtype, (name, ts.obs.dtype, obs_r.dtype)
    assert_leaves_match((obs_r, rew_r, done_r, tobs_r),
                        (ts.obs, ts.reward, ts.done,
                         ts.info["terminal_obs"]), f"{name}/{backend}")
    assert_leaves_match(st_ref, st_f, f"{name}/{backend} state")


@pytest.mark.slow
@pytest.mark.parametrize("name", ALL_IDS)
def test_pool_conformance(name):
    """`make_vec` hosts every id; fused-capable ids must match the vmap
    engine through the pool's chunked rollout (incl. a remainder chunk)."""
    key = jax.random.PRNGKey(7)
    rew_v, eps_v, _ = make_vec(name, 4, backend="vmap").rollout(14, key)
    assert np.all(np.isfinite(np.asarray(rew_v)))
    if name not in FUSED_IDS:
        return
    rew_f, eps_f, _ = make_vec(name, 4, backend="jnp", unroll=5).rollout(14, key)
    np.testing.assert_allclose(np.asarray(rew_v), np.asarray(rew_f),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_v), np.asarray(eps_f))


@pytest.mark.slow
@pytest.mark.parametrize("name", ALL_IDS)
def test_async_backend_conformance(name):
    """`make_vec(id, N, backend="async")` hosts every id behind the shared
    pool protocol. The lock-step facade must be bit-identical to the vmap
    EnvPool (same reset split, same carry-key chain), which transitively
    inherits the whole lock-step contract — spaces, `info["truncated"]`
    iff a TimeLimit is declared, autoreset-after-done — for the async
    engine; the space/info checks are still asserted directly below so a
    facade bug cannot mask a contract bug."""
    n, steps = 3, 6
    apool = make_vec(name, n, backend="async")
    vpool = make_vec(name, n, backend="vmap")
    obs = apool.reset(seed=17)
    assert_leaves_match(vpool.reset(seed=17), obs, f"{name} reset")
    for i in range(n):
        _assert_in_space(apool.observation_space, np.asarray(obs)[i],
                         f"{name} lane{i} reset obs")
    for t in range(steps):
        a = np.asarray(vpool.sample_actions(seed=t))
        ref, got = vpool.step(a), apool.step(a)
        assert_leaves_match(ref[:3], got[:3], f"{name} step{t}")
        assert ("truncated" in got[3]) == _has_time_limit(name), name
        for i in range(n):
            _assert_in_space(apool.observation_space, np.asarray(got[0])[i],
                             f"{name} lane{i} step{t}")


@pytest.mark.slow
def test_async_autoreset_after_done():
    """Async lanes keep flowing across episode boundaries: an Env instance
    under a tight outer TimeLimit(4) forces `done` inside the session and
    the AutoReset lane must restart in-place (obs back in the space, done
    pulses observed on every lane)."""
    env = TimeLimit(make("CartPole-v1"), 4)
    pool = make_vec(env, 3, backend="async")
    pool.reset(seed=5)
    dones = np.zeros(3, np.int64)
    for t in range(9):
        obs, _, done, _ = pool.step(np.zeros(3, np.int32))
        dones += np.asarray(done)
        for i in range(3):
            _assert_in_space(pool.observation_space, np.asarray(obs)[i],
                             f"lane{i} step{t}")
    assert (dones >= 2).all()  # steps 4 and 8 cut + reset on every lane


@pytest.mark.slow
@pytest.mark.parametrize("name", BASELINE_IDS)
def test_python_baseline_parity(name):
    """Interpreted twin == compiled env, step for step, from a shared state.

    `set_state` copies the compiled env's (procedurally generated) state
    into the python twin; both are then driven by the same action sequence.
    Stops at the first episode end (the twins manage their own resets).
    """
    env = make(name)
    key = jax.random.PRNGKey(sum(map(ord, name)) + 1)
    state, obs = env.reset(key)
    base_state = state
    while hasattr(base_state, "inner"):
        base_state = base_state.inner
    py = BASELINES[name]()
    py.seed(0)
    py.reset()
    py.set_state(base_state)
    discrete = isinstance(env.action_space, Discrete)
    for t in range(12):
        a = sample_batch(env.action_space, jax.random.fold_in(key, t), 1)[0]
        ts = env.step(state, a, jax.random.fold_in(key, 500 + t))
        obs_py, rew_py, done_py, info_py = py.step(
            int(a) if discrete else np.asarray(a))
        np.testing.assert_allclose(np.asarray(ts.obs, np.float64),
                                   np.asarray(obs_py, np.float64),
                                   rtol=1e-4, atol=1e-5, err_msg=f"{name}@{t}")
        np.testing.assert_allclose(float(ts.reward), float(rew_py),
                                   rtol=1e-4, atol=1e-5, err_msg=f"{name}@{t}")
        assert bool(ts.done) == bool(done_py), f"{name}@{t}"
        assert bool(ts.info["truncated"]) == bool(info_py["truncated"])
        state = ts.state
        if bool(ts.done):
            break


def test_discovery_is_complete():
    """The sweep really is registry-driven: the families this repo ships are
    all present, and the fused set is discovered, not hand-listed."""
    assert len(ALL_IDS) >= 28
    for fam in ("CartPole-v1", "Pong-v0", "LightsOut-v0", "FrozenLake-v0",
                "Snake-px", "Maze-raw"):
        assert fam in ALL_IDS
    assert "FrozenLake-v0" in FUSED_IDS and "Snake-raw" in FUSED_IDS
    assert "Multitask-v0" not in FUSED_IDS
    assert len(BASELINE_IDS) >= 9
    for sp in (Box, Discrete, MultiDiscrete):  # all space types swept
        assert any(isinstance(make(n).observation_space, sp)
                   or isinstance(make(n).action_space, sp) for n in ALL_IDS)
