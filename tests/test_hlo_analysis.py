"""The trip-count-aware HLO analyzer vs cost_analysis ground truths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matmul_flops_exact():
    m = k = n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    a = analyze_hlo(c.as_text())
    ca = c.cost_analysis()  # dict in new jax, [dict] (one per device) in older
    ref = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    np.testing.assert_allclose(a.flops, ref, rtol=0.01)
    np.testing.assert_allclose(a.flops, 2 * m * k * n, rtol=0.01)


def test_scan_flops_scale_with_trip_count():
    m = 128

    def g(x, ws):
        def body(h, w):
            return h @ w, None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    for trips in (3, 11):
        c = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                     jax.ShapeDtypeStruct((trips, m, m), jnp.float32))
        a = analyze_hlo(c.as_text())
        np.testing.assert_allclose(a.flops, trips * 2 * m**3, rtol=0.05)


def test_nested_scan():
    m = 64

    def h(x, ws):
        def outer(carry, w2):
            def inner(c2, w):
                return c2 @ w, None

            y, _ = jax.lax.scan(inner, carry, w2)
            return y, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = _compile(h, jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((2, 5, m, m), jnp.float32))
    a = analyze_hlo(c.as_text())
    np.testing.assert_allclose(a.flops, 10 * 2 * m**3, rtol=0.05)


def test_bytes_nonzero_and_scales():
    m = 128

    def g(x, ws):
        def body(h, w):
            return h @ w, None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    c3 = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                  jax.ShapeDtypeStruct((3, m, m), jnp.float32))
    c9 = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                  jax.ShapeDtypeStruct((9, m, m), jnp.float32))
    b3 = analyze_hlo(c3.as_text()).bytes
    b9 = analyze_hlo(c9.as_text()).bytes
    assert b9 > 2.5 * b3  # roughly linear in trip count


def test_model_train_step_flops_match_6nd():
    """End-to-end: analyzer ≈ 6·N·D (+remat) on a scanned LM train step."""
    from repro.configs.registry import get_config
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("yi-6b", reduced=True)
    tc = TrainConfig(remat="none", lr=1e-3, warmup=1, total_steps=10)
    params, opt = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    b, l = 4, 32
    batch = {"tokens": jnp.zeros((b, l), jnp.int32), "labels": jnp.zeros((b, l), jnp.int32)}
    c = jax.jit(make_train_step(cfg, tc)).lower(params, opt, batch).compile()
    a = analyze_hlo(c.as_text())
    # matmul-only estimate: 6·N·D for weights + attention quadratic terms
    n_mm = cfg.param_count() - cfg.vocab_size * cfg.d_model  # head counted below
    d_tokens = b * l
    expect = 6 * n_mm * d_tokens + 6 * cfg.vocab_size * cfg.d_model * d_tokens
    # attention score/value matmuls: 12·L²·d per layer (fwd+bwd, both einsums)
    expect += 12 * cfg.num_layers * d_tokens * l * cfg.num_heads * cfg.hd
    assert 0.5 * expect < a.flops < 2.0 * expect, (a.flops, expect)


# -- full-module hardening: tuple/token types, batched dots, liveness ---------

from repro.launch.hlo_analysis import (_dot_flops, _shape_bytes, _shape_dims,
                                       parse_computations, peak_live_bytes)

NESTED_TUPLE_HLO = """\
HloModule jit_step

%body (arg: (f32[4,2], s32[], token[])) -> (f32[4,2], s32[], token[]) {
  %arg = (f32[4,2]{1,0}, s32[], token[]) parameter(0)
  %gte0 = f32[4,2]{1,0} get-tuple-element(%arg), index=0
  %gte1 = s32[] get-tuple-element(%arg), index=1
  %tok = token[] get-tuple-element(%arg), index=2
  %one = s32[] constant(1)
  %next = s32[] add(%gte1, %one)
  %twice = f32[4,2]{1,0} add(%gte0, %gte0)
  ROOT %tuple = (f32[4,2]{1,0} /*index=0*/, s32[], token[]) tuple(%twice, %next, %tok)
}

%cond (arg: (f32[4,2], s32[], token[])) -> pred[] {
  %arg = (f32[4,2]{1,0}, s32[], token[]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=1
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[4,2]) -> f32[4,2] {
  %p = f32[4,2]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tok0 = token[] after-all()
  %init = (f32[4,2]{1,0}, s32[], token[]) tuple(%p, %zero, %tok0)
  %w = (f32[4,2]{1,0}, s32[], token[]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4,2]{1,0} get-tuple-element(%w), index=0
}
"""

BATCHED_DOT_HLO = """\
HloModule jit_bmm

ENTRY %main (p0: f32[8,16,32], p1: f32[8,32,64]) -> f32[8,16,64] {
  %p0 = f32[8,16,32]{2,1,0} parameter(0)
  %p1 = f32[8,32,64]{2,1,0} parameter(1)
  ROOT %dot = f32[8,16,64]{2,1,0} dot(%p0, %p1), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}
}
"""


def test_shape_bytes_skips_tokens_inside_tuples():
    assert _shape_bytes("(f32[2,2]{1,0}, token[])") == 16
    assert _shape_bytes("token[]") == 0
    assert _shape_bytes("(f32[4,2]{1,0}, s32[], token[])") == 36


def test_shape_dims_skips_non_array_entries():
    assert _shape_dims("(token[], f32[4,2]{1,0})") == [4, 2]
    assert _shape_dims("token[]") is None
    assert _shape_dims("s32[]") == []


def test_nested_tuple_while_module_parses_and_counts_trips():
    comps = parse_computations(NESTED_TUPLE_HLO)
    assert set(comps) == {"body", "cond", "main"}
    a = analyze_hlo(NESTED_TUPLE_HLO)
    # body: 1 (s32 add) + 8 (f32[4,2] add) flops, x5 trips from the cond
    assert a.flops == 45.0
    assert a.bytes > 0
    assert a.collective_bytes == 0.0


def test_batched_dot_contracts_the_right_dim():
    # |out| = 8*16*64 already includes the batch dim; K = 32 from the lhs
    a = analyze_hlo(BATCHED_DOT_HLO)
    assert a.flops == 2.0 * 8 * 16 * 64 * 32


def test_dot_falls_back_to_rhs_when_lhs_unresolved():
    hlo = """\
ENTRY %main (p1: f32[32,64]) -> f32[16,64] {
  %p1 = f32[32,64]{1,0} parameter(0)
  ROOT %dot = f32[16,64]{1,0} dot(%ext, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps = parse_computations(hlo)
    symtab = {i.name: i for i in comps["main"]}
    dot = symtab["dot"]
    assert "ext" not in symtab
    assert _dot_flops(dot, symtab) == 2.0 * 16 * 64 * 32


def test_peak_live_bytes_linear_chain():
    hlo = """\
ENTRY %main (p: f32[4,2]) -> f32[4,2] {
  %p = f32[4,2]{1,0} parameter(0)
  %a = f32[4,2]{1,0} add(%p, %p)
  %b = f32[4,2]{1,0} multiply(%a, %a)
  ROOT %c = f32[4,2]{1,0} add(%b, %b)
}
"""
    # two 32-byte buffers live at once (producer + consumer), never three
    assert peak_live_bytes(hlo) == 64.0


def test_peak_live_bytes_tuple_views_are_free():
    hlo = """\
ENTRY %e (p: f32[2,2]) -> (f32[2,2], f32[2,2]) {
  %p = f32[2,2]{1,0} parameter(0)
  %a = f32[2,2]{1,0} add(%p, %p)
  ROOT %t = (f32[2,2]{1,0}, f32[2,2]{1,0}) tuple(%p, %a)
}
"""
    # the tuple aliases p and a; counting it would double to 64
    assert peak_live_bytes(hlo) == 32.0


def test_peak_live_bytes_on_a_real_compiled_program():
    m = 64
    c = _compile(lambda a, b: (a @ b) @ b,
                 jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((m, m), jnp.float32))
    peak = peak_live_bytes(c.as_text())
    # at least the two parameters plus one live temp
    assert peak >= 3 * m * m * 4
    assert peak_live_bytes("") == 0.0
