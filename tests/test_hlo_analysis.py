"""The trip-count-aware HLO analyzer vs cost_analysis ground truths."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matmul_flops_exact():
    m = k = n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    a = analyze_hlo(c.as_text())
    ca = c.cost_analysis()  # dict in new jax, [dict] (one per device) in older
    ref = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    np.testing.assert_allclose(a.flops, ref, rtol=0.01)
    np.testing.assert_allclose(a.flops, 2 * m * k * n, rtol=0.01)


def test_scan_flops_scale_with_trip_count():
    m = 128

    def g(x, ws):
        def body(h, w):
            return h @ w, None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    for trips in (3, 11):
        c = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                     jax.ShapeDtypeStruct((trips, m, m), jnp.float32))
        a = analyze_hlo(c.as_text())
        np.testing.assert_allclose(a.flops, trips * 2 * m**3, rtol=0.05)


def test_nested_scan():
    m = 64

    def h(x, ws):
        def outer(carry, w2):
            def inner(c2, w):
                return c2 @ w, None

            y, _ = jax.lax.scan(inner, carry, w2)
            return y, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = _compile(h, jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((2, 5, m, m), jnp.float32))
    a = analyze_hlo(c.as_text())
    np.testing.assert_allclose(a.flops, 10 * 2 * m**3, rtol=0.05)


def test_bytes_nonzero_and_scales():
    m = 128

    def g(x, ws):
        def body(h, w):
            return h @ w, None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    c3 = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                  jax.ShapeDtypeStruct((3, m, m), jnp.float32))
    c9 = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                  jax.ShapeDtypeStruct((9, m, m), jnp.float32))
    b3 = analyze_hlo(c3.as_text()).bytes
    b9 = analyze_hlo(c9.as_text()).bytes
    assert b9 > 2.5 * b3  # roughly linear in trip count


def test_model_train_step_flops_match_6nd():
    """End-to-end: analyzer ≈ 6·N·D (+remat) on a scanned LM train step."""
    from repro.configs.registry import get_config
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("yi-6b", reduced=True)
    tc = TrainConfig(remat="none", lr=1e-3, warmup=1, total_steps=10)
    params, opt = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    b, l = 4, 32
    batch = {"tokens": jnp.zeros((b, l), jnp.int32), "labels": jnp.zeros((b, l), jnp.int32)}
    c = jax.jit(make_train_step(cfg, tc)).lower(params, opt, batch).compile()
    a = analyze_hlo(c.as_text())
    # matmul-only estimate: 6·N·D for weights + attention quadratic terms
    n_mm = cfg.param_count() - cfg.vocab_size * cfg.d_model  # head counted below
    d_tokens = b * l
    expect = 6 * n_mm * d_tokens + 6 * cfg.vocab_size * cfg.d_model * d_tokens
    # attention score/value matmuls: 12·L²·d per layer (fwd+bwd, both einsums)
    expect += 12 * cfg.num_layers * d_tokens * l * cfg.num_heads * cfg.hd
    assert 0.5 * expect < a.flops < 2.0 * expect, (a.flops, expect)
