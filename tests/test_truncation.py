"""Termination/truncation correctness sweep.

The classic conflation bug: folding a time-limit cut into `done` makes
value-based learners refuse to bootstrap at truncation, biasing targets for
every env that mostly ends by limit (Pendulum-v1, MountainCar-v0 — every
episode). The contract under test (docs/pool.md, "The info contract"):
`done` stays the folded episode boundary, `info["truncated"]` keeps the cut
distinguishable through every layer (TimeLimit, AutoReset, Vec, both pool
engines, the fused kernel), and DQN/PPO bootstrap through it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make
from repro.core.wrappers import AutoReset, TimeLimit, Vec
from repro.envs.classic import CartPole, MountainCar, Pendulum
from repro.kernels.envstep import fused_step
from repro.pool import EnvPool


def test_timelimit_sets_truncated_distinct_from_terminal():
    env = TimeLimit(Pendulum(), 3)  # never self-terminates
    key = jax.random.PRNGKey(0)
    state, _ = env.reset(key)
    for i in range(3):
        ts = env.step(state, jnp.asarray([0.0]), jax.random.fold_in(key, i))
        state = ts.state
    assert bool(ts.done) and bool(ts.info["truncated"])

    # env-terminal at the limit step is TERMINAL, not truncated
    env = TimeLimit(CartPole(), 1)
    from repro.core.wrappers import TimeLimitState
    from repro.envs.classic.cartpole import CartPoleState
    falling = TimeLimitState(
        CartPoleState(*(jnp.asarray(v) for v in (2.39, 5.0, 0.0, 0.0))),
        jnp.asarray(0, jnp.int32))
    ts = env.step(falling, jnp.asarray(1), key)
    assert bool(ts.done) and not bool(ts.info["truncated"])


def test_autoreset_and_vec_propagate_truncated():
    env = Vec(AutoReset(TimeLimit(Pendulum(), 4)), 3)
    key = jax.random.PRNGKey(1)
    state, _ = env.reset(key)
    flags = []
    for i in range(9):
        ts = env.step(state, jnp.zeros((3, 1)), jax.random.fold_in(key, i))
        state = ts.state
        assert "truncated" in ts.info and ts.info["truncated"].shape == (3,)
        flags.append(np.asarray(ts.info["truncated"]))
    # truncates at steps 4 and 8 for every env (autoreset resets the counter)
    assert flags[3].all() and flags[7].all()
    assert not np.stack(flags[:3]).any() and not np.stack(flags[4:7]).any()


@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
def test_fused_truncated_matches_vmap(backend):
    env = TimeLimit(MountainCar(), 7)
    num_envs, k = 5, 20
    key = jax.random.PRNGKey(2)
    venv = Vec(AutoReset(env), num_envs)
    state0, _ = venv.reset(key)
    state, trunc_ref, done_ref = state0, [], []
    for t in range(k):
        ts = venv.step(state, jnp.zeros((num_envs,), jnp.int32),
                       jax.random.fold_in(key, t))
        state = ts.state
        trunc_ref.append(ts.info["truncated"])
        done_ref.append(ts.done)
    _, ts_f = fused_step(env, state0, jnp.zeros((k, num_envs), jnp.int32),
                         backend=backend)
    np.testing.assert_array_equal(np.asarray(ts_f.info["truncated"]),
                                  np.asarray(jnp.stack(trunc_ref)))
    np.testing.assert_array_equal(np.asarray(ts_f.done),
                                  np.asarray(jnp.stack(done_ref)))
    assert np.asarray(jnp.stack(trunc_ref)).sum() > 0  # cuts actually happened


@pytest.mark.parametrize("backend", ["vmap", "jnp"])
def test_pool_surfaces_truncated(backend):
    pool = EnvPool("MountainCar-v0", 4, backend=backend)
    pool.reset(seed=0)
    seen = False
    for i in range(201):
        _, _, done, info = pool.step(np.ones((4,), np.int32))
        assert "truncated" in info
        seen = seen or bool(np.asarray(info["truncated"]).any())
    assert seen  # MountainCar under a fixed action always times out


def test_dqn_stores_truncation_as_nonterminal():
    """The headline regression: a time-limit cut must be stored with
    terminal=0 so the TD target `r + γ·(1-terminal)·max q(terminal_obs)`
    keeps bootstrapping. The old `(1 - done)` target stored the folded done
    (=1 at the cut) and fails this test."""
    from repro.rl.dqn import DQNConfig, dqn_init, make_train_step

    env = TimeLimit(MountainCar(), 3)  # truncates every 3 steps, no terminals
    cfg = DQNConfig(num_envs=2, learn_start=100, memory_size=32)
    state, apply_fn = dqn_init(env, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(env, apply_fn, cfg))
    for _ in range(6):
        state, _ = step_fn(state, None)
    stored = np.asarray(state.replay.done[: int(state.replay.size)])
    assert stored.shape[0] == 12
    assert stored.sum() == 0.0  # every cut is truncation — never terminal


def test_dqn_still_stores_env_terminals():
    """CartPole failures are env-terminal: the stored flag must stay 1 there
    (bootstrapping through real terminals would be the opposite bug)."""
    from repro.rl.dqn import DQNConfig, dqn_init, make_train_step

    env = make("CartPole-v1")
    cfg = DQNConfig(num_envs=4, learn_start=1000, memory_size=256,
                    exploration_start=1.0, exploration_final=1.0)  # random
    state, apply_fn = dqn_init(env, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(env, apply_fn, cfg))
    for _ in range(60):
        state, _ = step_fn(state, None)
    stored = np.asarray(state.replay.done[: int(state.replay.size)])
    assert stored.sum() > 0  # random CartPole falls well before 500 steps


def test_ppo_trains_through_truncations():
    from repro.rl.ppo import PPOConfig, train

    env = TimeLimit(MountainCar(), 8)  # truncation-only episode ends
    cfg = PPOConfig(num_envs=4, rollout_len=20, epochs=2, minibatches=2)
    _, metrics = train(env, cfg, 2, jax.random.PRNGKey(0))
    assert np.isfinite(np.asarray(metrics["loss"])).all()
    assert np.isfinite(np.asarray(metrics["return"])).all()
