"""Model-stack correctness: decode path ≡ parallel forward path, per family.

The strongest invariant in the serving stack: prefill(tokens[:L]) followed by
a decode step at position L must produce the same logits as the parallel
forward over tokens[:L+1] at its last position — for EVERY block type
(full/swa/local-global/MLA/MoE/mLSTM/sLSTM/Mamba2/shared-attn/enc-dec).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import lm


def _batch(cfg, key, b=2, l=16):
    batch = {
        "tokens": jax.random.randint(key, (b, l), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (b, l), 0, cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(jax.random.fold_in(key, 2), (b, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    b, l = 2, 12
    batch = _batch(cfg, key, b, l + 1)
    full_tokens = batch["tokens"]

    # parallel forward over L+1 tokens -> logits at last position
    fwd_batch = dict(batch, tokens=full_tokens)
    hidden, _ = lm.forward(cfg, params, fwd_batch)
    ref_logits = np.asarray(lm.logits_for(cfg, params, hidden[:, -1:]))[:, 0]

    # prefill over L tokens, then decode token L
    pre_batch = dict(batch, tokens=full_tokens[:, :l])
    _, caches = lm.prefill(cfg, params, pre_batch, max_seq=l + 4)
    logits, _ = lm.decode_step(cfg, params, caches, full_tokens[:, l:l + 1], l)
    np.testing.assert_allclose(np.asarray(logits), ref_logits, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config(arch, reduced=True)
    tc = TrainConfig(lr=3e-3, warmup=1, total_steps=50, remat="none")
    key = jax.random.PRNGKey(0)
    params, opt = init_train_state(cfg, tc, key)
    step = jax.jit(make_train_step(cfg, tc))
    batch = _batch(cfg, key)
    losses = []
    for _ in range(5):
        params, opt, m = step(params, opt, batch)  # same batch: loss must drop
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_single_batch():
    from repro.train.trainer import TrainConfig, init_train_state, make_train_step

    cfg = get_config("yi-6b", reduced=True)
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key, b=4, l=8)

    tc1 = TrainConfig(lr=1e-2, warmup=1, total_steps=10, remat="none", accum_steps=1)
    tc2 = TrainConfig(lr=1e-2, warmup=1, total_steps=10, remat="none", accum_steps=2)
    p1, o1 = init_train_state(cfg, tc1, key)
    p2, o2 = init_train_state(cfg, tc2, key)
    p1n, _, m1 = jax.jit(make_train_step(cfg, tc1))(p1, o1, batch)
    p2n, _, m2 = jax.jit(make_train_step(cfg, tc2))(p2, o2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    a = jax.tree.leaves(p1n)[0]
    b_ = jax.tree.leaves(p2n)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-5)


def test_remat_matches_no_remat():
    cfg = get_config("yi-6b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    l_none = float(lm.loss_fn(cfg, params, batch, remat="none"))
    l_full = float(lm.loss_fn(cfg, params, batch, remat="full"))
    l_dots = float(lm.loss_fn(cfg, params, batch, remat="dots"))
    np.testing.assert_allclose(l_none, l_full, rtol=1e-6)
    np.testing.assert_allclose(l_none, l_dots, rtol=1e-6)

    g_none = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, remat="none"))(params)
    g_full = jax.grad(lambda p: lm.loss_fn(cfg, p, batch, remat="full"))(params)
    for a, b in zip(jax.tree.leaves(g_none), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_chunked_ce_matches_direct():
    from repro.models.layers import chunked_cross_entropy
    from repro.train.optim import softmax_cross_entropy

    key = jax.random.PRNGKey(0)
    b, l, d, v = 2, 16, 8, 64
    hidden = jax.random.normal(key, (b, l, d))
    embed = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (b, l), 0, v)
    chunked = float(chunked_cross_entropy(hidden, embed, labels, chunk=4))
    direct = float(softmax_cross_entropy(hidden @ embed.T, labels).mean())
    np.testing.assert_allclose(chunked, direct, rtol=1e-5)


def test_swa_sees_only_window():
    """A token beyond the window must not influence attention output."""
    cfg = ModelConfig(name="w", family="dense", d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=64, segments=((("swa",), 1),), window=4,
                      dtype="float32")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 10), 0, 64)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % 64)  # perturb a token far outside window
    h1, _ = lm.forward(cfg, params, {"tokens": toks})
    h2, _ = lm.forward(cfg, params, {"tokens": toks2})
    # last position attends only to positions 6..9 -> unchanged
    np.testing.assert_allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]), atol=1e-5)
    # but an early position does change
    assert not np.allclose(np.asarray(h1[:, 1]), np.asarray(h2[:, 1]), atol=1e-5)
