"""Sharding rules + multi-device integration (subprocess: needs >1 device)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import lm
from repro.sharding import rules


class _FakeMesh:
    """Just enough Mesh interface for spec derivation."""

    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divide_evenly(arch):
    """Every sharded dim must divide by its mesh axes (the invariant the
    rule-cleaner enforces); replicate otherwise."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = rules.param_specs(params, mesh)

    def check(leaf, spec):
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[i] % n == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, params, specs)


def test_tp_axes_actually_used():
    cfg = get_config("yi-6b")
    params = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = rules.param_specs(params, mesh)
    flat = jax.tree.leaves(specs)
    used_model = sum(1 for s in flat for ax in s if ax == "model" or (isinstance(ax, tuple) and "model" in ax))
    used_data = sum(1 for s in flat for ax in s if ax == "data" or (isinstance(ax, tuple) and "data" in ax))
    assert used_model > 4 and used_data > 4  # TP and FSDP both engaged


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs.registry import get_config
from repro.models import lm
from repro.sharding import rules
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("yi-6b", reduced=True)
tc = TrainConfig(remat="none", lr=1e-3, warmup=1, total_steps=10)
params, opt = init_train_state(cfg, tc, jax.random.PRNGKey(0))
batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
         "labels": jnp.zeros((8, 16), jnp.int32)}

# single-device reference
step0 = jax.jit(make_train_step(cfg, tc))
_, _, m0 = step0(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)

psh = rules.to_shardings(rules.param_specs(params, mesh), mesh)
osh = rules.to_shardings(rules.opt_specs(opt, params, mesh), mesh)
bsh = rules.to_shardings(rules.batch_specs(mesh, batch), mesh)
step = jax.jit(make_train_step(cfg, tc), in_shardings=(psh, osh, bsh),
               out_shardings=(psh, osh, None))
with mesh:
    params = jax.device_put(params, psh)
    opt = jax.device_put(opt, osh)
    batch = jax.device_put(batch, bsh)
    params, opt, m = step(params, opt, batch)
print(json.dumps({"sharded_loss": float(m["loss"]), "ref_loss": float(m0["loss"])}))
"""


def test_sharded_train_step_matches_single_device():
    """GSPMD-sharded train step ≡ single-device semantics (8 fake devices)."""
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT], capture_output=True,
                         text=True, timeout=600, env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["sharded_loss"] - res["ref_loss"]) < 1e-3, res


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json, tempfile
from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.models import lm
from repro.sharding import rules

cfg = get_config("yi-6b", reduced=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mesh8 = jax.make_mesh((2, 4), ("data", "model"))
with mesh8:
    p8 = jax.device_put(params, rules.to_shardings(rules.param_specs(params, mesh8), mesh8))
mgr.save(1, p8)
# elastic restore onto a DIFFERENT mesh (4, 1) — simulating node loss
mesh4 = jax.make_mesh((4, 1), ("data", "model"), devices=jax.devices()[:4])
with mesh4:
    sh4 = rules.to_shardings(rules.param_specs(params, mesh4), mesh4)
    p4 = mgr.restore(params, shardings=sh4)
import numpy as np
ok = all(np.allclose(np.asarray(a), np.asarray(b))
         for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p4)))
print(json.dumps({"elastic_restore_ok": bool(ok)}))
"""


def test_elastic_restore_across_meshes():
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], capture_output=True,
                         text=True, timeout=600, env=_env())
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["elastic_restore_ok"]


def _env():
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return env
