"""Gridworld suite: dynamics units, per-episode level regeneration, and the
fused megastep path across autoreset boundaries.

The generic cross-backend sweep lives in tests/test_conformance.py; here are
the grid-specific behaviours: hole/cliff/wall semantics, the deterministic
food chain, solvability of regenerated levels (plain seed sweep — the
hypothesis variant in test_property.py skips when hypothesis is absent),
and the acceptance case: level layout regenerating *inside* a fused chunk,
bit-identical to vmap.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import bfs_reachable

from repro.core import make
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset, TimeLimit, Vec
from repro.envs.grid import CliffWalk, FrozenLake, Maze, Snake
from repro.envs.grid.cliff_walk import CLIFF_REWARD
from repro.kernels.envstep import fused_step
from repro.pool import EnvPool, ShardedEnvPool, default_pool_mesh


def test_frozen_lake_hole_and_goal():
    env = FrozenLake()
    holes = jnp.zeros((16,), jnp.int32).at[1].set(1)
    state = env.reset(jax.random.PRNGKey(0))[0]._replace(
        pos=jnp.asarray(0, jnp.int32), holes=holes)
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))  # right -> hole
    assert bool(ts.done) and float(ts.reward) == 0.0
    state = state._replace(pos=jnp.asarray(14, jnp.int32))
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))  # right -> goal
    assert bool(ts.done) and float(ts.reward) == 1.0
    # bumping the boundary stays put and continues
    state = state._replace(pos=jnp.asarray(0, jnp.int32),
                           holes=jnp.zeros((16,), jnp.int32))
    ts = env.step(state, jnp.asarray(3), jax.random.PRNGKey(1))  # up at top row
    assert int(ts.state.pos) == 0 and not bool(ts.done)


def test_cliff_teleports_back_to_start():
    env = CliffWalk()
    state, _ = env.reset(jax.random.PRNGKey(0))
    # bottom-left start; the cell to the right is always classic cliff
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))
    assert float(ts.reward) == CLIFF_REWARD
    assert not bool(ts.done)                      # falling does not terminate
    assert int(ts.state.pos) == env.start         # teleported home
    # goal cell terminates with the ordinary -1 step reward
    state = state._replace(pos=jnp.asarray(env.m - 2, jnp.int32))
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))
    assert bool(ts.done) and float(ts.reward) == -1.0


def test_maze_walls_block():
    env = Maze()
    walls = jnp.zeros((64,), jnp.int32).at[1].set(1)
    state = env.reset(jax.random.PRNGKey(0))[0]._replace(
        pos=jnp.asarray(0, jnp.int32), walls=walls)
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))  # right: wall
    assert int(ts.state.pos) == 0 and not bool(ts.done)
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(1))  # down: free
    assert int(ts.state.pos) == 8


def test_snake_eats_grows_and_dies():
    env = Snake()
    state, _ = env.reset(jax.random.PRNGKey(3))
    # plant food right of the head, then eat it
    food = state.head + 1
    state = state._replace(food=food.astype(jnp.int32))
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))
    assert float(ts.reward) == 1.0 and not bool(ts.done)
    assert int(ts.state.length) == 2 and int(ts.state.head) == int(food)
    assert int(ts.state.food) != int(food)        # the chain moved the food
    assert int(np.asarray(ts.state.ages).max()) == 2
    # walking off the board dies
    state = state._replace(head=jnp.asarray(0, jnp.int32), food=jnp.asarray(7, jnp.int32))
    ts = env.step(state, jnp.asarray(3), jax.random.PRNGKey(1))  # up off-board
    assert bool(ts.done) and float(ts.reward) == -1.0


def test_levels_regenerate_and_stay_solvable():
    """Seed sweep (the hypothesis twin lives in test_property.py): every
    regenerated FrozenLake/Maze level is solvable, and layouts actually vary
    across episodes — procedural generation, not a fixed map."""
    lake, maze = FrozenLake(), Maze()
    lake_layouts, maze_goals = set(), set()
    for seed in range(25):
        s, _ = lake.reset(jax.random.PRNGKey(seed))
        holes = np.asarray(s.holes)
        assert bfs_reachable(holes, lake.n, lake.n, 0, lake.m - 1)
        lake_layouts.add(holes.tobytes())
        s, _ = maze.reset(jax.random.PRNGKey(1000 + seed))
        walls, goal = np.asarray(s.walls), int(s.goal)
        assert bfs_reachable(walls, maze.n, maze.n, 0, goal)
        maze_goals.add(goal)
    assert len(lake_layouts) >= 20   # distinct levels
    assert len(maze_goals) >= 10     # the goal itself is procedural


@pytest.mark.slow
@pytest.mark.parametrize("backend", ("jnp", "pallas_interpret"))
def test_fused_layout_regenerates_across_autoreset(backend):
    """Acceptance: a short TimeLimit forces several episode boundaries inside
    one fused chunk; the regenerated layouts must match the vmap stream bit
    for bit AND actually differ between episodes."""
    env = TimeLimit(FrozenLake(), 5)
    num_envs, k = 4, 23
    key = jax.random.PRNGKey(11)
    actions = jnp.stack([sample_batch(env.action_space,
                                      jax.random.fold_in(key, 100 + t),
                                      num_envs) for t in range(k)])
    venv = Vec(AutoReset(env), num_envs)
    state0, _ = venv.reset(key)
    state, obs_seq, done_seq = state0, [], []
    for t in range(k):
        ts = venv.step(state, actions[t], jax.random.fold_in(key, t))
        state = ts.state
        obs_seq.append(ts.obs)
        done_seq.append(ts.done)
    obs_ref = jnp.stack(obs_seq)
    done_ref = np.asarray(jnp.stack(done_seq))

    st_f, ts = fused_step(env, state0, actions, backend=backend)
    np.testing.assert_array_equal(np.asarray(ts.obs), np.asarray(obs_ref))
    np.testing.assert_array_equal(np.asarray(ts.done), done_ref)
    assert done_ref.sum() >= 3 * num_envs  # several regen boundaries crossed

    # Layout = the hole field visible in the obs codes (code 1 cells; the
    # reset obs has the agent parked on cell 0). Collect per-episode layouts
    # of env 0 from the fused outputs: they must not all be the same level.
    layouts = {np.asarray(ts.obs[t, 0] == 1).tobytes()
               for t in range(k) if done_ref[t, 0]}
    assert len(layouts) >= 2


def test_grid_pools_and_sharding():
    """Grid ids flow through EnvPool and ShardedEnvPool unchanged."""
    rew_u, eps_u, _ = EnvPool("Snake-v0", 8).rollout(30, jax.random.PRNGKey(5))
    sharded = ShardedEnvPool("Snake-v0", 8, mesh=default_pool_mesh(1),
                             backend="jnp", unroll=8)
    rew_s, eps_s, _ = sharded.rollout(30, jax.random.PRNGKey(5))
    np.testing.assert_allclose(np.asarray(rew_s), np.asarray(rew_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_s), np.asarray(eps_u))
    assert int(np.asarray(eps_u).sum()) > 0
    pool = EnvPool("FrozenLake-v0", 4)
    obs = pool.reset(0)
    assert obs.shape == (4, 16) and obs.dtype == jnp.int32
    obs, rew, done, info = pool.step(pool.sample_actions(1))
    assert "truncated" in info and obs.dtype == jnp.int32


@pytest.mark.slow
def test_dqn_training_parity_on_grid():
    """DQN trains on a MultiDiscrete-obs grid env, and the fused engine
    reproduces the vmap engine's training trajectory."""
    from repro.rl.dqn import DQNConfig, train_compiled

    env = make("FrozenLake-v0")
    key = jax.random.PRNGKey(0)
    cfg = DQNConfig(num_envs=4, learn_start=20, memory_size=200)
    _, _, m_v = train_compiled(env, cfg, 40, key)
    _, _, m_f = train_compiled(
        env, dataclasses.replace(cfg, env_backend="jnp"), 40, key)
    assert np.all(np.isfinite(np.asarray(m_v["loss"])))
    np.testing.assert_allclose(np.asarray(m_v["return"]),
                               np.asarray(m_f["return"]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_v["loss"]),
                               np.asarray(m_f["loss"]), rtol=2e-4, atol=1e-5)


@pytest.mark.slow
def test_ppo_trains_on_grid():
    from repro.rl.ppo import PPOConfig, train

    env = make("Snake-v0")
    cfg = PPOConfig(num_envs=8, rollout_len=32, epochs=2, minibatches=2)
    _, metrics = train(env, cfg, 2, jax.random.PRNGKey(0))
    assert np.all(np.isfinite(np.asarray(metrics["return"])))
