"""Hypothesis drivers over the fused-trainer invariants.

The property bodies live in tests/test_train_fused.py
(`check_replay_chunking`, `check_fused_interleaving`) so the same
invariants still run — over seeded draws — when hypothesis is absent;
these drivers widen the search when it is installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from test_train_fused import check_fused_interleaving, check_replay_chunking


@settings(max_examples=25, deadline=None)
@given(cap=st.integers(1, 12),
       batches=st.lists(st.integers(1, 15), min_size=1, max_size=6),
       seed=st.integers(0, 2**16 - 1))
def test_replay_ring_invariant_under_chunking(cap, batches, seed):
    """No transition lost or duplicated at an add-call boundary, for any
    (capacity, batch sizes, regrouping of the same stream)."""
    rng = np.random.default_rng(seed)
    total = sum(batches)
    if total <= 1:
        regroup = [total]
    else:
        n_cuts = int(rng.integers(0, total))
        cuts = sorted(rng.choice(np.arange(1, total),
                                 size=min(n_cuts, total - 1),
                                 replace=False).tolist())
        regroup = [b - a for a, b in zip([0] + cuts, cuts + [total])]
    check_replay_chunking(cap, batches, regroup)


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(chunk=st.integers(1, 16), cap=st.sampled_from([24, 48, 96]),
       batch=st.sampled_from([4, 8]), width=st.integers(1, 2),
       seed=st.integers(0, 2**16 - 1))
def test_fused_trainer_interleaving_property(chunk, cap, batch, width, seed):
    """Donated chunked training ≡ monolithic program bit for bit, replay
    cursor lands per the stream length, fleet rows reproduce solo runs —
    for random (chunk, capacity, batch, width) interleavings."""
    check_fused_interleaving(chunk, cap, batch, width, seed)
