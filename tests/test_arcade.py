"""Arcade pixel-game suite: dynamics, baselines, and fused-engine parity.

Mirrors tests/test_envstep_fused.py for the pixel workload class: for
`Pong-v0` / `Breakout-v0` (FrameStack(ObsToPixels(TimeLimit(game)))) and the
`-raw` state-vector variants, the fused megastep path — game logic in the
kernel, frames rasterised per-chunk outside it — must reproduce the
scan-of-vmap-step trajectory (exact for int/bool fields, <=1e-5 floats),
including auto-reset boundaries and the frame-stack ring. Pixel rollouts
must stay device-resident (zero host transfers in the compiled HLO) and be
deterministic in the key.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make
from repro.core.env import supports_fused_step
from repro.core.spaces import sample_batch
from repro.core.wrappers import AutoReset, FrameStack, ObsToPixels, TimeLimit, Vec
from repro.envs.arcade import Breakout, Pong
from repro.envs.arcade.breakout import BreakoutState
from repro.envs.arcade.pong import PongState
from repro.envs.baseline_python.arcade import BreakoutPy, PongPy
from repro.kernels.envstep import fused_step
from repro.launch.hlo_analysis import host_transfer_ops
from repro.pool import EnvPool, ShardedEnvPool, default_pool_mesh

ARCADE_IDS = ["Pong-v0", "Breakout-v0", "Pong-raw", "Breakout-raw"]
BACKENDS = ("jnp", "pallas_interpret")


# -- dynamics vs the interpreted ports (test_envs.py pattern) ----------------

def test_pong_matches_python():
    actions = [0, 2, 1, 2, 2, 0, 1, 2, 0, 1, 2, 2, 1, 0, 2]
    py = PongPy()
    py.reset()
    py.ball_x, py.ball_y = 0.5, 0.4
    py.ball_vx, py.ball_vy = 0.035, 0.013
    py.player_y, py.opp_y = 0.45, 0.55
    env = Pong()
    state = PongState(*(jnp.asarray(v, jnp.float32)
                        for v in (0.5, 0.4, 0.035, 0.013, 0.45, 0.55)))
    for a in actions:
        po, pr, pd, _ = py.step(a)
        ts = env.step(state, jnp.asarray(a), jax.random.PRNGKey(0))
        state = ts.state
        np.testing.assert_allclose(np.asarray(ts.obs), np.asarray(po),
                                   rtol=1e-5, atol=1e-6)
        assert pd == bool(ts.done) and abs(pr - float(ts.reward)) < 1e-6


def test_breakout_matches_python_and_breaks_bricks():
    actions = [1, 1, 1, 0, 2, 1, 1, 1, 0, 2, 1, 1]
    py = BreakoutPy()
    py.reset()
    py.ball_x, py.ball_y = 0.31, 0.505   # off the brick-boundary lattice
    py.ball_vx, py.ball_vy = 0.022, -0.03
    py.paddle_x = 0.4
    py.bricks = [[1] * 6 for _ in range(4)]
    env = Breakout()
    state = BreakoutState(*(jnp.asarray(v, jnp.float32)
                            for v in (0.31, 0.505, 0.022, -0.03, 0.4)),
                          jnp.ones((4, 6), jnp.int32))
    broke = 0.0
    for a in actions:
        po, pr, pd, _ = py.step(a)
        ts = env.step(state, jnp.asarray(a), jax.random.PRNGKey(0))
        state = ts.state
        np.testing.assert_allclose(np.asarray(ts.obs), np.asarray(po),
                                   rtol=1e-5, atol=1e-6)
        assert pd == bool(ts.done) and abs(pr - float(ts.reward)) < 1e-6
        broke += pr
    assert broke >= 1.0  # the upward serve reached the brick grid


def test_pong_scores_and_terminates():
    env = Pong()
    # ball one step from passing the agent, paddle far away
    state = PongState(*(jnp.asarray(v, jnp.float32)
                        for v in (0.98, 0.2, 0.035, 0.0, 0.8, 0.5)))
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(0))
    assert bool(ts.done) and float(ts.reward) == -1.0


def test_breakout_clear_bonus():
    env = Breakout()
    bricks = jnp.zeros((4, 6), jnp.int32).at[3, 2].set(1)  # one brick left
    # ball inside the last brick's cell next step: x≈0.41 (col 2), y->0.295
    state = BreakoutState(*(jnp.asarray(v, jnp.float32)
                            for v in (0.41, 0.325, 0.0, -0.03, 0.5)), bricks)
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(0))
    assert bool(ts.done) and float(ts.reward) == 6.0  # +1 brick, +5 clear


def test_pixel_obs_pipeline_shapes():
    env = make("Pong-v0")
    assert env.observation_space.shape == (4, 84, 84)
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (4, 84, 84)
    ts = env.step(state, jnp.asarray(2), jax.random.PRNGKey(1))
    assert ts.obs.shape == (4, 84, 84)
    # the newest frame enters at the end of the ring and pixels move
    assert not np.allclose(np.asarray(ts.obs[3]), np.asarray(obs[3]))
    assert "truncated" in ts.info


def test_supports_fused_step_arcade_contract():
    for name in ARCADE_IDS:
        assert supports_fused_step(make(name)), name
    # FrameStack over a non-pixel env is NOT modelled by the fused engine
    assert not supports_fused_step(FrameStack(make("CartPole-v1"), 4))


# -- fused vs vmap parity (pixel pipeline included) ---------------------------

def _vmap_reference(env, num_envs, key, actions):
    venv = Vec(AutoReset(env), num_envs)
    state0, _ = venv.reset(key)
    state, outs = state0, []
    for t in range(actions.shape[0]):
        ts = venv.step(state, actions[t], jax.random.fold_in(key, t))
        state = ts.state
        outs.append(ts)
    return state0, state, outs


def _check_parity(env, num_envs, key, actions, backend):
    st0, st_ref, outs = _vmap_reference(env, num_envs, key, actions)
    st_f, ts = fused_step(env, st0, actions, backend=backend)
    stack = lambda f: jnp.stack([f(o) for o in outs])
    np.testing.assert_allclose(np.asarray(ts.obs),
                               np.asarray(stack(lambda o: o.obs)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ts.reward),
                               np.asarray(stack(lambda o: o.reward)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ts.done),
                                  np.asarray(stack(lambda o: o.done)))
    np.testing.assert_allclose(
        np.asarray(ts.info["terminal_obs"]),
        np.asarray(stack(lambda o: o.info["terminal_obs"])),
        rtol=1e-5, atol=1e-6)
    if "truncated" in outs[0].info:
        np.testing.assert_array_equal(
            np.asarray(ts.info["truncated"]),
            np.asarray(stack(lambda o: o.info["truncated"])))
    for a, b in zip(jax.tree.leaves(st_ref), jax.tree.leaves(st_f)):
        assert a.dtype == b.dtype and a.shape == b.shape
        if np.issubdtype(np.asarray(a).dtype, np.integer) or \
                np.asarray(a).dtype == np.uint32:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
    return stack(lambda o: o.done)


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", ARCADE_IDS)
def test_arcade_fused_matches_vmap(name, backend):
    env = make(name)
    num_envs, k = 4, 10
    key = jax.random.PRNGKey(sum(map(ord, name)))
    actions = jnp.stack([
        sample_batch(env.action_space, jax.random.fold_in(key, 100 + t),
                     num_envs) for t in range(k)])
    _check_parity(env, num_envs, key, actions, backend)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["Pong-v0", "Breakout-v0"])
def test_arcade_fused_autoreset_boundary(name):
    """Under 'stay' the ball drops / rallies end well inside K: the pixel
    auto-reset re-entry (fresh frames + frame-stack ring refill) fires."""
    env = make(name)
    k, num_envs = 40, 4
    actions = jnp.ones((k, num_envs), jnp.int32)
    done = _check_parity(env, num_envs, jax.random.PRNGKey(11), actions, "jnp")
    assert int(np.asarray(done).sum()) >= num_envs  # every env reset >= once


@pytest.mark.slow
def test_arcade_timelimit_truncation_fused():
    """A short pixel TimeLimit truncates inside K: counter + ring both reset."""
    env = FrameStack(ObsToPixels(TimeLimit(Pong(), 6)), 3)
    k, num_envs = 14, 3
    actions = jnp.zeros((k, num_envs), jnp.int32)
    done = _check_parity(env, num_envs, jax.random.PRNGKey(4), actions, "jnp")
    assert int(np.asarray(done).sum()) >= 2 * num_envs


# -- pools ---------------------------------------------------------------------

def test_arcade_pool_pallas_interpret_acceptance():
    """Acceptance: both arcade ids run through
    EnvPool(backend="pallas_interpret", unroll=8) — Pallas megastep kernel
    AND Pallas rasteriser, both in interpret mode."""
    for name in ("Pong-v0", "Breakout-v0"):
        pool = EnvPool(name, 4, backend="pallas_interpret", unroll=8)
        obs = pool.reset(seed=0)
        assert obs.shape == (4, 4, 84, 84)
        obs, rew, done, info = pool.step(pool.sample_actions(0))
        assert obs.shape == (4, 4, 84, 84)
        assert "truncated" in info and "terminal_obs" in info
        rew_f, eps_f, _ = pool.rollout(16, jax.random.PRNGKey(1))
        assert np.isfinite(np.asarray(rew_f)).all()


@pytest.mark.slow
def test_arcade_pool_fused_rollout_matches_vmap():
    key = jax.random.PRNGKey(7)
    rew_v, eps_v, _ = EnvPool("Breakout-v0", 4).rollout(30, key)
    rew_f, eps_f, _ = EnvPool("Breakout-v0", 4, backend="jnp",
                              unroll=8).rollout(30, key)  # 30 = 3*8 + 6
    np.testing.assert_allclose(np.asarray(rew_v), np.asarray(rew_f),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_v), np.asarray(eps_f))
    assert int(np.asarray(eps_v).sum()) > 0  # episodes crossed chunk seams


@pytest.mark.slow
def test_arcade_sharded_matches_unsharded_on_one_device_mesh():
    key = jax.random.PRNGKey(5)
    sharded = ShardedEnvPool("Pong-v0", 4, mesh=default_pool_mesh(1),
                             backend="jnp", unroll=8)
    plain = EnvPool("Pong-v0", 4)
    rew_s, eps_s, _ = sharded.rollout(20, key)
    rew_u, eps_u, _ = plain.rollout(20, key)
    np.testing.assert_allclose(np.asarray(rew_s), np.asarray(rew_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(eps_s), np.asarray(eps_u))


def test_arcade_pixel_rollout_is_device_resident():
    """Acceptance: zero host transfers in the compiled fused PIXEL rollout —
    rendering included."""
    pool = EnvPool("Pong-v0", 8, backend="jnp", unroll=8)
    hlo = pool.rollout_lowered(16).compile().as_text()
    assert host_transfer_ops(hlo) == []


def test_arcade_pixel_rollout_deterministic():
    """Same key ⇒ same pixel rollout, including the final observation."""
    key = jax.random.PRNGKey(3)
    p1 = EnvPool("Breakout-v0", 3, backend="jnp", unroll=4)
    p2 = EnvPool("Breakout-v0", 3, backend="jnp", unroll=4)
    r1, e1, _ = p1.rollout(12, key)
    r2, e2, _ = p2.rollout(12, key)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    o1, o2 = p1.reset(seed=9), p2.reset(seed=9)
    a = p1.sample_actions(0)
    np.testing.assert_array_equal(np.asarray(p1.step(a)[0]),
                                  np.asarray(p2.step(a)[0]))


# -- learning ------------------------------------------------------------------

@pytest.mark.slow
def test_dqn_cnn_trains_on_pong_pixels():
    """The end-to-end §IV-C claim: pixel obs feed DQN's CNN on device, on
    both step engines, with matching training curves."""
    import dataclasses

    from repro.rl.dqn import DQNConfig, train_compiled

    env = make("Pong-v0")
    cfg = DQNConfig(network="cnn", num_envs=2, learn_start=8, memory_size=64,
                    batch_size=8)
    key = jax.random.PRNGKey(0)
    _, _, m_v = train_compiled(env, cfg, 10, key)
    _, _, m_f = train_compiled(
        env, dataclasses.replace(cfg, env_backend="jnp"), 10, key)
    assert np.isfinite(np.asarray(m_v["loss"])).all()
    np.testing.assert_allclose(np.asarray(m_v["return"]),
                               np.asarray(m_f["return"]), rtol=1e-4, atol=1e-4)
