"""Wrapper semantics + space behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Box, Discrete, FlattenObs, MultiDiscrete, TimeLimit, Vec, make
from repro.core.wrappers import FrameStack, ObsToPixels
from repro.envs.classic import CartPole, Pendulum


def test_discrete_sample_contains():
    sp = Discrete(5)
    for i in range(10):
        s = sp.sample(jax.random.PRNGKey(i))
        assert bool(sp.contains(s))
    assert not bool(sp.contains(jnp.asarray(7)))


def test_box_sample_bounds():
    sp = Box(low=-2.0, high=2.0, shape=(3,))
    s = sp.sample(jax.random.PRNGKey(0))
    assert bool(sp.contains(s))


def test_multidiscrete():
    sp = MultiDiscrete((2, 3, 4))
    s = sp.sample(jax.random.PRNGKey(0))
    assert s.shape == (3,)
    assert bool(sp.contains(s))


def test_contains_rejects_fractional_values():
    """Regression (int32-vs-f32 audit): a float obs that is not integral is
    NOT in a Discrete/MultiDiscrete space — the fused megastep path computes
    int observations in f32 rows, and a missing round-trip cast must fail
    `contains`, not silently pass (tests/test_conformance.py relies on it)."""
    assert not bool(Discrete(4).contains(jnp.asarray(2.5)))
    assert bool(Discrete(4).contains(jnp.asarray(2.0)))   # integral float ok
    assert bool(Discrete(4).contains(jnp.asarray(2)))
    sp = MultiDiscrete((4, 4, 4))
    assert not bool(sp.contains(jnp.asarray([1.0, 2.5, 3.0])))
    assert bool(sp.contains(jnp.asarray([1.0, 2.0, 3.0])))
    assert bool(sp.contains(np.asarray([1, 2, 3], np.int64)))  # host ints


def test_multidiscrete_sample_dtype_and_bounds():
    """Regression: `sample`/`sample_batch` keep the space dtype and respect
    per-axis bounds (layout-valued grid observation spaces are wide —
    (4,)*64 — so the batch path must not unroll per-axis randints)."""
    from repro.core.spaces import sample_batch

    sp = MultiDiscrete((4,) * 9)
    s = sp.sample(jax.random.PRNGKey(0))
    assert s.dtype == sp.dtype and s.shape == (9,)
    assert bool(sp.contains(s))
    batch = sample_batch(sp, jax.random.PRNGKey(1), 64)
    assert batch.dtype == sp.dtype and batch.shape == (64, 9)
    arr = np.asarray(batch)
    assert arr.min() >= 0 and arr.max() < 4
    assert len(np.unique(arr)) == 4  # every code shows up across 576 draws
    ragged = MultiDiscrete((2, 3, 7))
    rb = np.asarray(sample_batch(ragged, jax.random.PRNGKey(2), 128))
    assert (rb < np.asarray([2, 3, 7])).all() and (rb >= 0).all()
    assert rb[:, 2].max() >= 3  # axis bounds are per-axis, not min(nvec)


def test_sample_batch_dtype_matches_space():
    from repro.core.spaces import sample_batch

    for sp in (Discrete(5), Box(low=-1.0, high=1.0, shape=(3,)),
               MultiDiscrete((2, 5))):
        batch = sample_batch(sp, jax.random.PRNGKey(3), 7)
        assert batch.dtype == sp.dtype, type(sp).__name__
        assert batch.shape == (7,) + tuple(sp.shape)


def test_time_limit_truncates():
    env = TimeLimit(Pendulum(), 5)  # pendulum never self-terminates
    key = jax.random.PRNGKey(0)
    state, _ = env.reset(key)
    done = False
    for i in range(5):
        ts = env.step(state, jnp.asarray([0.0]), jax.random.fold_in(key, i))
        state, done = ts.state, bool(ts.done)
    assert done


def test_flatten_obs():
    env = FlattenObs(make("LightsOut-v0", n=3))
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.ndim == 1
    assert env.observation_space.shape == (9,)


def test_vec_batches_everything():
    env = Vec(CartPole(), 6)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    assert obs.shape == (6, 4)
    actions = env.sample_actions(jax.random.PRNGKey(1))
    ts = env.step(state, actions, jax.random.PRNGKey(2))
    assert ts.reward.shape == (6,)
    frames = env.render(ts.state)
    assert frames.shape == (6, 84, 84)


def test_obs_to_pixels():
    env = ObsToPixels(CartPole())
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (84, 84)
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(1))
    assert ts.obs.shape == (84, 84)
    # moving cart changes pixels
    assert not np.allclose(np.asarray(obs), np.asarray(ts.obs))


def test_frame_stack_ring():
    env = FrameStack(ObsToPixels(CartPole()), 3)
    assert env.observation_space.shape == (3, 84, 84)
    state, obs = env.reset(jax.random.PRNGKey(0))
    # reset fills the stack with the initial frame
    np.testing.assert_array_equal(np.asarray(obs[0]), np.asarray(obs[2]))
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(1))
    # the previous newest frame shifted one slot toward the past
    np.testing.assert_array_equal(np.asarray(ts.obs[1]), np.asarray(obs[2]))
    assert not np.allclose(np.asarray(ts.obs[2]), np.asarray(obs[2]))
    # step-axis stacking preserves the truncated/done plumbing
    ts2 = env.step(ts.state, jnp.asarray(0), jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(ts2.obs[0]),
                                  np.asarray(ts.obs[1]))
