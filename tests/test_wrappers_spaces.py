"""Wrapper semantics + space behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Box, Discrete, FlattenObs, MultiDiscrete, TimeLimit, Vec, make
from repro.core.wrappers import FrameStack, ObsToPixels
from repro.envs.classic import CartPole, Pendulum


def test_discrete_sample_contains():
    sp = Discrete(5)
    for i in range(10):
        s = sp.sample(jax.random.PRNGKey(i))
        assert bool(sp.contains(s))
    assert not bool(sp.contains(jnp.asarray(7)))


def test_box_sample_bounds():
    sp = Box(low=-2.0, high=2.0, shape=(3,))
    s = sp.sample(jax.random.PRNGKey(0))
    assert bool(sp.contains(s))


def test_multidiscrete():
    sp = MultiDiscrete((2, 3, 4))
    s = sp.sample(jax.random.PRNGKey(0))
    assert s.shape == (3,)
    assert bool(sp.contains(s))


def test_time_limit_truncates():
    env = TimeLimit(Pendulum(), 5)  # pendulum never self-terminates
    key = jax.random.PRNGKey(0)
    state, _ = env.reset(key)
    done = False
    for i in range(5):
        ts = env.step(state, jnp.asarray([0.0]), jax.random.fold_in(key, i))
        state, done = ts.state, bool(ts.done)
    assert done


def test_flatten_obs():
    env = FlattenObs(make("LightsOut-v0", n=3))
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.ndim == 1
    assert env.observation_space.shape == (9,)


def test_vec_batches_everything():
    env = Vec(CartPole(), 6)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    assert obs.shape == (6, 4)
    actions = env.sample_actions(jax.random.PRNGKey(1))
    ts = env.step(state, actions, jax.random.PRNGKey(2))
    assert ts.reward.shape == (6,)
    frames = env.render(ts.state)
    assert frames.shape == (6, 84, 84)


def test_obs_to_pixels():
    env = ObsToPixels(CartPole())
    state, obs = env.reset(jax.random.PRNGKey(0))
    assert obs.shape == (84, 84)
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(1))
    assert ts.obs.shape == (84, 84)
    # moving cart changes pixels
    assert not np.allclose(np.asarray(obs), np.asarray(ts.obs))


def test_frame_stack_ring():
    env = FrameStack(ObsToPixels(CartPole()), 3)
    assert env.observation_space.shape == (3, 84, 84)
    state, obs = env.reset(jax.random.PRNGKey(0))
    # reset fills the stack with the initial frame
    np.testing.assert_array_equal(np.asarray(obs[0]), np.asarray(obs[2]))
    ts = env.step(state, jnp.asarray(1), jax.random.PRNGKey(1))
    # the previous newest frame shifted one slot toward the past
    np.testing.assert_array_equal(np.asarray(ts.obs[1]), np.asarray(obs[2]))
    assert not np.allclose(np.asarray(ts.obs[2]), np.asarray(obs[2]))
    # step-axis stacking preserves the truncated/done plumbing
    ts2 = env.step(ts.state, jnp.asarray(0), jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(ts2.obs[0]),
                                  np.asarray(ts.obs[1]))
